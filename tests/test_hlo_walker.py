"""Unit tests for the trip-multiplying HLO cost walker (the §Roofline
measurement engine)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed import hlo_analysis as ha


def lower_text(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


class TestByteRules:
    def test_scan_over_stack_byte_bound(self):
        """Scanning over a stacked weight is charged within a small constant
        of one stack pass per trip set.

        A bare dynamic-slice is charged at slice size (2x out); when the CPU
        compiler *fuses* the slice, the fusion boundary charges its full
        operand once per trip -- a documented over-count (EXPERIMENTS
        caveats) bounded here at 3x the per-trip stack read, far below
        pathological repeated-stack blowups."""
        stack = jax.ShapeDtypeStruct((16, 128, 128), np.float32)
        x = jax.ShapeDtypeStruct((128, 128), np.float32)

        def f(stack, x):
            def body(c, w):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, stack)
            return out

        cost = ha.analyze_hlo(lower_text(f, stack, x))
        stack_bytes = 16 * 128 * 128 * 4
        slice_per_step = 16 * (128 * 128 * 4)
        assert slice_per_step < cost.bytes < 16 * 3 * stack_bytes

    def test_flops_scale_with_trip_count(self):
        def make(n):
            def f(x):
                def body(c, _):
                    return c @ c, None
                out, _ = jax.lax.scan(body, x, None, length=n)
                return out
            return f

        x = jax.ShapeDtypeStruct((64, 64), np.float32)
        f4 = ha.analyze_hlo(lower_text(make(4), x)).flops
        f8 = ha.analyze_hlo(lower_text(make(8), x)).flops
        assert f8 / f4 == pytest.approx(2.0, rel=0.2)

    def test_elementwise_excluded_from_proxy_bytes(self):
        """A pure elementwise chain contributes to bytes_strict but not to
        the TPU-proxy bytes term (a TPU compile fuses it)."""
        x = jax.ShapeDtypeStruct((1024, 1024), np.float32)

        def f(x):
            return jnp.tanh(x) * 2.0 + 1.0

        cost = ha.analyze_hlo(lower_text(f, x))
        assert cost.bytes_strict > 0
        assert cost.bytes <= cost.bytes_strict

    def test_strict_always_upper_bounds_proxy(self):
        x = jax.ShapeDtypeStruct((64, 64), np.float32)

        def f(x):
            y = jnp.tanh(x @ x)
            return (y * y).sum()

        cost = ha.analyze_hlo(lower_text(f, x))
        assert cost.bytes_strict >= cost.bytes > 0


class TestParsing:
    def test_trip_count_from_cond(self):
        x = jax.ShapeDtypeStruct((8, 8), np.float32)

        def f(x):
            def body(c, _):
                return c + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=13)
            return out

        mod = ha.HloModule(lower_text(f, x))
        whiles = [ins for comp in mod.computations.values() for ins in comp
                  if ins.opcode == "while"]
        assert whiles, "expected a while loop in the HLO"
        conds = mod._called(whiles[0], "condition")
        assert mod.trip_count(conds[0]) == 13

    def test_dot_flops_formula(self):
        a = jax.ShapeDtypeStruct((32, 48), np.float32)
        b = jax.ShapeDtypeStruct((48, 16), np.float32)
        cost = ha.analyze_hlo(lower_text(lambda a, b: a @ b, a, b))
        assert cost.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.05)

    def test_roofline_dominant_term(self):
        c = ha.Cost(flops=197e12, bytes=819e9 * 2, coll_bytes={
            k: 0.0 for k in ha.COLLECTIVES},
            coll_counts={k: 0.0 for k in ha.COLLECTIVES})
        r = ha.roofline_from_cost(c)
        assert r.dominant == "memory"
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(2.0)
