"""Dispatch-tile coarsening for row-parallel kernels.

The match kernels are row-elementwise: every program instance computes a
pure function of its row tile, so the *dispatch* tile (the BlockSpec row
count) is a free parameter as long as it divides the padded row count.
The public padding contracts stay at the fine tiles (``ROW_TILE`` = 8,
``FILTER_ROW_TILE`` = 128) -- callers pad to those -- but launching one
program per fine tile is ruinous at scale: a 1M-row corpus is 131072 grid
steps for the SWAR kernel, and per-step overhead (a few us on TPU, ~400us
in interpret mode) dominates the arithmetic.  Coarsening the dispatch
tile amortizes the launch: same ops per row, bit-identical output,
O(grid) overhead shrunk by the coarsening factor.

The tile grows by doubling (keeps divisibility trivially) until it stops
dividing the row count, exceeds the VMEM block budget, or hits the row
cap.  The VMEM budget is conservative: Mosaic double-buffers every
block, so we keep the *single-copy* footprint under ~2 MiB of the
~16 MiB/core (see /opt/skills/guides -- "assume ~16MB of VMEM").
"""

from __future__ import annotations

VMEM_BLOCK_BUDGET = 2 << 20   # bytes, single-copy footprint of all blocks
MAX_TILE_ROWS = 1 << 17       # diminishing returns past ~131K rows/program


def coarse_row_tile(n_rows: int, base_tile: int, row_bytes: int, *,
                    budget_bytes: int = VMEM_BLOCK_BUDGET,
                    max_rows: int = MAX_TILE_ROWS) -> int:
    """Largest power-of-two multiple of ``base_tile`` that divides
    ``n_rows`` and keeps ``tile * row_bytes`` within the VMEM budget.

    ``row_bytes`` is the per-row footprint of every row-tiled block the
    kernel touches (inputs + outputs).  Returns ``base_tile`` unchanged
    when nothing larger fits -- the fine tile is always legal.
    """
    tile = base_tile
    while (tile * 2 <= max_rows
           and n_rows % (tile * 2) == 0
           and tile * 2 * row_bytes <= budget_bytes):
        tile *= 2
    return tile
