"""Standing queries: device-resident pattern bank (DESIGN.md Sec. 3j).

Everything before this module treats patterns as transient and the corpus
as resident.  The temporal-correlation PCM work (Sebastian et al.,
PAPERS.md) runs the *inverted* regime -- a fixed set of resident detectors
scored against every arriving sample -- and the in-storage sparse pattern
processor (Jun et al.) shows a filter cascade is what makes that regime
affordable.  ``PatternBank`` is that inversion for the TPU engine:

* **Registration freezes.**  ``register`` normalizes any pattern spelling
  (IUPAC string, code array, 1-D ``MatchQuery``) through ``as_masks``,
  validates it against the bank geometry, and freezes it as a threshold
  ``MatchQuery`` -- the same IR an ad-hoc caller would compile, which is
  what the bit-identity tests compare against.  Each pattern carries an
  id, a threshold, an optional TTL and an optional hit callback.
* **Residency protocol.**  The bank owns the same device-residency
  discipline as ``PackedCorpus``: host buffers are the source of truth,
  device forms (accept-mask bit planes for the verify kernel; required-bit
  q-gram signatures + per-pattern slacks for the prefilter) pack lazily
  **once** (``plane_pack_count`` / ``sig_pack_count`` stay <= 1),
  ``register``/``unregister`` splice only the touched slots
  (``.at[].set``), and growth is capacity-reserved zero-extension.  Live
  patterns always occupy slots ``[0, n_live)``: ``unregister`` swap-moves
  the last live slot into the hole (<= 2 slot splices), so the verify
  operand is a plain slice, never a per-scan gather.
* **One fused launch per batch.**  ``scan`` scores an arriving document
  batch against every live pattern in a single ``match_swar_masks``
  dispatch with the roles swapped: the docs ride the row axis (the
  "corpus chunk"), the bank rides the pattern axis -- the engine's
  ``mode="batched"`` formulation exactly, so hits are bit-identical to
  compiling each pattern as an ad-hoc threshold query over the batch.
* **Pattern-side prefilter.**  The q-gram lemma read backwards: a doc
  admitting a qualifying alignment of pattern p contains all of that
  window's q-grams, so ``popcount(psig & ~docsig) > slack_p`` proves p
  cannot fire on it -- zero false negatives, same argument as
  ``CorpusIndex`` with rows and queries exchanged.  One
  ``bank_prefilter`` dispatch prunes the pattern axis for the whole
  batch; ``Planner.plan_bank`` prices prefilter-then-verify against the
  full bank scan through the calibrated cost source, with a bank-local
  measured-selectivity EWMA feeding the survivor estimate.

``MatchService`` drives the bank from ``ingest``: every batch is scanned
*before* it splices into the corpus, so a standing alert fires even when
the corpus runs as a sliding window that would evict the doc later.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.kernels import filter_qgram as _fq
from repro.kernels import match_swar as _swar
from repro.match import index as _idx
from repro.match.engine import _pack_mask_planes, _valid_mask, \
    default_interpret
from repro.match.feedback import EwmaRatio
from repro.match.planner import BankPlan, Planner, _swar_geometry
from repro.match.query import MatchQuery, as_masks
from repro.obs import NULL_OBS

# Hit array columns (HitTicket.hits): batch-local doc index, alignment
# location, pattern id, similarity score.
HIT_DOC, HIT_LOC, HIT_PATTERN, HIT_SCORE = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class StandingPattern:
    """One registered pattern's frozen metadata (the bank's slot record)."""

    pattern_id: int
    query: MatchQuery            # frozen threshold IR (ad-hoc equivalent)
    threshold: float
    deadline: float              # clock seconds; +inf = no TTL
    n_sig_bits: int              # distinct required signature bits
    slack: int                   # q-gram mismatch budget (< 0: unsat.)


@dataclasses.dataclass
class HitTicket:
    """Result of scanning one ingest batch against the bank.

    ``hits`` is (n, 4) int64 ``[doc, loc, pattern_id, score]`` in the
    engine's batched-threshold order (ascending doc, then loc, then the
    pattern's launch column) -- per pattern, identical to the ``hits`` of
    an ad-hoc threshold query over the same docs.  ``base_row`` anchors
    the batch: the service scans pre-splice, so doc ``d`` becomes corpus
    row ``base_row + d`` once appended.
    """

    n_docs: int
    base_row: Optional[int] = None
    hits: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 4), np.int64))
    plan: Optional[BankPlan] = None
    n_patterns: int = 0          # live bank slots at scan time
    n_verified: int = 0          # patterns that reached the verify launch
    survivor_frac: Optional[float] = None  # measured (None: no prefilter)
    n_bank_launches: int = 0     # verify dispatches this scan (0 or 1)
    wall_s: float = 0.0

    @property
    def corpus_rows(self) -> Optional[np.ndarray]:
        """Per-hit corpus row ids (None when the scan was unanchored)."""
        if self.base_row is None:
            return None
        return self.base_row + self.hits[:, HIT_DOC]

    def by_pattern(self) -> Dict[int, np.ndarray]:
        """Hits grouped per pattern id (insertion order = launch order)."""
        out: Dict[int, np.ndarray] = {}
        for pid in np.unique(self.hits[:, HIT_PATTERN]):
            out[int(pid)] = self.hits[self.hits[:, HIT_PATTERN] == pid]
        return out


class PatternBank:
    """Thousands of standing patterns, resident once, scanned per batch.

    ``fragment_chars`` / ``pattern_chars`` fix the launch geometry at
    construction (every registered pattern has the same length, like every
    corpus row has the same width); ``filter`` is the routing hint with
    ``MatchQuery.filter`` semantics (None: price it, True: force the
    prefilter whenever the bank is prunable, False: always full scan).
    ``clock`` injects time for TTL tests.
    """

    def __init__(self, fragment_chars: int, pattern_chars: int, *,
                 q: int = _idx.DEFAULT_Q, n_bits: int = _idx.DEFAULT_BITS,
                 capacity: int = 256, planner: Optional[Planner] = None,
                 filter: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 clock: Callable[[], float] = time.perf_counter):
        F, P = int(fragment_chars), int(pattern_chars)
        if P < 1:
            raise ValueError("pattern_chars must be >= 1")
        if F - P + 1 <= 0:
            raise ValueError(
                f"pattern_chars={P} longer than fragment_chars={F}")
        q = int(q)
        n_bits = int(n_bits)
        if q < 1 or q > 16:
            raise ValueError(f"q must be in [1, 16], got {q}")
        if n_bits < 32 or n_bits & (n_bits - 1):
            raise ValueError(
                f"n_bits must be a power of two >= 32, got {n_bits}")
        if filter is not None and not isinstance(filter, bool):
            raise ValueError("filter must be None, True or False")
        self.fragment_chars = F
        self.pattern_chars = P
        self.n_locs = F - P + 1
        self.q = q
        self.n_bits = n_bits
        self.sig_words = n_bits // 32
        self.filter = filter
        self.capacity = max(1, int(capacity))
        self.planner = planner or Planner()
        self.interpret = (default_interpret() if interpret is None
                          else interpret)
        self.clock = clock
        self.wp, self.need_words = _swar_geometry(P, self.n_locs)
        # Host source-of-truth buffers, dense over slots [0, n_live).
        self._masks = np.zeros((self.capacity, P), np.uint8)
        self._sig_host = np.zeros((self.capacity, self.sig_words), np.uint32)
        self._thr = np.zeros(self.capacity, np.float64)
        self._slack = np.full(self.capacity, -1, np.int64)
        self._nbits = np.zeros(self.capacity, np.int32)
        self._ids = np.full(self.capacity, -1, np.int64)
        self._deadline = np.full(self.capacity, np.inf, np.float64)
        self._slots: Dict[int, int] = {}          # pattern id -> slot
        self._patterns: Dict[int, StandingPattern] = {}
        self._callbacks: Dict[int, Callable] = {}
        self.n_live = 0
        self._next_id = 0
        # Device forms (lazy pack-once; splices keep them current).
        self._planes: Optional[jnp.ndarray] = None   # (cap, 4*Wp) uint32
        self._sigs: Optional[jnp.ndarray] = None     # (capF, Wb) uint32
        self._slacks_dev: Optional[jnp.ndarray] = None  # (capF, 1) int32
        self._valid = jnp.asarray(_valid_mask(P, self.wp))
        # Residency + scan counters (the invariants tests assert on).
        self.plane_pack_count = 0
        self.sig_pack_count = 0
        self.slot_update_count = 0
        self.generation = 0
        self.n_registered = 0
        self.n_expired = 0
        self.n_scans = 0
        self.n_bank_launches = 0
        self.n_prefilter_launches = 0
        self.n_hits = 0
        self.last_survivor_frac: Optional[float] = None
        self._hit_counts: Dict[int, int] = {}
        # Bank-local measured-selectivity calibration, same discipline as
        # CorpusIndex.record_selectivity (ratios against the uncalibrated
        # estimate; tight clamp against absorbing outliers).
        self._selectivity = EwmaRatio(decay=0.3, clamp=(0.1, 10.0))
        # Host pulls route through a ShardMerger for transfer accounting
        # (DESIGN.md Sec. 3k).  Bank forms are replicated bank-local
        # state (patterns + arriving docs, identical on every process),
        # so the default merger is a pass-through counter; a service
        # attaches its engine's merger so bank traffic lands in the same
        # ledger as the corpus reductions.
        from .merge import ShardMerger
        self.merger = ShardMerger(None, None, 1)
        # Observability handle: scan/prefilter/verify spans record here.
        # A MatchService replaces it with its engine's so bank activity
        # lands in the same trace as the corpus reductions.
        self.obs = NULL_OBS

    # -- geometry --------------------------------------------------------------
    @property
    def _cap_filter(self) -> int:
        """Filter-form slot count: capacity padded to the filter row tile."""
        tile = _fq.FILTER_ROW_TILE
        return -(-self.capacity // tile) * tile

    # -- registration ----------------------------------------------------------
    def register(self, pattern, *, threshold: float,
                 ttl_s: Optional[float] = None,
                 on_hit: Optional[Callable] = None) -> int:
        """Freeze one pattern into the bank; returns its pattern id.

        ``pattern`` is an IUPAC string, a uint8 code array, or a 1-D
        ``MatchQuery``; it must match the bank's ``pattern_chars``.
        ``on_hit(pattern_id, hits)`` fires from ``scan`` with that
        pattern's (n, 4) hit rows.  The new slot is spliced into the
        cached device forms; nothing repacks.
        """
        masks = as_masks(pattern)
        if masks.shape[0] != self.pattern_chars:
            raise ValueError(
                f"bank patterns are {self.pattern_chars} chars; got "
                f"{masks.shape[0]}")
        query = MatchQuery.from_masks(masks, reduction="threshold",
                                      threshold=float(threshold))
        fo = _idx.build_query_filter(masks[None, :], (float(threshold),),
                                     self.q, self.n_bits)
        if self.n_live == self.capacity:
            self.reserve(self.capacity * 2)
        slot = self.n_live
        pid = self._next_id
        self._next_id += 1
        deadline = (np.inf if ttl_s is None
                    else self.clock() + float(ttl_s))
        self._masks[slot] = masks
        self._sig_host[slot] = fo.qsig_words[0]
        self._thr[slot] = float(threshold)
        self._slack[slot] = fo.slacks[0]
        self._nbits[slot] = fo.n_bits[0]
        self._ids[slot] = pid
        self._deadline[slot] = deadline
        self._slots[pid] = slot
        self._patterns[pid] = StandingPattern(
            pattern_id=pid, query=query, threshold=float(threshold),
            deadline=float(deadline), n_sig_bits=int(fo.n_bits[0]),
            slack=int(fo.slacks[0]))
        if on_hit is not None:
            self._callbacks[pid] = on_hit
        self._splice_slot(slot)
        self.n_live += 1
        self.n_registered += 1
        self.generation += 1
        return pid

    def unregister(self, pattern_id: int) -> None:
        """Drop one pattern; the last live slot swap-fills the hole.

        Touches at most two slots on device (the hole and the cleared
        tail), keeping operands dense over ``[0, n_live)`` with flat pack
        counters -- the splice discipline of ``PackedCorpus.set_rows``.
        """
        slot = self._slots.pop(int(pattern_id), None)
        if slot is None:
            raise ValueError(f"unknown pattern id {pattern_id}")
        self._patterns.pop(int(pattern_id))
        self._callbacks.pop(int(pattern_id), None)
        last = self.n_live - 1
        if slot != last:
            for buf in (self._masks, self._sig_host, self._thr,
                        self._slack, self._nbits, self._ids,
                        self._deadline):
                buf[slot] = buf[last]
            self._slots[int(self._ids[slot])] = slot
            self._splice_slot(slot)
        # Clear the vacated tail slot: the verify operand slices
        # [:n_live] so stale planes there are unreachable, but the
        # prefilter scans padded slots -- slack -1 guarantees they never
        # survive.
        self._masks[last] = 0
        self._sig_host[last] = 0
        self._thr[last] = 0.0
        self._slack[last] = -1
        self._nbits[last] = 0
        self._ids[last] = -1
        self._deadline[last] = np.inf
        if self._slacks_dev is not None:
            self._slacks_dev = self._slacks_dev.at[last, 0].set(-1)
            self.slot_update_count += 1
        self.n_live -= 1
        self.generation += 1

    def expire(self, now: Optional[float] = None) -> List[int]:
        """Unregister every pattern whose TTL deadline has passed."""
        now = self.clock() if now is None else float(now)
        stale = [int(pid) for pid in self._ids[:self.n_live]
                 if self._deadline[self._slots[int(pid)]] <= now]
        for pid in stale:
            self.unregister(pid)
        self.n_expired += len(stale)
        return stale

    def reserve(self, capacity: int) -> None:
        """Grow slot capacity in place; device forms zero-extend.

        Like ``PackedCorpus.reserve``: no repack (pack counters flat), new
        filter slots carry slack -1 so they can never survive the
        prefilter.
        """
        capacity = int(capacity)
        if capacity <= self.capacity:
            return
        grow = capacity - self.capacity
        old_capf = self._cap_filter
        self._masks = np.concatenate(
            [self._masks, np.zeros((grow, self.pattern_chars), np.uint8)])
        self._sig_host = np.concatenate(
            [self._sig_host, np.zeros((grow, self.sig_words), np.uint32)])
        self._thr = np.concatenate([self._thr, np.zeros(grow)])
        self._slack = np.concatenate(
            [self._slack, np.full(grow, -1, np.int64)])
        self._nbits = np.concatenate(
            [self._nbits, np.zeros(grow, np.int32)])
        self._ids = np.concatenate([self._ids, np.full(grow, -1, np.int64)])
        self._deadline = np.concatenate(
            [self._deadline, np.full(grow, np.inf)])
        self.capacity = capacity
        if self._planes is not None:
            self._planes = jnp.concatenate(
                [self._planes,
                 jnp.zeros((grow, 4 * self.wp), jnp.uint32)], 0)
        capf = self._cap_filter
        if capf > old_capf and self._sigs is not None:
            pad = capf - old_capf
            self._sigs = jnp.concatenate(
                [self._sigs, jnp.zeros((pad, self.sig_words), jnp.uint32)],
                0)
            self._slacks_dev = jnp.concatenate(
                [self._slacks_dev,
                 jnp.full((pad, 1), -1, jnp.int32)], 0)

    def pattern(self, pattern_id: int) -> StandingPattern:
        """Frozen record for one live pattern (raises if unknown)."""
        try:
            return self._patterns[int(pattern_id)]
        except KeyError:
            raise ValueError(f"unknown pattern id {pattern_id}") from None

    def live_ids(self) -> np.ndarray:
        """(n_live,) pattern ids in slot order (the launch column order)."""
        return np.array(self._ids[:self.n_live])

    # -- device residency ------------------------------------------------------
    def _splice_slot(self, slot: int) -> None:
        """Write one slot's host row into every cached device form."""
        touched = False
        if self._planes is not None:
            planes, _ = _pack_mask_planes(self._masks[slot][None, :],
                                          self.wp)
            self._planes = self._planes.at[slot, :].set(
                jnp.asarray(planes[0]))
            touched = True
        if self._sigs is not None:
            self._sigs = self._sigs.at[slot, :].set(
                jnp.asarray(self._sig_host[slot]))
            self._slacks_dev = self._slacks_dev.at[slot, 0].set(
                int(self._slack[slot]))
            touched = True
        if touched:
            self.slot_update_count += 1

    def planes(self) -> jnp.ndarray:
        """(capacity, 4*Wp) uint32 verify operand, packed at most once."""
        if self._planes is None:
            planes = np.zeros((self.capacity, 4 * self.wp), np.uint32)
            if self.n_live:
                live, _ = _pack_mask_planes(self._masks[:self.n_live],
                                            self.wp)
                planes[:self.n_live] = live
            self._planes = jnp.asarray(planes)
            self.plane_pack_count += 1
        return self._planes

    def filter_operands(self) -> tuple:
        """((capF, Wb) signatures, (capF, 1) slacks), packed at most once."""
        if self._sigs is None:
            capf = self._cap_filter
            sigs = np.zeros((capf, self.sig_words), np.uint32)
            sigs[:self.capacity] = self._sig_host
            slacks = np.full((capf, 1), -1, np.int32)
            slacks[:self.capacity, 0] = np.clip(
                self._slack, -1, np.iinfo(np.int32).max)
            self._sigs = jnp.asarray(sigs)
            self._slacks_dev = jnp.asarray(slacks)
            self.sig_pack_count += 1
        return self._sigs, self._slacks_dev

    # -- selectivity model -----------------------------------------------------
    @property
    def prunable(self) -> bool:
        """True iff the prefilter can exclude at least one live pattern."""
        n = self.n_live
        return bool(n and (self._slack[:n] < self._nbits[:n]).any())

    def estimate_survivor_frac(self, *, calibrated: bool = True) -> float:
        """Estimated fraction of live patterns surviving one doc batch.

        Per pattern: P(#absent required bits <= slack) against a document
        modeled at the analytic occupancy density (the bank never indexes
        the transient docs, so there is no measured density to use) --
        mean over patterns, not the corpus filter's union-over-queries
        (each pattern survives or dies independently).  ``calibrated``
        folds in the bank-local measured EWMA, recorded against the
        uncalibrated estimate like ``CorpusIndex``.
        """
        n = self.n_live
        if not n:
            return 0.0
        d = _idx.expected_density(self.fragment_chars, self.q, self.n_bits)
        total = sum(_idx.pass_probability(int(self._nbits[i]),
                                          int(self._slack[i]), d)
                    for i in range(n))
        frac = total / n
        if calibrated and self._selectivity.value is not None:
            frac *= self._selectivity.value
        return float(min(1.0, frac))

    # -- the scan --------------------------------------------------------------
    def scan(self, docs: np.ndarray, *, base_row: Optional[int] = None
             ) -> HitTicket:
        """Score one arriving batch against every live pattern.

        One fused ``match_swar_masks`` launch regardless of bank size
        (``n_bank_launches`` increments by exactly one), optionally
        preceded by one ``bank_prefilter`` dispatch when the planner
        prices the two-stage path cheaper.  Empty batches and empty banks
        launch nothing.
        """
        t0 = time.perf_counter()
        docs = np.asarray(docs, np.uint8)
        if docs.ndim == 1:
            docs = docs[None, :]
        if docs.ndim != 2 or docs.shape[1] != self.fragment_chars:
            raise ValueError(
                f"docs must be (n, {self.fragment_chars}); got "
                f"{docs.shape}")
        D = docs.shape[0]
        ticket = HitTicket(n_docs=D, base_row=base_row,
                           n_patterns=self.n_live)
        if D == 0 or self.n_live == 0:
            return ticket
        self.n_scans += 1
        tr = self.obs.tracer
        with tr.span("bank.scan",
                     {"n_docs": D, "n_patterns": self.n_live}
                     if tr.enabled else None):
            with tr.span("plan") as sp_plan:
                plan = self.planner.plan_bank(
                    n_docs=D, fragment_chars=self.fragment_chars,
                    pattern_chars=self.pattern_chars,
                    n_patterns=self.n_live, sig_words=self.sig_words,
                    survivor_frac=self.estimate_survivor_frac(),
                    prunable=self.prunable, force=self.filter)
                if tr.enabled:
                    sp_plan.set("strategy", plan.strategy)
                    sp_plan.set("est_seconds", plan.est_seconds)
            ticket.plan = plan
            slots = np.arange(self.n_live, dtype=np.int64)
            if plan.strategy == "filter":
                with tr.span("filter",
                             {"op": "bank_prefilter"}
                             if tr.enabled else None) as sp_fil:
                    slots = self._prefilter(docs)
                    ticket.survivor_frac = len(slots) / self.n_live
                    if tr.enabled:
                        sp_fil.set("survivor_frac", ticket.survivor_frac)
            ticket.n_verified = len(slots)
            if len(slots):
                with tr.span("launch",
                             {"op": "bank_verify", "n_verified": len(slots)}
                             if tr.enabled else None):
                    hits = self._verify(docs, slots)
                ticket.n_bank_launches = 1
                ticket.hits = hits
                self.n_hits += hits.shape[0]
                self._deliver(hits)
        ticket.wall_s = time.perf_counter() - t0
        return ticket

    def _prefilter(self, docs: np.ndarray) -> np.ndarray:
        """One ``bank_prefilter`` dispatch -> surviving live slot ids."""
        doc_sigs, _ = _idx.row_signatures(docs, self.q, self.n_bits)
        d_pad = -(-doc_sigs.shape[0] // _swar.ROW_TILE) * _swar.ROW_TILE
        if d_pad > doc_sigs.shape[0]:
            # All-zero pad docs admit only patterns with slack >= their
            # required bits -- patterns that survive any real doc too, so
            # padding never changes the survivor set.
            doc_sigs = np.concatenate(
                [doc_sigs, np.zeros((d_pad - doc_sigs.shape[0],
                                     self.sig_words), np.uint32)])
        sigs, slacks = self.filter_operands()
        flags = self.merger.pull(_fq.bank_prefilter(
            sigs, jnp.asarray(doc_sigs), slacks,
            interpret=self.interpret))[:, 0]
        self.n_prefilter_launches += 1
        survivors = np.flatnonzero(flags[:self.n_live]).astype(np.int64)
        measured = len(survivors) / self.n_live
        self._selectivity.update(
            measured / max(self.estimate_survivor_frac(calibrated=False),
                           1e-9))
        self.last_survivor_frac = measured
        return survivors

    def _verify(self, docs: np.ndarray, slots: np.ndarray) -> np.ndarray:
        """One fused roles-swapped batched launch -> (n, 4) hit rows.

        The engine's ``mode="batched"`` execution verbatim: tile the doc
        words per pattern, repeat each pattern's planes per doc row, one
        ``match_swar_masks`` dispatch, reshape to (docs, locs, patterns).
        Threshold hits come out of the same ``argwhere`` the engine runs,
        so per-pattern hit streams are bit-identical to ad-hoc compiles.
        """
        D = docs.shape[0]
        Qs = len(slots)
        d_pad = -(-D // _swar.ROW_TILE) * _swar.ROW_TILE
        words = encoding.pack_codes_u32(docs)
        padded = np.zeros((d_pad, self.need_words), np.uint32)
        w = min(words.shape[1], self.need_words)
        padded[:D, :w] = words[:, :w]
        planes_all = self.planes()
        if Qs == self.n_live:
            planes_sel = planes_all[:self.n_live]   # dense slice, no gather
        else:
            planes_sel = planes_all[jnp.asarray(slots)]
        words_t = jnp.tile(jnp.asarray(padded), (Qs, 1))
        planes_t = jnp.repeat(planes_sel, d_pad, axis=0)
        out = _swar.match_swar_masks(
            words_t, planes_t, self._valid, n_locs=self.n_locs,
            pattern_chars=self.pattern_chars, interpret=self.interpret)
        self.n_bank_launches += 1
        sc = self.merger.pull(out, kind="block").reshape(
            Qs, d_pad, self.n_locs).transpose(1, 2, 0)[:D]
        thr = self._thr[slots]
        local = np.argwhere(sc >= thr[None, None, :])
        if not local.size:
            return np.zeros((0, 4), np.int64)
        vals = sc[tuple(local.T)]
        pids = self._ids[slots[local[:, 2]]]
        return np.column_stack([local[:, 0], local[:, 1], pids,
                                vals]).astype(np.int64)

    def _deliver(self, hits: np.ndarray) -> None:
        """Per-pattern hit accounting + callback dispatch."""
        for pid in np.unique(hits[:, HIT_PATTERN]):
            pid = int(pid)
            mine = hits[hits[:, HIT_PATTERN] == pid]
            self._hit_counts[pid] = (self._hit_counts.get(pid, 0)
                                     + mine.shape[0])
            cb = self._callbacks.get(pid)
            if cb is not None:
                cb(pid, mine)

    # -- stats -----------------------------------------------------------------
    def hit_counts(self) -> Dict[int, int]:
        """Cumulative per-pattern hit counts (live and expired patterns)."""
        return dict(self._hit_counts)

    def stats(self) -> dict:
        return {
            "n_live": self.n_live,
            "capacity": self.capacity,
            "n_registered": self.n_registered,
            "n_expired": self.n_expired,
            "generation": self.generation,
            "q": self.q,
            "n_bits": self.n_bits,
            "plane_pack_count": self.plane_pack_count,
            "sig_pack_count": self.sig_pack_count,
            "slot_update_count": self.slot_update_count,
            "n_scans": self.n_scans,
            "n_bank_launches": self.n_bank_launches,
            "n_prefilter_launches": self.n_prefilter_launches,
            "n_hits": self.n_hits,
            "last_survivor_frac": self.last_survivor_frac,
            "calibration": (None if self._selectivity.value is None
                            else round(self._selectivity.value, 4)),
            "hits_by_pattern": self.hit_counts(),
        }
