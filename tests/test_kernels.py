"""Pallas kernel tests: deterministic shape sweeps vs the jnp oracles.

All kernels run in interpret=True mode on CPU (the kernel body executes in
Python); integer paths must be bit-exact, the bf16 MXU path exact after
rounding (one-hot dot products are small integers, exactly representable).

Randomized property tests live in ``test_kernels_properties.py`` (skipped
when ``hypothesis`` is absent, so a missing dev dep never takes down the
deterministic coverage here).
"""

import numpy as np
import pytest

from repro.core.matcher import sliding_scores
from repro.kernels import ops
from repro.kernels import ref as kref


RNG = np.random.default_rng(1234)


def random_case(r, f, p, per_row=False, q=None, seed=0):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (r, f), np.uint8)
    if q is not None:
        pats = rng.integers(0, 4, (q, p), np.uint8)
    elif per_row:
        pats = rng.integers(0, 4, (r, p), np.uint8)
    else:
        pats = rng.integers(0, 4, p, np.uint8)
    return frags, pats


class TestMatchSwar:
    @pytest.mark.parametrize("r,f,p", [
        (1, 20, 5), (3, 33, 16), (8, 64, 17), (10, 300, 100),
        (5, 128, 1), (2, 40, 32), (7, 257, 31), (16, 2000, 100),
    ])
    def test_shape_sweep_shared_pattern(self, r, f, p):
        frags, pat = random_case(r, f, p, seed=r * f + p)
        got = np.asarray(ops.match_scores(frags, pat, method="swar"))
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    @pytest.mark.parametrize("r,f,p", [(4, 50, 10), (9, 120, 48)])
    def test_per_row_patterns(self, r, f, p):
        frags, pats = random_case(r, f, p, per_row=True, seed=7)
        got = np.asarray(ops.match_scores(frags, pats, method="swar"))
        np.testing.assert_array_equal(got, sliding_scores(frags, pats))

    def test_word_boundary_alignments(self):
        """Alignments crossing uint32 word boundaries (loc % 16 != 0)."""
        rng = np.random.default_rng(3)
        frags = rng.integers(0, 4, (2, 64), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        for loc in (0, 1, 15, 16, 17, 31, 48):
            frags[1, loc:loc + 16] = pat
            got = np.asarray(ops.match_scores(frags, pat, method="swar"))
            assert got[1, loc] == 16, loc


class TestMatchMXU:
    @pytest.mark.parametrize("r,f,p,q", [
        (2, 40, 8, 1), (3, 100, 33, 4), (5, 300, 100, 3),
        (1, 64, 32, 130), (4, 600, 100, 8),
    ])
    def test_shape_sweep_batched(self, r, f, p, q):
        frags, pats = random_case(r, f, p, q=q, seed=r + f + p + q)
        got = np.asarray(ops.match_scores(frags, pats, method="mxu"))
        want = np.stack(
            [sliding_scores(frags, pats[i]) for i in range(q)], -1)
        np.testing.assert_array_equal(got, want)

    def test_shared_pattern_path(self):
        frags, pat = random_case(4, 80, 20, seed=11)
        got = np.asarray(ops.match_scores(frags, pat, method="mxu"))
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    def test_onehot_oracle_agrees_with_char_oracle(self):
        frags, pats = random_case(3, 50, 10, q=4, seed=5)
        a = np.asarray(kref.onehot_scores_ref(frags, pats))
        want = np.stack(
            [sliding_scores(frags, pats[i]) for i in range(4)], -1)
        np.testing.assert_array_equal(a, want)


class TestPopcount:
    @pytest.mark.parametrize("n,w", [(1, 1), (5, 3), (300, 7), (1000, 1)])
    def test_shape_sweep(self, n, w):
        rng = np.random.default_rng(n * w)
        words = rng.integers(0, 2**32, (n, w), dtype=np.uint64).astype(np.uint32)
        got = np.asarray(ops.popcount(words))
        want = np.array([sum(bin(int(v)).count("1") for v in row)
                         for row in words], np.int32)
        np.testing.assert_array_equal(got, want)

    def test_edge_values(self):
        words = np.array([[0], [0xFFFFFFFF], [0x55555555], [0x80000001]],
                         np.uint32)
        np.testing.assert_array_equal(
            np.asarray(ops.popcount(words)), [0, 32, 16, 2])


class TestBitwise:
    @pytest.mark.parametrize("op", ops._bitwise.OPS)
    @pytest.mark.parametrize("n,w", [(4, 2), (300, 5)])
    def test_ops_sweep(self, op, n, w):
        rng = np.random.default_rng(hash(op) % 2**31 + n)
        a = rng.integers(0, 2**32, (n, w), dtype=np.uint64).astype(np.uint32)
        b = rng.integers(0, 2**32, (n, w), dtype=np.uint64).astype(np.uint32)
        got = np.asarray(ops.bitwise(op, a, b))
        want = np.asarray(kref.bitwise_ref(op, a, b))
        np.testing.assert_array_equal(got, want)

    def test_rc4_roundtrip(self):
        """RC4 semantics: XOR with keystream twice restores plaintext."""
        rng = np.random.default_rng(0)
        text = rng.integers(0, 2**32, (128, 8), dtype=np.uint64).astype(np.uint32)
        key = rng.integers(0, 2**32, (128, 8), dtype=np.uint64).astype(np.uint32)
        cipher = np.asarray(ops.bitwise("XOR", text, key))
        plain = np.asarray(ops.bitwise("XOR", cipher, key))
        np.testing.assert_array_equal(plain, text)


class TestCrossValidation:
    def test_swar_ref_mirror(self):
        """The packed jnp mirror (ref.match_scores_swar_ref) agrees with the
        Pallas kernel bit for bit (same packed semantics)."""
        from repro.core import encoding
        rng = np.random.default_rng(9)
        frags = rng.integers(0, 4, (8, 70), np.uint8)
        pat = rng.integers(0, 4, 20, np.uint8)
        P, L = 20, 51
        wp = 2
        rw = encoding.pack_codes_u32(frags)
        need = (L - 1) // 16 + wp + 1
        rw = np.concatenate([rw, np.zeros((8, need - rw.shape[1]), np.uint32)], 1)
        pw = encoding.pack_codes_u32(np.broadcast_to(pat, (8, P)))
        mask_codes = np.zeros(wp * 16, np.uint32)
        mask_codes[:P] = 1
        mask = encoding.pack_codes_u32(mask_codes[None, :])
        mirror = np.asarray(kref.match_scores_swar_ref(
            rw, pw, mask[0], n_locs=L, pattern_chars=P))
        kernel = np.asarray(ops.match_scores(frags, pat, method="swar"))
        np.testing.assert_array_equal(mirror, kernel)

    def test_matcher_cram_vs_kernels(self):
        """End-to-end: CRAM array simulation == TPU fast path == oracle."""
        from repro.core.matcher import Matcher
        rng = np.random.default_rng(21)
        frags = rng.integers(0, 4, (8, 30), np.uint8)
        pat = rng.integers(0, 4, 7, np.uint8)
        m = Matcher(frags, pattern_chars=7)
        m.load_pattern(pat)
        cram = m.run()
        swar = np.asarray(ops.match_scores(frags, pat, method="swar"))
        np.testing.assert_array_equal(cram, swar)
