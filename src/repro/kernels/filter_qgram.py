"""Q-gram signature filter -- Pallas TPU kernel (DESIGN.md Sec. 3g).

Stage one of the filter-then-verify pipeline: the corpus index
(``repro.match.index``) keeps one B-bit q-gram occurrence signature per
corpus row, packed as uint32 words; a query lowers to a signature of the
q-grams it *requires* (q-grams spanning wildcard/ambiguity positions are
dropped, so the requirement is conservative).  This kernel scans the row
signatures and emits a candidate-row bitmap:

    absent(r)    = popcount(query_sig & ~row_sig(r))
    candidate(r) = absent(r) <= slack

``slack`` encodes the q-gram lemma: an alignment with at most ``e``
mismatches destroys at most ``e * q`` of the pattern's fully-determined
q-grams, and every absent signature bit witnesses >= 1 destroyed q-gram --
so a row whose absent count exceeds ``e * q`` cannot contain a qualifying
alignment.  Zero false negatives by construction; collisions of the
signature hash only ever *add* candidates.

This is the in-storage sparse-filter discipline (Jun et al.: prune with a
cheap bulk filter where the data live, verify the survivors exactly): the
kernel touches ``W_b`` words per row instead of the ``L x Wp`` words per
row the exact scan reads, which is what makes selective queries cheap at
scale.

Data layout:
  row_sigs (R, Wb) uint32 -- per-row q-gram signatures, rows padded to
                             ``FILTER_ROW_TILE`` (padding rows are all-zero
                             and sliced off by the caller).
  qsig     (1, Wb) uint32 -- the query's required-bit signature.
  out      (R, 1)  int32  -- 1 iff the row is a candidate.

The row tile is much larger than the match kernels' (128 vs 8): the
per-row work is a handful of word ops, so the grid must be coarse for the
launch not to dominate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.popcount import popcount_words
from repro.kernels.tiling import coarse_row_tile

FILTER_ROW_TILE = 128


def _filter_kernel(sig_ref, qsig_ref, out_ref, *, slack: int):
    sigs = sig_ref[...]                      # (TILE, Wb)
    qsig = qsig_ref[...]                     # (1, Wb)
    # Full SWAR popcount per word (absent bits are arbitrary, unlike the
    # match kernels' <=1-bit-per-lane fast path).
    counts = popcount_words(qsig & ~sigs).sum(axis=-1, keepdims=True)
    out_ref[...] = (counts <= slack).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("slack", "interpret"))
def filter_qgram(row_sigs: jnp.ndarray, qsig: jnp.ndarray, *, slack: int,
                 interpret: bool = False) -> jnp.ndarray:
    """Candidate-row bitmap: see module docstring for layouts.

    ``slack`` is static: it is query geometry (``e * q``), one compile per
    distinct value, like ``pattern_chars`` in the match kernels.  A
    negative slack is legal and marks no row (the query's threshold is
    unsatisfiable).
    """
    R, Wb = row_sigs.shape
    if R % FILTER_ROW_TILE:
        raise ValueError(
            f"rows must be padded to a multiple of {FILTER_ROW_TILE}")
    if qsig.shape != (1, Wb):
        raise ValueError(f"qsig must be (1, {Wb}); got {qsig.shape}")
    # Row-elementwise body: coarsen the dispatch tile (kernels.tiling) so
    # launch overhead amortizes at scale; output is bit-identical.
    tile = coarse_row_tile(R, FILTER_ROW_TILE, (Wb + 1) * 4)
    grid = (R // tile,)
    kernel = functools.partial(_filter_kernel, slack=int(slack))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, Wb), lambda i: (i, 0)),
            pl.BlockSpec((1, Wb), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.int32),
        interpret=interpret,
    )(row_sigs, qsig)


def filter_qgram_ref(row_sigs: np.ndarray, qsig: np.ndarray,
                     slack: int) -> np.ndarray:
    """NumPy oracle for the filter kernel ((R,) int32 candidate flags)."""
    absent = np.asarray(qsig, np.uint32) & ~np.asarray(row_sigs, np.uint32)
    bytes_ = absent.view(np.uint8).reshape(absent.shape[0], -1)
    counts = np.unpackbits(bytes_, axis=1).sum(1).astype(np.int64)
    return (counts <= slack).astype(np.int32)


# -- pattern-bank prefilter (standing queries, DESIGN.md Sec. 3j) -------------
#
# The inverted regime swaps the roles: the *patterns* are the resident
# axis (thousands of standing queries in a PatternBank) and the arriving
# document batch is the transient side.  One dispatch answers, for every
# pattern at once, "can this pattern possibly fire on any document of the
# batch?" -- the corpus filter's q-gram lemma read backwards: a document
# that contains a qualifying alignment of pattern p contains all of that
# window's q-grams, so every *required* signature bit of p absent from
# the document's occurrence signature witnesses a destroyed q-gram, and
# ``popcount(psig & ~docsig) > slack_p`` proves p cannot fire on it.
# Per-pattern slacks ride as a dynamic operand (unlike the corpus
# filter's static slack: the bank mixes thresholds freely and must not
# recompile per distinct value).

def _bank_kernel(psig_ref, dsig_ref, slack_ref, out_ref):
    psigs = psig_ref[...]                    # (TILE, Wb) required bits
    dsigs = dsig_ref[...]                    # (D, Wb) doc occurrence sigs
    slacks = slack_ref[...]                  # (TILE, 1) per-pattern budget
    absent = popcount_words(
        psigs[:, None, :] & ~dsigs[None, :, :]).sum(axis=-1)  # (TILE, D)
    out_ref[...] = (absent <= slacks).any(axis=1,
                                          keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bank_prefilter(pat_sigs: jnp.ndarray, doc_sigs: jnp.ndarray,
                   slacks: jnp.ndarray, *,
                   interpret: bool = False) -> jnp.ndarray:
    """Surviving-pattern bitmap for one document batch.

    pat_sigs (Q, Wb) uint32 -- per-pattern required-bit signatures, rows
                               padded to ``FILTER_ROW_TILE`` (pad rows
                               carry slack -1 and never survive).
    doc_sigs (D, Wb) uint32 -- per-document occurrence signatures (all-
                               zero pad docs admit only unprunable
                               patterns, which survive regardless).
    slacks   (Q, 1)  int32  -- per-pattern mismatch budgets e*q
                               (negative: unsatisfiable, never fires).
    out      (Q, 1)  int32  -- 1 iff some document admits the pattern.
    """
    Q, Wb = pat_sigs.shape
    D = doc_sigs.shape[0]
    if Q % FILTER_ROW_TILE:
        raise ValueError(
            f"patterns must be padded to a multiple of {FILTER_ROW_TILE}")
    if doc_sigs.shape[1] != Wb:
        raise ValueError(f"doc_sigs must be (D, {Wb}); got "
                         f"{doc_sigs.shape}")
    if slacks.shape != (Q, 1):
        raise ValueError(f"slacks must be ({Q}, 1); got {slacks.shape}")
    # Per-pattern-row footprint includes the (TILE, D, Wb) popcount
    # temporary, so the coarsening budget sees D * Wb words per row.
    tile = coarse_row_tile(Q, FILTER_ROW_TILE, (Wb * (D + 1) + D + 2) * 4)
    grid = (Q // tile,)
    return pl.pallas_call(
        _bank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, Wb), lambda i: (i, 0)),
            pl.BlockSpec((D, Wb), lambda i: (0, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        interpret=interpret,
    )(pat_sigs, doc_sigs, slacks)


def bank_prefilter_ref(pat_sigs: np.ndarray, doc_sigs: np.ndarray,
                       slacks: np.ndarray) -> np.ndarray:
    """NumPy oracle for ``bank_prefilter`` ((Q,) int32 survivor flags)."""
    ps = np.asarray(pat_sigs, np.uint32)[:, None, :]
    ds = np.asarray(doc_sigs, np.uint32)[None, :, :]
    absent = ps & ~ds                                # (Q, D, Wb)
    bytes_ = absent.view(np.uint8).reshape(
        absent.shape[0], absent.shape[1], -1)
    counts = np.unpackbits(bytes_, axis=2).sum(2).astype(np.int64)
    return (counts <= np.asarray(slacks).reshape(-1, 1)).any(1).astype(
        np.int32)
