"""CRAM-PM core: the paper's contribution as a composable library.

Layers (bottom-up): device/tech model -> analog gate model -> array
interpreter -> ISA/codegen -> matcher (Algorithm 1) -> scheduling -> cost
model.  See DESIGN.md for the full inventory.
"""

from . import array, costmodel, encoding, gates, isa, matcher, scheduler, tech

__all__ = [
    "array", "costmodel", "encoding", "gates", "isa", "matcher",
    "scheduler", "tech",
]
