"""Cost-model monotonicity properties (hypothesis-driven; DESIGN.md 3i).

Split out behind ``importorskip`` so a missing ``hypothesis`` install
skips only this module (repo convention, see
``test_kernels_properties.py``).

Properties:

* every ``Planner.*_seconds`` estimate is monotone non-decreasing in R,
  Q, and P (holding L fixed) under BOTH cost sources -- for the static
  model this is the roofline arithmetic, for a calibrated source it is
  the positivity clamps on the fitted curve (alpha > 0, beta >= 0), and
  it must hold for ANY such curve, not just the fitted ones, or a noisy
  calibration could make the planner prefer *more* work;
* table persistence round-trips: for ANY positive curve set,
  save -> load gives the identical digest and identical plan decisions
  on the golden shape matrix.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.tech import (CalibratedCostSource, KernelCurve,  # noqa: E402
                             StaticCostSource)
from repro.match.calibrate import (KERNELS, CalibrationTable,  # noqa: E402
                                   golden_decisions)
from repro.match.planner import Planner  # noqa: E402

# Decision-scale alphas/betas: overhead factors from ~ideal (1) to
# interpret-mode (1e5), intercepts up to 10ms.
curve_st = st.builds(
    KernelCurve,
    alpha=st.floats(1e-2, 1e5, allow_nan=False, allow_infinity=False),
    beta=st.floats(0.0, 1e-2, allow_nan=False, allow_infinity=False))

curves_st = st.fixed_dictionaries({k: curve_st for k in KERNELS})

source_st = st.one_of(
    st.just(StaticCostSource()),
    st.builds(lambda curves: CalibratedCostSource(curves, digest="ab" * 16),
              curves_st))


def _prices(planner, R, L, P, Q, pred):
    return (planner.swar_seconds(R, L, P, Q, pred),
            planner.mxu_seconds(R, L, P, Q),
            planner.ref_seconds(R, L, P, Q),
            planner.filter_seconds(R, max(1, P // 4), Q))


class TestMonotonicity:
    @settings(max_examples=80, deadline=None)
    @given(source_st,
           st.integers(1, 1 << 20), st.integers(0, 1 << 20),
           st.integers(1, 4096),
           st.integers(1, 512), st.integers(0, 512),
           st.integers(1, 256), st.integers(0, 256),
           st.sampled_from(["exact", "accept"]))
    def test_seconds_monotone_in_R_P_Q(self, source, R, dR, L, P, dP, Q,
                                       dQ, pred):
        p = Planner(cost_source=source)
        base = _prices(p, R, L, P, Q, pred)
        for grown, label in ((_prices(p, R + dR, L, P, Q, pred), "R"),
                             (_prices(p, R, L, P + dP, Q, pred), "P"),
                             (_prices(p, R, L, P, Q + dQ, pred), "Q")):
            for b, g, fn in zip(base, grown,
                                ("swar", "mxu", "ref", "filter")):
                assert g >= b * (1.0 - 1e-9), \
                    f"{fn}_seconds decreased as {label} grew: {b} -> {g}"

    @settings(max_examples=40, deadline=None)
    @given(source_st, st.integers(1, 1 << 20), st.integers(1, 4096),
           st.integers(1, 512), st.integers(1, 256))
    def test_seconds_positive(self, source, R, L, P, Q):
        p = Planner(cost_source=source)
        assert all(s > 0.0 for s in _prices(p, R, L, P, Q, "exact"))


class TestPersistenceRoundtrip:
    @settings(max_examples=25, deadline=None)
    @given(curves=curves_st)
    def test_roundtrip_identical_golden_decisions(self, curves):
        # Serialize through the actual on-disk JSON format (the
        # filesystem half is covered in test_match_calibrate.py).
        import json

        table = CalibrationTable(device_kind="cpu", backend="cpu",
                                 interpret=True, curves=curves)
        loaded = CalibrationTable.from_json(
            json.loads(json.dumps(table.to_json())))
        assert loaded.digest == table.digest
        assert golden_decisions(loaded.cost_source()) == \
            golden_decisions(table.cost_source())
