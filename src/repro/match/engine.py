"""Sharded streaming match executor (DESIGN.md Sec. 3c).

Single entry point for all string-matching workloads: owns a
``PackedCorpus`` (device-resident, packed once), asks the ``Planner`` for a
kernel + geometry, then streams corpus row-chunks through the chosen Pallas
kernel with a fused per-chunk reduction, so the full (R, L, Q) score tensor
is never materialized unless explicitly requested.

Reductions (fused per chunk):
  best      -- per-row argmax over alignments (the paper's host extract,
               Sec. 3.2): (R,[Q]) locs + scores.
  topk      -- global top-k rows by best score (running merge across
               chunks): which corpus rows match best.
  threshold -- all (row, loc[, q]) hits with score >= threshold.
  full      -- materialized score tensor (small problems / compat path).

Sharding: with a ``jax.sharding.Mesh`` the corpus rows distribute over the
mesh axes mapped by the ``rows`` logical axis (``distributed.sharding``),
and each chunk executes under ``shard_map`` -- rows are embarrassingly
parallel, the direct analogue of the paper's array-level parallelism
(Sec. 3.4: arrays compute independently, the host merges scores).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.core import encoding
from repro.distributed import sharding as _sharding
from repro.kernels import match_mxu as _mxu
from repro.kernels import match_swar as _swar
from repro.kernels import ref as _kref

from .corpus import PackedCorpus
from .planner import Plan, Planner


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclasses.dataclass
class MatchResult:
    """Outcome of one engine query (reduced unless ``scores`` requested)."""

    plan: Plan
    best_locs: np.ndarray                 # (R,) or (R, Q) int
    best_scores: np.ndarray               # (R,) or (R, Q) int32
    scores: Optional[np.ndarray] = None   # (R, L[, Q]) when reduction="full"
    topk_rows: Optional[np.ndarray] = None     # (k,[Q]) best-matching rows
    topk_scores: Optional[np.ndarray] = None
    hits: Optional[np.ndarray] = None     # (n, 3|4): row, loc[, q], score
    n_chunks: int = 0


def _pack_pattern_swar(patterns: np.ndarray, wp: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-pack (tiny) pattern words + valid mask for the SWAR kernel."""
    P = patterns.shape[-1]
    pat_words = encoding.pack_codes_u32(patterns)
    mask_codes = np.zeros(wp * 16, np.uint32)
    mask_codes[:P] = 1
    valid_mask = encoding.pack_codes_u32(mask_codes[None, :])
    return pat_words, valid_mask


def _pack_patterns_mxu(patterns: np.ndarray, p_chars: int, q_pad: int
                       ) -> np.ndarray:
    """Host-pack (tiny) one-hot pattern matrix (p_chars*4, q_pad)."""
    Q, P = patterns.shape
    pat_mat = np.zeros((p_chars, 4, q_pad), np.float32)
    pat_mat[np.arange(P)[:, None], patterns.T, np.arange(Q)[None, :]] = 1.0
    return pat_mat.reshape(p_chars * 4, q_pad)


class MatchEngine:
    """Planner + packed corpus + streaming executor in one object.

    ``corpus`` may be a PackedCorpus or a raw (R, F) uint8 fragment matrix.
    ``mesh`` (optional) shards corpus rows over the mesh axes the ``rows``
    logical rule maps to; pass ``rules`` to use a non-default rule table.
    """

    def __init__(self, corpus: Union[PackedCorpus, np.ndarray], *,
                 planner: Optional[Planner] = None,
                 interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None, rules=None):
        n_corpus_rows = (corpus.n_rows if isinstance(corpus, PackedCorpus)
                         else np.asarray(corpus).shape[0])
        if n_corpus_rows < 1:
            # Fail at construction, not deep inside the planner on the
            # first query ("corpus has no rows" with no context).
            raise ValueError("MatchEngine needs a non-empty corpus: got 0 "
                             "fragment rows")
        self.mesh = mesh
        self.rules = rules
        self._row_shards = 1
        self._row_axes: Optional[Tuple[str, ...]] = None
        row_pad = _swar.ROW_TILE
        if mesh is not None:
            n = (corpus.n_rows if isinstance(corpus, PackedCorpus)
                 else np.asarray(corpus).shape[0])
            r = _sharding.resolve_axis(
                "rows", -(-n // _swar.ROW_TILE) * _swar.ROW_TILE, mesh, rules)
            if r is not None:
                self._row_axes = r if isinstance(r, tuple) else (r,)
                self._row_shards = int(
                    np.prod([mesh.shape[a] for a in self._row_axes]))
                row_pad = _swar.ROW_TILE * self._row_shards
        if isinstance(corpus, PackedCorpus):
            if corpus.row_pad % row_pad:
                corpus.row_pad = row_pad
                corpus.invalidate()
            self.corpus = corpus
        else:
            self.corpus = PackedCorpus(np.asarray(corpus, np.uint8),
                                       row_pad=row_pad)
        self.planner = planner or Planner()
        self.interpret = default_interpret() if interpret is None else interpret

    # -- planning -------------------------------------------------------------
    def _infer_mode(self, patterns: np.ndarray, mode: Optional[str],
                    backend: Optional[str], n_rows: int) -> str:
        if patterns.ndim == 1:
            if mode not in (None, "shared"):
                raise ValueError(f"1-D patterns are 'shared', got mode={mode!r}")
            return "shared"
        if mode is not None:
            if mode not in ("per_row", "batched"):
                raise ValueError(f"2-D patterns need mode 'per_row' or "
                                 f"'batched', got {mode!r}")
            if mode == "per_row" and patterns.shape[0] != n_rows:
                raise ValueError("per_row patterns must have one row per "
                                 "corpus row")
            return mode
        # (Q, P) with Q == n_rows is ambiguous; resolve like the historical
        # ops API: the mxu kernel is inherently batched, everything else
        # reads a row-count match as per-row.  Pass mode= to be explicit.
        if backend == "mxu":
            return "batched"
        return "per_row" if patterns.shape[0] == n_rows else "batched"

    def plan(self, patterns: np.ndarray, *, backend: Optional[str] = None,
             mode: Optional[str] = None, rows: Optional[np.ndarray] = None,
             chunk_rows: Optional[int] = None) -> Plan:
        patterns = np.asarray(patterns, np.uint8)
        n_rows = self.corpus.n_rows if rows is None else len(rows)
        mode = self._infer_mode(patterns, mode, backend, n_rows)
        return self.planner.plan(
            n_rows=n_rows,
            fragment_chars=self.corpus.fragment_chars,
            pattern_chars=patterns.shape[-1],
            n_patterns=patterns.shape[0] if mode == "batched" else None,
            per_row=mode == "per_row", backend=backend, chunk_rows=chunk_rows)

    # -- kernel dispatch (one chunk, pure device) -----------------------------
    def _swar_chunk(self, words: jnp.ndarray, pat_words: jnp.ndarray,
                    mask: jnp.ndarray, plan: Plan) -> jnp.ndarray:
        def call(w, p):
            return _swar.match_swar(w, p, mask, n_locs=plan.n_locs,
                                    pattern_chars=plan.pattern_chars,
                                    interpret=self.interpret)
        if self.mesh is not None and self._row_axes is not None:
            from jax.experimental.shard_map import shard_map
            spec = PartitionSpec(self._row_axes if len(self._row_axes) > 1
                                 else self._row_axes[0])
            call = shard_map(call, mesh=self.mesh, in_specs=(spec, spec),
                             out_specs=spec, check_rep=False)
        return call(words, pat_words)

    def _mxu_chunk(self, ref_flat: jnp.ndarray, pat_mat: jnp.ndarray,
                   plan: Plan) -> jnp.ndarray:
        def call(r, p):
            return _mxu.match_mxu(r, p, l_pad=plan.l_pad,
                                  interpret=self.interpret)
        if self.mesh is not None and self._row_axes is not None:
            from jax.experimental.shard_map import shard_map
            spec = PartitionSpec(self._row_axes if len(self._row_axes) > 1
                                 else self._row_axes[0])
            call = shard_map(call, mesh=self.mesh,
                             in_specs=(spec, PartitionSpec(None, None)),
                             out_specs=spec, check_rep=False)
        return call(ref_flat, pat_mat)

    def _chunk_scores(self, plan: Plan, patterns: np.ndarray, c0: int,
                      c1: int, packed, idx: Optional[jnp.ndarray]
                      ) -> jnp.ndarray:
        """Scores for query rows [c0, c1): (rows, L) or (rows, L, Q).

        ``idx`` (padded corpus-row indices) is set for row-subset queries:
        the chunk is gathered from the resident device forms instead of
        sliced -- still no host repacking.
        """
        if plan.backend == "ref":
            if idx is not None:
                sel = np.asarray(idx[c0:min(c1, plan.n_rows)])
                frags = jnp.asarray(self.corpus.fragments[sel])
            else:
                frags = jnp.asarray(self.corpus.fragments[c0:min(c1,
                                    self.corpus.n_rows)])
            if plan.mode == "batched":
                outs = [_kref.match_scores_ref(frags, patterns[q])
                        for q in range(plan.n_patterns)]
                return jnp.stack(outs, -1)
            pats = patterns[c0:c1] if plan.mode == "per_row" else patterns
            return _kref.match_scores_ref(frags, pats)

        if plan.backend == "swar":
            base = self.corpus.swar_words(plan.need_words)
            words = base[idx[c0:c1]] if idx is not None else base[c0:c1]
            pat_words, mask = packed
            mask = jnp.asarray(mask)
            if plan.mode == "per_row":
                pw = jnp.asarray(pat_words)
                r_pad = words.shape[0]
                rows = pw[c0:min(c1, pw.shape[0])]
                if rows.shape[0] < r_pad:
                    rows = jnp.concatenate(
                        [rows, jnp.zeros((r_pad - rows.shape[0],
                                          rows.shape[1]), jnp.uint32)], 0)
                return self._swar_chunk(words, rows, mask, plan)
            if plan.mode == "batched":
                # Fused batched launch: tile the chunk Q times and ride
                # each pattern as a per-row pattern -- one kernel dispatch
                # for all Q queries (the lock-step multi-pattern search of
                # the paper's Sec. 3.4) instead of a Q-pass Python loop.
                Q = plan.n_patterns
                Rc = words.shape[0]
                words_t = jnp.tile(words, (Q, 1))
                pw_t = jnp.repeat(jnp.asarray(pat_words), Rc, axis=0)
                out = self._swar_chunk(words_t, pw_t, mask, plan)
                return out.reshape(Q, Rc, plan.n_locs).transpose(1, 2, 0)
            pw = jnp.broadcast_to(jnp.asarray(pat_words[0])[None, :],
                                  (words.shape[0], plan.wp))
            return self._swar_chunk(words, pw, mask, plan)

        # mxu
        base = self.corpus.onehot_flat(plan.f_chars)
        ref_flat = base[idx[c0:c1]] if idx is not None else base[c0:c1]
        out = self._mxu_chunk(ref_flat, packed, plan)
        scores = jnp.round(out[:, :plan.n_locs, :plan.n_patterns]
                           ).astype(jnp.int32)
        return scores[:, :, 0] if plan.mode != "batched" else scores

    # -- empty subsets --------------------------------------------------------
    def _empty_result(self, patterns: np.ndarray, mode: Optional[str],
                      reduction: str) -> MatchResult:
        """Well-formed all-empty MatchResult for a zero-row subset query.

        The planner (rightly) refuses zero-row workloads and the streaming
        loop would otherwise ``np.concatenate`` empty chunk lists; an empty
        subset is a legal query whose answer is simply no rows.
        """
        P = int(patterns.shape[-1])
        F = self.corpus.fragment_chars
        if P < 1:
            raise ValueError("pattern must have at least one character")
        L = F - P + 1
        if L <= 0:
            raise ValueError("pattern longer than fragment")
        if patterns.ndim == 1:
            mode_r, Q = "shared", 1
        else:
            mode_r = mode if mode is not None else "batched"
            Q = int(patterns.shape[0])
        batched = mode_r == "batched"
        plan = Plan(backend="ref", mode=mode_r, n_rows=0, fragment_chars=F,
                    pattern_chars=P, n_patterns=Q if batched else 1,
                    n_locs=L, chunk_rows=0, reason="empty row subset")
        shape0 = (0, Q) if batched else (0,)
        res = MatchResult(plan=plan,
                          best_locs=np.zeros(shape0, np.int32),
                          best_scores=np.zeros(shape0, np.int32))
        if reduction == "full":
            res.scores = np.zeros((0, L, Q) if batched else (0, L), np.int32)
        elif reduction == "topk":
            res.topk_rows = np.zeros(shape0, np.int32)
            res.topk_scores = np.zeros(shape0, np.int32)
        elif reduction == "threshold":
            res.hits = np.zeros((0, 4 if batched else 3), np.int64)
        return res

    # -- execution ------------------------------------------------------------
    def match(self, patterns: np.ndarray, *, backend: Optional[str] = None,
              mode: Optional[str] = None, rows: Optional[np.ndarray] = None,
              reduction: str = "best", k=10,
              threshold=None,
              chunk_rows: Optional[int] = None) -> MatchResult:
        """Run one query; see module docstring for reductions.

        patterns: (P,) shared, (R, P) per-row, or (Q, P) batched uint8.
        ``mode`` disambiguates 2-D patterns ("per_row" / "batched") when the
        shape alone is ambiguous.  ``rows`` restricts the query to a subset
        of corpus rows (device gather from the resident forms; results are
        in subset order; an empty subset yields an all-empty result).
        ``threshold`` is in characters (absolute score).  In batched mode
        ``k`` and ``threshold`` may be per-query sequences of length Q (the
        top-k merge runs at max(k); slice ``topk_rows[:k_q, q]`` per query).
        """
        if reduction not in ("best", "topk", "threshold", "full"):
            raise ValueError(f"unknown reduction {reduction!r}")
        if reduction == "threshold" and threshold is None:
            raise ValueError("reduction='threshold' requires a threshold")
        patterns = np.asarray(patterns, np.uint8)
        sel = (np.asarray(rows, np.int64).reshape(-1) if rows is not None
               else None)
        if sel is not None and sel.size == 0:
            return self._empty_result(patterns, mode, reduction)
        plan = self.plan(patterns, backend=backend, mode=mode, rows=rows,
                         chunk_rows=chunk_rows)
        pats2d = patterns if patterns.ndim == 2 else patterns[None, :]

        # Per-query reduction parameters (batched runs only).
        k_vec = np.atleast_1d(np.asarray(k, np.int64))
        if k_vec.size != 1 and (plan.mode != "batched"
                                or k_vec.size != plan.n_patterns):
            raise ValueError("per-query k needs a batched query with one "
                             "entry per pattern")
        k_eff = int(k_vec.max())
        thr_vec = None
        if reduction == "threshold":
            thr_vec = np.asarray(threshold, np.float64).reshape(-1)
            if plan.mode == "batched":
                if thr_vec.size == 1:
                    thr_vec = np.full(plan.n_patterns, thr_vec[0])
                elif thr_vec.size != plan.n_patterns:
                    raise ValueError("per-query thresholds need one entry "
                                     "per pattern")
            elif thr_vec.size != 1:
                raise ValueError("per-query thresholds need a batched query")

        if plan.backend == "swar":
            packed = _pack_pattern_swar(pats2d, plan.wp)
        elif plan.backend == "mxu":
            packed = jnp.asarray(
                _pack_patterns_mxu(pats2d, plan.p_chars_pad, plan.q_pad),
                jnp.bfloat16)
        else:
            packed = None

        if sel is not None:
            if sel.min() < 0 or sel.max() >= self.corpus.n_rows:
                # jnp gathers clamp out-of-range indices silently; fail
                # loudly instead of returning the wrong rows' scores.
                raise IndexError(
                    f"rows must be in [0, {self.corpus.n_rows}), got "
                    f"[{sel.min()}, {sel.max()}]")
            R = len(sel)
            R_pad = -(-R // self.corpus.row_pad) * self.corpus.row_pad
            pad_idx = np.zeros(R_pad, np.int64)
            pad_idx[:R] = sel
            idx = jnp.asarray(pad_idx)
        else:
            R = self.corpus.n_rows
            R_pad = self.corpus.n_rows_padded
            idx = None
        step = plan.chunk_rows
        if self._row_shards > 1:
            tile = _swar.ROW_TILE * self._row_shards
            step = max(tile, (step // tile) * tile)

        best_l: List[np.ndarray] = []
        best_s: List[np.ndarray] = []
        full: List[np.ndarray] = []
        hit_rows: List[np.ndarray] = []
        run_rows = run_scores = None      # running global top-k state
        n_chunks = 0

        for c0 in range(0, R_pad, step):
            c1 = min(c0 + step, R_pad)
            valid = min(c1, R) - c0       # rows in this chunk that are real
            if valid <= 0:
                break                     # pure-padding tail chunk
            scores = self._chunk_scores(plan, pats2d, c0, c1, packed, idx)
            scores = scores[:valid]
            n_chunks += 1
            if reduction == "full":
                # Host materialization is the point of this reduction; the
                # best reduction is derived from it at the end.
                full.append(np.asarray(scores))
                continue
            # Fused per-chunk reduction: only (chunk, ...) lives at once.
            bl = jnp.argmax(scores, axis=1)
            bs = jnp.max(scores, axis=1)
            best_l.append(np.asarray(bl))
            best_s.append(np.asarray(bs))
            # topk / threshold report *corpus* row ids; with a rows= subset
            # that means mapping chunk positions through the selection.
            if reduction == "threshold":
                sc = np.asarray(scores)
                if plan.mode == "batched":
                    local = np.argwhere(sc >= thr_vec[None, None, :])
                else:
                    local = np.argwhere(sc >= float(thr_vec[0]))
                if local.size:
                    vals = sc[tuple(local.T)]
                    if rows is not None:
                        local[:, 0] = sel[local[:, 0] + c0]
                    else:
                        local[:, 0] += c0
                    hit_rows.append(np.concatenate(
                        [local, vals[:, None].astype(np.int64)], 1))
            elif reduction == "topk":
                if rows is not None:
                    chunk_rows_ids = jnp.asarray(sel[c0:c0 + valid])
                else:
                    chunk_rows_ids = jnp.arange(c0, c0 + valid)
                if bs.ndim == 2:          # batched: top-k per pattern
                    chunk_rows_ids = jnp.broadcast_to(
                        chunk_rows_ids[:, None], bs.shape)
                cat_s = bs if run_scores is None else jnp.concatenate(
                    [run_scores, bs], 0)
                cat_r = chunk_rows_ids if run_rows is None else \
                    jnp.concatenate([run_rows, chunk_rows_ids], 0)
                kk = min(k_eff, cat_s.shape[0])
                top_s, top_i = jax.lax.top_k(cat_s.T if cat_s.ndim == 2
                                             else cat_s, kk)
                if cat_s.ndim == 2:
                    run_scores = top_s.T
                    run_rows = jnp.take_along_axis(cat_r.T, top_i, 1).T
                else:
                    run_scores = top_s
                    run_rows = cat_r[top_i]

        if reduction == "full":
            all_scores = np.concatenate(full, 0)
            return MatchResult(plan=plan, best_locs=all_scores.argmax(1),
                               best_scores=all_scores.max(1),
                               scores=all_scores, n_chunks=n_chunks)
        best_locs = np.concatenate(best_l, 0)
        best_scores = np.concatenate(best_s, 0)
        res = MatchResult(plan=plan, best_locs=best_locs,
                          best_scores=best_scores, n_chunks=n_chunks)
        if reduction == "threshold":
            width = 3 + (1 if plan.mode == "batched" else 0)
            res.hits = (np.concatenate(hit_rows, 0) if hit_rows
                        else np.zeros((0, width), np.int64))
        elif reduction == "topk":
            res.topk_rows = np.asarray(run_rows)
            res.topk_scores = np.asarray(run_scores)
        return res

    def scores(self, patterns: np.ndarray, *, backend: Optional[str] = None,
               mode: Optional[str] = None, rows: Optional[np.ndarray] = None,
               chunk_rows: Optional[int] = None) -> np.ndarray:
        """Full materialized score tensor (compat path for small problems)."""
        return self.match(patterns, backend=backend, mode=mode, rows=rows,
                          reduction="full", chunk_rows=chunk_rows).scores
