"""N-gram speculative proposer backed by the CRAM-PM matcher (serving-plane
integration of the paper's technique; DESIGN.md Sec. 4).

Token history is transcoded to the 2-bit alphabet (each token id -> 8
crumbs) and folded across rows like the paper's reference (Fig. 3).  To
propose continuations for the current suffix, the suffix is matched
row-parallel against the history; the characters following the best-scoring
alignment are proposed as speculative tokens (exactly the paper's
"map a short pattern to the most similar substring of a long reference",
repurposed as prompt-cache lookup / n-gram speculation).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import encoding
from repro.kernels import ops

CRUMBS_PER_TOKEN = 8    # 16-bit token ids -> 8 two-bit crumbs


def tokens_to_crumbs(tokens: np.ndarray) -> np.ndarray:
    tokens = np.asarray(tokens, np.uint32)
    shifts = (2 * np.arange(CRUMBS_PER_TOKEN, dtype=np.uint32))
    return ((tokens[..., None] >> shifts) & 3).astype(np.uint8).reshape(
        tokens.shape[:-1] + (-1,))


class NgramSpeculator:
    def __init__(self, suffix_tokens: int = 4, fragment_tokens: int = 128,
                 method: str = "swar"):
        self.suffix_tokens = suffix_tokens
        self.fragment_tokens = fragment_tokens
        self.method = method
        self.history: List[int] = []

    def feed(self, tokens: List[int] | np.ndarray) -> None:
        self.history.extend(int(t) for t in np.asarray(tokens).reshape(-1))

    def propose(self, suffix: List[int] | np.ndarray,
                k: int = 4) -> Tuple[np.ndarray, float]:
        """Speculative continuation of length k after the best match of
        ``suffix`` in the history.  Returns (tokens (<=k,), confidence)."""
        suffix = np.asarray(suffix, np.int64).reshape(-1)[-self.suffix_tokens:]
        hist = np.asarray(self.history, np.int64)
        if len(hist) < len(suffix) + 1:
            return np.zeros((0,), np.int64), 0.0
        # Work in crumbs so arbitrary token ids are exact.
        crumbs = tokens_to_crumbs(hist)
        pat = tokens_to_crumbs(suffix)
        frag_len = min(self.fragment_tokens * CRUMBS_PER_TOKEN, len(crumbs))
        frags = encoding.fold_reference(crumbs, frag_len, len(pat))
        scores = np.asarray(ops.match_scores(frags, pat,
                                             backend=self.method))
        r, loc = np.unravel_index(scores.argmax(), scores.shape)
        conf = float(scores[r, loc]) / len(pat)
        # Token index right after the matched suffix in the original stream.
        step = frag_len - (len(pat) - 1)
        crumb_pos = r * step + loc + len(pat)
        tok_pos = crumb_pos // CRUMBS_PER_TOKEN
        if crumb_pos % CRUMBS_PER_TOKEN:
            tok_pos += 1
        prop = hist[tok_pos: tok_pos + k]
        return prop, conf


def verify(proposed: np.ndarray, actual: np.ndarray) -> int:
    """Speculation acceptance: length of the agreeing prefix."""
    n = min(len(proposed), len(actual))
    agree = 0
    for i in range(n):
        if proposed[i] != actual[i]:
            break
        agree += 1
    return agree
