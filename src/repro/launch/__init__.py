"""repro.launch"""
