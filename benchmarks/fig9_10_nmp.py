"""Paper Figs. 9-10: match rate + compute efficiency of CRAM-PM vs the NMP
and NMP-Hyp baselines, per benchmark app (+ DNA).  Paper anchors: WC max
match-rate gain 133552x (long-term); RC4 max efficiency gain ~300x/900x;
BC least benefit vs NMP-Hyp; all but BC >5x vs NMP-Hyp."""

import time

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM


def run():
    rows = []
    t0 = time.perf_counter()
    d = cm.Design(tech=NEAR_TERM, opt=False)
    dna_near = cm.run_workload(d, 3_000_000, "oracular")
    dna_long = cm.run_workload(
        cm.Design(tech=LONG_TERM, opt=False), 3_000_000, "oracular")
    nmp = cm.dna_nmp_run(d, 3_000_000)
    hyp = cm.dna_nmp_run(d, 3_000_000, hyp=True)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig9/DNA", round(us, 1),
                 f"rate_vs_nmp={dna_near.match_rate/nmp.match_rate:.4g}x"
                 f" long={dna_long.match_rate/nmp.match_rate:.4g}x"
                 f" vs_hyp={dna_near.match_rate/hyp.match_rate:.4g}x"))
    for name, app in cm.table4_apps().items():
        cn = cm.app_cram_run(app, NEAR_TERM)
        cl = cm.app_cram_run(app, LONG_TERM)
        n = cm.app_nmp_run(app)
        h = cm.app_nmp_run(app, hyp=True)
        rows.append((f"fig9/{name}", 0.0,
                     f"rate_vs_nmp near={cn.match_rate/n.match_rate:.4g}x"
                     f" long={cl.match_rate/n.match_rate:.4g}x"
                     + (" paper_long=133552x" if name == "WC" else "")))
        rows.append((f"fig10/{name}", 0.0,
                     f"eff_vs_nmp near={cn.efficiency/n.efficiency:.4g}x"
                     f" long={cl.efficiency/n.efficiency:.4g}x"
                     f" vs_hyp near={cn.efficiency/h.efficiency:.3g}x"
                     f" long={cl.efficiency/h.efficiency:.3g}x"
                     + (" paper=~300x/900x" if name == "RC4" else "")))
    return rows
