"""Sharded-vs-single-shard oracle equivalence (DESIGN.md Sec. 3h).

The mesh-sharded match stack must be *bit-identical* to the single-shard
engine: cyclic row placement, shard-local kernels under shard_map, the
survivor union, and the cross-shard top-k merge are all layout/execution
changes, never semantic ones.  Every test here runs the same query on a
1-shard engine and on 2- and 4-shard row meshes and asserts exact
equality -- backends x reductions x predicates x growth.

Needs forced host devices (tests/conftest.py sets
``--xla_force_host_platform_device_count=8``); skips with a named reason
when fewer devices are available.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.distributed import sharding as _sharding
from repro.match.engine import MatchEngine
from repro.match.query import MatchQuery
from repro.match.service import MatchService


def row_mesh(n_shards: int):
    if len(jax.devices()) < n_shards:
        pytest.skip(f"needs >= {n_shards} devices "
                    "(forced host devices; see tests/conftest.py)")
    from repro.launch.mesh import make_row_mesh
    return make_row_mesh(n_shards)


def corpus(n_rows: int, seed: int, chars: int = 64):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (n_rows, chars), np.uint8)
    # Plant a pattern a few times so threshold/topk have real hits.
    pat = frags[n_rows // 3, 10:26].copy()
    for r in (0, n_rows // 2, n_rows - 1):
        frags[r, 20:36] = pat
    return frags, pat


def engines(frags, n_shards):
    e1 = MatchEngine(frags.copy())
    es = MatchEngine(frags.copy(), mesh=row_mesh(n_shards))
    assert es.n_shards == n_shards
    return e1, es


def assert_result_equal(r1, rs):
    for f in ("scores", "best_locs", "best_scores", "topk_rows",
              "topk_scores", "hits"):
        a, b = getattr(r1, f), getattr(rs, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(a, b, err_msg=f)


class TestCyclicLayout:
    """The layout helpers are each other's inverses and match the map
    r -> (r % S) * J + r // S."""

    def test_permute_roundtrip(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 100, (24, 3))
        for s in (1, 2, 4, 8):
            np.testing.assert_array_equal(
                _sharding.cyclic_unpermute(
                    _sharding.cyclic_permute(a, s), s), a)

    def test_physical_rows_match_permute(self):
        rng = np.random.default_rng(1)
        a = rng.integers(0, 100, (24,))
        for s in (2, 4):
            phys = _sharding.cyclic_physical_rows(np.arange(24), s, 24 // s)
            np.testing.assert_array_equal(
                _sharding.cyclic_permute(a, s)[phys], a)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
class TestBackendEquivalence:
    def test_full_scores(self, backend, n_shards):
        frags, pat = corpus(100, seed=10)
        e1, es = engines(frags, n_shards)
        np.testing.assert_array_equal(
            np.asarray(e1.scores(pat, backend=backend)),
            np.asarray(es.scores(pat, backend=backend)))

    def test_reductions(self, backend, n_shards):
        frags, pat = corpus(100, seed=11)
        e1, es = engines(frags, n_shards)
        # filter=False pins the scan path: with filter=None the planner may
        # legitimately pick different strategies at different shard counts
        # (per-shard pricing), and the filtered path's survivor-only
        # best_locs would then differ in shape while the deliverable
        # (hits) stays identical.  TestFilteredPath covers the other leg.
        for q in (MatchQuery.exact(pat, reduction="best", backend=backend),
                  MatchQuery.exact(pat, reduction="topk", k=7,
                                   backend=backend),
                  MatchQuery.exact(pat, reduction="threshold", threshold=14,
                                   backend=backend, filter=False)):
            assert_result_equal(e1.match(q), es.match(q))

    def test_batched_coalesced(self, backend, n_shards):
        frags, pat = corpus(100, seed=12)
        rng = np.random.default_rng(13)
        pats = np.stack([pat] + [rng.integers(0, 4, 16, np.uint8)
                                 for _ in range(3)])
        e1, es = engines(frags, n_shards)
        q = MatchQuery.exact(pats, mode="batched", reduction="topk",
                             k=[5, 5, 5, 5], backend=backend)
        assert_result_equal(e1.match(q), es.match(q))


@pytest.mark.parametrize("n_shards", [2, 4])
class TestPredicatesAndSubsets:
    def test_wildcard_iupac(self, n_shards):
        frags, pat = corpus(100, seed=20)
        e1, es = engines(frags, n_shards)
        pstr = "".join("ACGT"[c] for c in pat)
        q = MatchQuery.iupac("N" + pstr[1:8] + "R" + pstr[9:],
                             reduction="best")
        assert_result_equal(e1.match(q), es.match(q))

    def test_rows_subset_gather(self, n_shards):
        frags, pat = corpus(100, seed=21)
        e1, es = engines(frags, n_shards)
        rows = [0, 3, 33, 50, 97, 99]
        q = MatchQuery.exact(pat, rows=rows, reduction="topk", k=4)
        assert_result_equal(e1.match(q), es.match(q))

    def test_topk_merge_is_bit_identical_on_ties(self, n_shards):
        # All-identical rows: every score ties, so the merge order is
        # decided purely by the (score desc, row asc) total order the
        # host merge must reproduce exactly.
        frags = np.tile(np.arange(4, dtype=np.uint8), (32, 16))
        pat = frags[0, :16].copy()
        e1, es = engines(frags, n_shards)
        q = MatchQuery.exact(pat, reduction="topk", k=9)
        r1, rs = e1.match(q), es.match(q)
        assert_result_equal(r1, rs)
        np.testing.assert_array_equal(rs.topk_rows, np.arange(9))


@pytest.mark.parametrize("n_shards", [2, 4])
class TestGrowth:
    def test_append_rows_equivalence_and_flat_pack_counters(self, n_shards):
        frags, pat = corpus(96, seed=30)
        e1, es = engines(frags, n_shards)
        # Force both device forms resident before growing.
        es.scores(pat, backend="swar")
        es.scores(np.stack([pat, pat]), backend="mxu")
        packs = es.corpus.host_pack_count
        rng = np.random.default_rng(31)
        for n in (5, 64, 300):   # in-place splice, then capacity growth
            more = rng.integers(0, 4, (n, 64), np.uint8)
            e1.corpus.append_rows(more)
            es.corpus.append_rows(more)
            np.testing.assert_array_equal(
                np.asarray(e1.scores(pat, backend="swar")),
                np.asarray(es.scores(pat, backend="swar")))
        # Growth splices rows per shard; it never repacks the resident
        # corpus (pack counters stay flat, DESIGN.md Sec. 3f + 3h).
        assert es.corpus.host_pack_count == packs

    def test_compiled_rows_subset_survives_growth(self, n_shards):
        # Capacity growth changes the per-shard stride, so the compiled
        # query's cached physical gather indices go stale and must be
        # rebuilt -- not reused -- after append_rows.
        frags, pat = corpus(96, seed=32)
        e1, es = engines(frags, n_shards)
        q = MatchQuery.exact(pat, rows=[1, 40, 95], reduction="best")
        c1, cs = e1.compile(q), es.compile(q)
        assert_result_equal(c1.run(), cs.run())
        more = np.random.default_rng(33).integers(0, 4, (500, 64), np.uint8)
        e1.corpus.append_rows(more)
        es.corpus.append_rows(more)
        assert_result_equal(c1.run(), cs.run())


@pytest.mark.parametrize("n_shards", [2, 4])
class TestFilteredPath:
    def test_filtered_threshold_equivalence(self, n_shards):
        frags, pat = corpus(200, seed=40)
        e1, es = engines(frags, n_shards)     # index auto-attached
        q = MatchQuery.exact(pat, reduction="threshold", threshold=14,
                             filter=True)
        r1, rs = e1.match(q), es.match(q)
        assert rs.plan.strategy == "filter", rs.plan.reason
        assert_result_equal(r1, rs)

    def test_sharded_filter_zero_false_negatives(self, n_shards):
        # Survivor union vs. exhaustive scan: identical hit sets, with
        # wildcards and after growth.
        frags, pat = corpus(200, seed=41)
        es = MatchEngine(frags.copy(), mesh=row_mesh(n_shards))
        more = np.random.default_rng(42).integers(0, 4, (100, 64), np.uint8)
        more[7, 5:21] = pat
        es.corpus.append_rows(more)
        pstr = "".join("ACGT"[c] for c in pat)
        for q in (MatchQuery.exact(pat, reduction="threshold", threshold=13),
                  MatchQuery.iupac("N" + pstr[1:], reduction="threshold",
                                   threshold=13)):
            filt = es.match(dataclasses.replace(q, filter=True))
            scan = es.match(dataclasses.replace(q, filter=False))
            np.testing.assert_array_equal(filt.hits, scan.hits)
            assert scan.plan.strategy == "scan"

    def test_sharded_filter_true_never_silent_scans(self, n_shards):
        # Regression (PR 6 satellite): before sharding-aware filtering,
        # a sharded engine silently dropped filter=True to a full scan.
        # Now it must either filter or raise a named error -- here the
        # index exists, so it filters.
        frags, pat = corpus(200, seed=43)
        es = MatchEngine(frags.copy(), mesh=row_mesh(n_shards))
        r = es.match(MatchQuery.exact(pat, reduction="threshold",
                                      threshold=14, filter=True))
        assert r.plan.strategy == "filter", r.plan.reason
        assert r.survivor_frac is not None
        # ... and when filtering is structurally impossible (index=False),
        # filter=True raises a named error rather than silently scanning.
        es2 = MatchEngine(frags.copy(), mesh=row_mesh(n_shards),
                          index=False)
        with pytest.raises(ValueError, match="cannot honor filter=True"):
            es2.match(MatchQuery.exact(pat, reduction="threshold",
                                       threshold=14, filter=True))


class TestSurfacing:
    def test_resolve_axis_warns_on_fallback(self):
        mesh = row_mesh(3)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            eng = MatchEngine(np.zeros((10, 64), np.uint8), mesh=mesh)
        assert eng.n_shards == 1
        msgs = [str(w.message) for w in rec
                if issubclass(w.category, UserWarning)]
        assert any("rows" in m and "replication" in m for m in msgs), msgs

    def test_repr_and_result_surface_shards(self):
        frags, pat = corpus(64, seed=50)
        es = MatchEngine(frags, mesh=row_mesh(2))
        assert "shards=2" in repr(es)
        assert es.match(pat).n_shards == 2
        e1 = MatchEngine(frags.copy())
        assert e1.match(pat).n_shards == 1

    def test_service_reports_per_shard_rows(self):
        frags, pat = corpus(64, seed=51)
        es = MatchEngine(frags, mesh=row_mesh(4))
        svc = MatchService(es)
        rng = np.random.default_rng(52)
        for i in range(10):
            svc.ingest(rng.integers(0, 4, (1 + i % 3, 64), np.uint8))
        svc.submit(pat, reduction="best")
        svc.flush()
        snap = svc.stats.snapshot()
        assert snap["n_shards"] == 4
        assert sum(snap["shard_rows"]) == es.corpus.n_rows
        assert snap["shard_balance"] <= 1.1
        # Cyclic placement: shard s holds ceil((n - s) / S) rows exactly.
        np.testing.assert_array_equal(
            snap["shard_rows"], es.shard_live_rows())
