"""RC4-style bulk stream cipher on the bit-parallel engine (paper Table 4).

Generates an RC4 keystream (host-side PRGA), then runs the bulk XOR
encrypt/decrypt over many message rows with the Pallas bitwise kernel --
the same row-parallel computation CRAM-PM performs in the RC4 benchmark --
and reports the substrate cost-model projection.

Run:  PYTHONPATH=src python examples/crypto_rc4.py
"""

import numpy as np

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM
from repro.kernels import ops


def rc4_keystream(key: bytes, n: int) -> np.ndarray:
    s = list(range(256))
    j = 0
    for i in range(256):
        j = (j + s[i] + key[i % len(key)]) % 256
        s[i], s[j] = s[j], s[i]
    out = np.empty(n, np.uint8)
    i = j = 0
    for t in range(n):
        i = (i + 1) % 256
        j = (j + s[i]) % 256
        s[i], s[j] = s[j], s[i]
        out[t] = s[(s[i] + s[j]) % 256]
    return out


def main() -> None:
    rng = np.random.default_rng(0)
    n_rows, row_words = 4096, 8            # 248-bit segments, padded to 256
    text = rng.integers(0, 2**32, (n_rows, row_words),
                        dtype=np.uint64).astype(np.uint32)
    ks = rc4_keystream(b"repro-key", n_rows * row_words * 4)
    key = ks.view(np.uint32).reshape(n_rows, row_words)

    cipher = np.asarray(ops.bitwise("XOR", text, key))
    plain = np.asarray(ops.bitwise("XOR", cipher, key))
    assert np.array_equal(plain, text)
    print(f"encrypt/decrypt round-trip over {n_rows} rows x "
          f"{row_words*32} bits: OK")

    app = cm.table4_apps()["RC4"]
    for tech in (NEAR_TERM, LONG_TERM):
        r = cm.app_cram_run(app, tech)
        nmp = cm.app_nmp_run(app)
        print(f"CRAM-PM {tech.name:9s}: {r.match_rate:.4g} segments/s "
              f"({r.match_rate/nmp.match_rate:.0f}x NMP)")


if __name__ == "__main__":
    main()
