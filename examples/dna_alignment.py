"""End-to-end DNA sequence alignment (the paper's running case study).

Builds a synthetic genome slice, folds it across rows into a device-
resident packed corpus (Fig. 3), then exercises the declarative query IR
(DESIGN.md Sec. 3e) two ways:

* a **primer scan**: an N-wildcard primer (degenerate positions encoded as
  IUPAC accept masks) is compiled *once* (``MatchEngine.compile``) and the
  resulting ``CompiledMatch`` re-run against successive corpus
  generations -- the paper's reconfigurable-logic discipline: resident
  data, reprogrammed match logic, zero per-call planning or packing;
* **read alignment** with Oracular k-mer scheduling, where reads carry
  sequencing no-calls (``N`` positions that must match anything) on top of
  SNPs, so every pass is an accept-mask ``MatchQuery`` streamed through
  the engine.

Finally the paper-scale run is projected with the calibrated cost model
(Fig. 5 numbers).

Run:  PYTHONPATH=src python examples/dna_alignment.py
"""

import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import encoding
from repro.core.scheduler import schedule_oracular
from repro.core.tech import LONG_TERM, NEAR_TERM
from repro.match import MatchEngine, MatchQuery, PackedCorpus


def main() -> None:
    rng = np.random.default_rng(7)
    genome = encoding.random_dna(rng, 200_000)
    frag_len, pat_len = 1000, 100
    corpus = PackedCorpus.from_reference(genome, frag_len, pat_len)
    engine = MatchEngine(corpus)
    frags = corpus.fragments
    print(f"reference {len(genome)} chars folded into {frags.shape[0]} rows "
          f"of {frag_len} (overlap {pat_len - 1})")

    # -- 1. compiled N-wildcard primer scan -----------------------------------
    # A 24-mer primer whose four degenerate positions are written as IUPAC
    # codes: N matches anything, R = A|G.  Compile once, reuse every scan.
    site = 31_337
    primer_codes = genome[site:site + 24].copy()
    primer = encoding.decode_dna(primer_codes)
    primer = primer[:6] + "N" + primer[7:12] + "RN" + primer[14:22] + "NN"
    query = MatchQuery.iupac(primer, reduction="threshold", threshold=24)
    scan = engine.compile(query)                   # plan + pack, once
    hits = scan().hits
    step = frag_len - (pat_len - 1)
    glob = [int(r * step + loc) for r, loc, _ in hits]
    print(f"primer {primer} compiled once ({scan.plan.backend}/"
          f"{scan.plan.predicate}); full-score sites at {glob} "
          f"(planted at {site})")
    # A corpus row write bumps the generation; the same CompiledMatch
    # serves the new contents -- no re-plan, no re-pack.
    row = site // step
    orig = frags[row].copy()                       # set_rows mutates frags
    edited = orig.copy()
    edited[site - row * step] ^= 1                 # break the primer site
    corpus.set_rows(row, edited)
    print(f"after a row write (generation {corpus.generation}): "
          f"{scan().hits.shape[0]} full-score sites, "
          f"{corpus.host_pack_count} host pack event(s)")
    corpus.set_rows(row, orig)                     # restore

    # -- 2. read alignment with no-calls --------------------------------------
    # Reads get 2 SNPs (real mismatches) plus 3 sequencing no-calls that
    # must not count against the alignment: the no-call positions become
    # full-wildcard accept masks (the predicate API), so a perfect
    # placement scores pat_len minus the SNPs only.
    n_reads = 64
    starts = rng.integers(0, len(genome) - pat_len, n_reads)
    reads = np.stack([genome[s:s + pat_len].copy() for s in starts])
    read_masks = (np.uint8(1) << reads).astype(np.uint8)
    for r in range(n_reads):
        snps = rng.integers(0, pat_len, 2)
        reads[r, snps] = rng.integers(0, 4, 2)
        read_masks[r, snps] = (np.uint8(1) << reads[r, snps])
        nocalls = rng.integers(0, pat_len, 3)
        read_masks[r, nocalls] = 0b1111            # N: matches anything

    sched = schedule_oracular(frags, reads, k=12)
    print(f"oracular schedule: {sched.n_passes} passes, "
          f"avg {sched.replication:.1f} candidate rows/read (naive: "
          f"{n_reads} passes x all rows)")

    # Every pass streams only its candidate rows (the Oracular assignment)
    # through the same resident corpus -- a device gather from the packed
    # forms, so the corpus packs on the first pass and is reused untouched
    # afterwards.
    t0 = time.perf_counter()
    recovered = 0
    for assign in sched.passes:
        rows = sorted(assign)
        masks = read_masks[[assign[r] for r in rows]]
        res = engine.match(MatchQuery.from_masks(
            masks, mode="per_row", rows=rows, backend="swar",
            reduction="best"))
        for i, row in enumerate(rows):
            if res.best_scores[i] >= pat_len - 2:     # allow the 2 SNPs
                glob = row * step + res.best_locs[i]
                if abs(int(glob) - int(starts[assign[row]])) == 0:
                    recovered += 1
    dt = time.perf_counter() - t0
    print(f"recovered {recovered}/{n_reads} exact alignments in {dt:.2f}s "
          f"(CPU interpret mode; {len(sched.passes)} engine passes, "
          f"{corpus.host_pack_count} corpus pack event(s))")

    print("\npaper-scale projection (3G reference, 3M reads, 300 arrays):")
    for tech in (NEAR_TERM, LONG_TERM):
        for opt in (False, True):
            d = cm.Design(tech=tech, opt=opt)
            r = cm.run_workload(d, 3_000_000, "oracular")
            print(f"  {tech.name:9s} {'Opt' if opt else '   '} "
                  f"{r.total_time_s/3600:10.2f} h  "
                  f"{r.match_rate:12.4g} reads/s  "
                  f"{r.efficiency:8.3g} reads/s/mW")


if __name__ == "__main__":
    main()
