"""Character encodings + bit packing for CRAM-PM pattern matching.

The paper uses a 2-bit encoding for the DNA alphabet {A, C, G, T}
(Sec. 3.1); other benchmarks (string match, word count, RC4) operate on
byte text.  The packed representations feed both the CRAM array layout
(bit-columns) and the TPU fast path (uint32 SWAR words, 16 chars/word for
2-bit alphabets, 4 chars/word for bytes).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

DNA_ALPHABET = "ACGT"
DNA_CODE: Dict[str, int] = {c: i for i, c in enumerate(DNA_ALPHABET)}
DNA_BITS = 2
CHARS_PER_WORD_DNA = 32 // DNA_BITS            # 16
BYTE_BITS = 8
CHARS_PER_WORD_BYTE = 32 // BYTE_BITS          # 4

# 0b01 repeated: mask of low bit of each 2-bit char lane.
LOW_BIT_MASK_2 = np.uint32(0x55555555)
# low bit of each byte lane.
LOW_BIT_MASK_8 = np.uint32(0x01010101)


# IUPAC ambiguity codes as 4-bit accept masks: bit c set <=> DNA code c
# (A=0, C=1, G=2, T=3) is accepted at that position.  These are the
# per-position accept sets consumed by the predicate API
# (``repro.match.query``); N is the full wildcard.  U (RNA) reads as T.
IUPAC_MASKS: Dict[str, int] = {
    "A": 0b0001, "C": 0b0010, "G": 0b0100, "T": 0b1000, "U": 0b1000,
    "R": 0b0101, "Y": 0b1010, "S": 0b0110, "W": 0b1001,
    "K": 0b1100, "M": 0b0011,
    "B": 0b1110, "D": 0b1101, "H": 0b1011, "V": 0b0111,
    "N": 0b1111,
}


def encode_dna(s: str) -> np.ndarray:
    """String over ACGT -> uint8 codes (values 0..3).

    Raises ``ValueError`` on any other character: silently folding unknown
    bases to 'A' fabricates matches.  Ambiguity codes (N, R, ...) are not
    losses of information to be papered over -- encode them with
    ``encode_iupac`` and match through the predicate API.
    """
    lut = np.full(256, 255, np.uint8)
    for c, v in DNA_CODE.items():
        lut[ord(c)] = v
        lut[ord(c.lower())] = v
    raw = np.frombuffer(s.encode(), np.uint8)
    codes = lut[raw]
    if (codes == 255).any():
        # Name offenders from the byte buffer: string indices are char
        # offsets, not byte offsets (multi-byte chars would misindex).
        bad = sorted({chr(b) for b in raw[codes == 255][:8]})
        raise ValueError(
            f"encode_dna: invalid character(s) {bad} -- not in ACGT. "
            "Use encode_iupac for ambiguity codes (N, R, Y, ...)")
    return codes


def encode_iupac(s: str) -> np.ndarray:
    """IUPAC string -> uint8 per-position accept masks (values 1..15).

    Bit ``c`` of position ``i`` is set iff DNA code ``c`` is accepted there;
    plain ACGT positions become one-hot masks, ``N`` becomes 0b1111.  Feed
    the result to ``repro.match.MatchQuery.iupac`` / ``from_masks``.
    """
    lut = np.zeros(256, np.uint8)
    for c, m in IUPAC_MASKS.items():
        lut[ord(c)] = m
        lut[ord(c.lower())] = m
    raw = np.frombuffer(s.encode(), np.uint8)
    masks = lut[raw]
    if (masks == 0).any():
        bad = sorted({chr(b) for b in raw[masks == 0][:8]})
        raise ValueError(f"encode_iupac: invalid IUPAC character(s) {bad}")
    return masks


def decode_dna(codes: np.ndarray) -> str:
    return "".join(DNA_ALPHABET[c] for c in np.asarray(codes))


def random_dna(rng: np.random.Generator, n: int) -> np.ndarray:
    return rng.integers(0, 4, size=n, dtype=np.uint8)


def codes_to_bits(codes: np.ndarray, bits: int = DNA_BITS) -> np.ndarray:
    """(..., n) codes -> (..., n*bits) bit planes, LSB-first per character.

    This is the CRAM row layout: each character occupies `bits` adjacent
    cells (Sec. 3.1: "each character-level comparison entails two bit-level
    comparisons")."""
    codes = np.asarray(codes)
    out = np.zeros(codes.shape + (bits,), np.uint8)
    for b in range(bits):
        out[..., b] = (codes >> b) & 1
    return out.reshape(codes.shape[:-1] + (codes.shape[-1] * bits,))


def bits_to_codes(bitarr: np.ndarray, bits: int = DNA_BITS) -> np.ndarray:
    bitarr = np.asarray(bitarr)
    n = bitarr.shape[-1] // bits
    grouped = bitarr.reshape(bitarr.shape[:-1] + (n, bits))
    weights = (1 << np.arange(bits)).astype(np.uint8)
    return (grouped * weights).sum(-1).astype(np.uint8)


def pack_codes_u32(codes: np.ndarray, bits: int = DNA_BITS) -> np.ndarray:
    """(..., n) char codes -> (..., ceil(n/cpw)) uint32 SWAR words.

    Characters are packed LSB-first: char i occupies bits [i*bits, (i+1)*bits)
    of word i // cpw.  Tail lanes are zero-padded (caller masks them).
    """
    codes = np.asarray(codes, np.uint32)
    cpw = 32 // bits
    n = codes.shape[-1]
    n_words = -(-n // cpw)
    padded = np.zeros(codes.shape[:-1] + (n_words * cpw,), np.uint32)
    padded[..., :n] = codes
    lanes = padded.reshape(padded.shape[:-1] + (n_words, cpw))
    shifts = (np.arange(cpw, dtype=np.uint32) * bits).astype(np.uint32)
    return (lanes << shifts).sum(-1, dtype=np.uint64).astype(np.uint32)


def unpack_codes_u32(words: np.ndarray, n: int, bits: int = DNA_BITS) -> np.ndarray:
    words = np.asarray(words, np.uint32)
    cpw = 32 // bits
    shifts = (np.arange(cpw, dtype=np.uint32) * bits).astype(np.uint32)
    lanes = (words[..., :, None] >> shifts) & np.uint32((1 << bits) - 1)
    flat = lanes.reshape(words.shape[:-1] + (words.shape[-1] * cpw,))
    return flat[..., :n].astype(np.uint8)


def encode_bytes(s: bytes) -> np.ndarray:
    return np.frombuffer(s, np.uint8)


def fold_reference(ref_codes: np.ndarray, fragment_len: int,
                   pattern_len: int) -> np.ndarray:
    """Fold a long reference into overlapping per-row fragments (Sec. 3.1-3.2).

    Adjacent fragments overlap by pattern_len - 1 characters so alignments
    spanning a row boundary are still observed ("row replication at array
    boundaries", Sec. 3.2).  Returns (n_rows, fragment_len) uint8; the tail is
    padded with 0 ('A') codes.
    """
    ref_codes = np.asarray(ref_codes, np.uint8)
    step = fragment_len - (pattern_len - 1)
    if step <= 0:
        raise ValueError("fragment_len must exceed pattern_len - 1")
    n_rows = max(1, -(-max(len(ref_codes) - (pattern_len - 1), 1) // step))
    out = np.zeros((n_rows, fragment_len), np.uint8)
    for r in range(n_rows):
        chunk = ref_codes[r * step: r * step + fragment_len]
        out[r, :len(chunk)] = chunk
    return out
