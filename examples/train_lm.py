"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

Exercises the full substrate: synthetic data pipeline -> microbatched
train_step (AdamW, clipping, schedule) -> watchdog -> atomic checkpoints ->
auto-resume.  On a real slice the same driver shards over the production
mesh; here it runs single-device.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import loop

# ~100M parameters: 10L x d640 x ff2560, tied 50k vocab.
CONFIG_100M = ModelConfig(
    name="repro-100m", family="dense",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
    d_ff=2560, vocab=50_304,
    rope_theta=1e4, tie_embeddings=True,
    tp_pad=1, vocab_pad=1, remat=False,
    attn_block_q=128, attn_block_kv=128,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    n_params = cfg.n_params()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")
    opt = adamw.OptConfig(peak_lr=6e-4, warmup_steps=30,
                          decay_steps=args.steps)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=1)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    res = loop.train(cfg, opt, data, args.steps, ckpt=ckpt, ckpt_every=100,
                     log_every=20)
    first = sum(res.losses[:10]) / min(len(res.losses), 10)
    last = sum(res.losses[-10:]) / min(len(res.losses), 10)
    med = sorted(res.step_times)[len(res.step_times) // 2]
    tok_s = args.batch * args.seq / med
    print(f"\nloss {first:.3f} -> {last:.3f} over {res.final_step} steps")
    print(f"median step {med*1e3:.0f} ms ({tok_s:,.0f} tok/s on CPU)")
    print(f"checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
