"""repro.runtime"""
