"""Test-session bootstrap: force host devices for sharding tests.

The mesh/sharding tests (``test_sharding.py``, the shard-map engine
tests, ``test_match_shard.py``) need multiple devices; CI runs on CPU
hosts with a single XLA device unless told otherwise.  Setting
``--xla_force_host_platform_device_count=8`` here -- at conftest import,
before any test module imports jax and freezes the backend -- gives
every run 8 host devices, so those tests exercise the real pjit /
shard_map path instead of skipping.

Subprocess-safe: the flag is appended to ``os.environ`` (respecting any
pre-existing XLA_FLAGS), so subprocess-based tests (``test_dryrun.py``)
inherit a sane value and can still override it per-process.  If jax was
somehow imported before this conftest (or the platform is a real
accelerator where forcing host devices is wrong), we leave the
environment alone and the device-hungry tests skip with their named
"needs >= N devices" reasons -- never a silent wrong-device run.
"""

from __future__ import annotations

import os
import sys

_FORCE = "--xla_force_host_platform_device_count"

if "jax" not in sys.modules and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8").strip()
