"""End-to-end DNA sequence alignment (the paper's running case study).

Builds a synthetic genome slice, folds it across rows into a device-
resident packed corpus (Fig. 3), runs Oracular k-mer scheduling with every
pass streaming through the match engine (the corpus is packed once and
never re-uploaded -- the paper's data-residency discipline), verifies
recovered alignments, and projects the paper-scale run with the calibrated
cost model (Fig. 5 numbers).

Run:  PYTHONPATH=src python examples/dna_alignment.py
"""

import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import encoding
from repro.core.scheduler import schedule_oracular
from repro.core.tech import LONG_TERM, NEAR_TERM
from repro.match import MatchEngine, PackedCorpus


def main() -> None:
    rng = np.random.default_rng(7)
    genome = encoding.random_dna(rng, 200_000)
    frag_len, pat_len = 1000, 100
    corpus = PackedCorpus.from_reference(genome, frag_len, pat_len)
    engine = MatchEngine(corpus)
    frags = corpus.fragments
    print(f"reference {len(genome)} chars folded into {frags.shape[0]} rows "
          f"of {frag_len} (overlap {pat_len - 1})")

    # Sample reads from the genome (with a couple of SNPs each).
    n_reads = 64
    starts = rng.integers(0, len(genome) - pat_len, n_reads)
    reads = np.stack([genome[s:s + pat_len].copy() for s in starts])
    for r in range(n_reads):
        snps = rng.integers(0, pat_len, 2)
        reads[r, snps] = rng.integers(0, 4, 2)

    sched = schedule_oracular(frags, reads, k=12)
    print(f"oracular schedule: {sched.n_passes} passes, "
          f"avg {sched.replication:.1f} candidate rows/read (naive: "
          f"{n_reads} passes x all rows)")

    # Every pass streams only its candidate rows (the Oracular assignment)
    # through the same resident corpus -- a device gather from the packed
    # forms, so the corpus packs on the first pass and is reused untouched
    # afterwards.
    t0 = time.perf_counter()
    recovered = 0
    step = frag_len - (pat_len - 1)
    for assign in sched.passes:
        rows = sorted(assign)
        pats = reads[[assign[r] for r in rows]]
        res = engine.match(pats, backend="swar", mode="per_row", rows=rows,
                           reduction="best")
        for i, row in enumerate(rows):
            if res.best_scores[i] >= pat_len - 2:     # allow the 2 SNPs
                glob = row * step + res.best_locs[i]
                if abs(int(glob) - int(starts[assign[row]])) == 0:
                    recovered += 1
    dt = time.perf_counter() - t0
    print(f"recovered {recovered}/{n_reads} exact alignments in {dt:.2f}s "
          f"(CPU interpret mode; {len(sched.passes)} engine passes, "
          f"{corpus.host_pack_count} corpus pack event(s))")

    print("\npaper-scale projection (3G reference, 3M reads, 300 arrays):")
    for tech in (NEAR_TERM, LONG_TERM):
        for opt in (False, True):
            d = cm.Design(tech=tech, opt=opt)
            r = cm.run_workload(d, 3_000_000, "oracular")
            print(f"  {tech.name:9s} {'Opt' if opt else '   '} "
                  f"{r.total_time_s/3600:10.2f} h  "
                  f"{r.match_rate:12.4g} reads/s  "
                  f"{r.efficiency:8.3g} reads/s/mW")


if __name__ == "__main__":
    main()
