"""Paper Fig. 8: MTJ technology sensitivity (OracularOpt -> OracularOptProj).
Paper anchor: ~2.15x boost in match rate and compute efficiency."""

import time

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM


def run():
    t0 = time.perf_counter()
    near = cm.run_workload(cm.Design(tech=NEAR_TERM, opt=True),
                           3_000_000, "oracular")
    longt = cm.run_workload(cm.Design(tech=LONG_TERM, opt=True),
                            3_000_000, "oracular")
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("fig8/near", round(us, 1), f"rate={near.match_rate:.4g}/s"),
        ("fig8/long", 0.0, f"rate={longt.match_rate:.4g}/s"),
        ("fig8/boost", 0.0,
         f"rate_boost={longt.match_rate/near.match_rate:.3f}x paper=2.15x"
         f" eff_boost={longt.efficiency/near.efficiency:.3f}x"),
    ]
