"""Core LM layers: norms, RoPE, memory-bounded attention, MLP, MoE.

All attention paths are *chunked online-softmax* (flash-style, pure JAX
``lax.scan`` over KV blocks) so the S x S score matrix is never materialized
-- required for the 32k prefill cells to fit, and the natural thing XLA
overlaps with collectives under pjit.

Every ``*_specs`` function returns a pytree of ``spec.P`` declarations whose
logical axes drive sharding: "embed" (d_model), "ff", "heads", "kv_heads",
"vocab", "experts" -> model axis (TP/EP); batch/seq axes are activation-side.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .spec import P

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig) -> Dict[str, P]:
    if cfg.norm == "rms":
        return {"scale": P((cfg.d_model,), ("embed",), "ones")}
    return {"scale": P((cfg.d_model,), ("embed",), "ones"),
            "bias": P((cfg.d_model,), ("embed",), "zeros")}


def apply_norm(cfg: ModelConfig, p, x):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rms":
        var = jnp.mean(jnp.square(x32), -1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    else:
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.var(x32, -1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return out.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, H, S, D); positions: (B, S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.padded_heads, cfg.padded_kv_heads
    specs: Dict[str, Any] = {
        "wq": P((d, H, hd), ("embed", "heads", None)),
        "wk": P((d, K, hd), ("embed", "kv_heads", None)),
        "wv": P((d, K, hd), ("embed", "kv_heads", None)),
        "wo": P((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = P((H, hd), ("heads", None), "zeros")
        specs["bk"] = P((K, hd), ("kv_heads", None), "zeros")
        specs["bv"] = P((K, hd), ("kv_heads", None), "zeros")
    return specs


def _pick_block(skv: int, max_blk: int) -> int:
    """Largest divisor of skv that is <= max_blk (whisper's 1500 frames)."""
    b = min(max_blk, skv)
    while skv % b:
        b -= 1
    return b


def _online_softmax_scan(q, k, v, *, causal: bool, window: Optional[int],
                         q_offset, block_kv: int, bidir: bool = False):
    """q (B,H,Sq,D); k,v (B,K,Skv,D) -> (B,H,Sq,D).  Never materializes the
    full score matrix; scans KV blocks with a running (max, denom, acc)."""
    B, H, Sq, D = q.shape
    _, K, Skv, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(D)
    nb = Skv // block_kv
    assert nb * block_kv == Skv, "Skv must be divisible by block_kv"
    qg = q.reshape(B, K, G, Sq, D)
    kb = jnp.moveaxis(k.reshape(B, K, nb, block_kv, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, K, nb, block_kv, D), 2, 0)
    q_pos = q_offset[:, None] + jnp.arange(Sq)[None, :]      # (B, Sq)

    def body(carry, blk):
        m, l, acc, j = carry
        k_j, v_j = blk
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, k_j,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = j * block_kv + jnp.arange(block_kv)          # (C,)
        if not bidir:
            mask = q_pos[:, None, None, :, None] >= kv_pos[None, None, None, None, :]
            if window is not None:
                mask &= (q_pos[:, None, None, :, None]
                         - kv_pos[None, None, None, None, :]) < window
            s = jnp.where(mask, s, -1e30)
        new_m = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - new_m)
        p = jnp.exp(s - new_m[..., None])
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32)
        return (new_m, l, acc, j + 1), None

    m0 = jnp.full((B, K, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def _local_block_attention(q, k, v, *, window: int):
    """Sliding-window causal attention in block-local form: each query chunk
    of size `window` attends to (previous, self) chunks only -- linear in S,
    scanned over chunks so only one (w x 2w) score tile is live at a time.
    Shapes as in _online_softmax_scan; requires Sq == Skv divisible by
    window."""
    B, H, S, D = q.shape
    _, K, _, _ = k.shape
    G = H // K
    w = window
    nc = S // w
    assert nc * w == S
    scale = 1.0 / math.sqrt(D)
    qg = jnp.moveaxis(q.reshape(B, K, G, nc, w, D), 3, 0)   # (nc,B,K,G,w,D)
    kc = jnp.moveaxis(k.reshape(B, K, nc, w, D), 2, 0)      # (nc,B,K,w,D)
    vc = jnp.moveaxis(v.reshape(B, K, nc, w, D), 2, 0)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:1]), kc[:-1]], 0)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:1]), vc[:-1]], 0)
    qi = jnp.arange(w)[:, None] + w                # position within 2w window
    ki = jnp.arange(2 * w)[None, :]
    mask = (qi >= ki) & ((qi - ki) < w)            # (w, 2w)
    mask0 = mask & (jnp.arange(2 * w)[None, :] >= w)

    def body(_, blk):
        qi_, kp, kk, vp, vv, is_first = blk
        k2 = jnp.concatenate([kp, kk], 2)          # (B,K,2w,D)
        v2 = jnp.concatenate([vp, vv], 2)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qi_, k2,
                       preferred_element_type=jnp.float32) * scale
        m = jnp.where(is_first, mask0, mask)
        s = jnp.where(m[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(v2.dtype), v2,
                       preferred_element_type=jnp.float32)
        return 0, o.astype(qi_.dtype)

    is_first = jnp.arange(nc) == 0
    _, outs = jax.lax.scan(body, 0, (qg, kprev, kc, vprev, vc, is_first))
    out = jnp.moveaxis(outs, 0, 3)                 # (B,K,G,nc,w,D)
    return out.reshape(B, H, S, D).astype(q.dtype)


def attention_apply(cfg: ModelConfig, p, x, *, positions, mode: str,
                    cache: Optional[Dict] = None, cache_index=None,
                    local: bool = False, bidir: bool = False,
                    xa: Optional[jnp.ndarray] = None):
    """Full attention sub-layer (projections + mixing + out projection).

    mode: "full" (train/prefill over the whole sequence) or "decode"
    (one new token against the cache).  Returns (out, new_cache).
    cache: {"k","v": (B, K, S_max, hd)} -- updated functionally.
    ``xa``: encoder output for cross-attention (whisper); cross-attn caches
    are precomputed K/V over xa.
    """
    B = x.shape[0]
    H, K, hd = cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
    if mode == "decode" and xa is not None:
        k = v = None    # cross-attn decode reads precomputed enc K/V cache
    else:
        kv_src = xa if xa is not None else x
        k = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wv"].astype(x.dtype))
        if "bk" in p:
            k = k + p["bk"].astype(x.dtype)[None, :, None, :]
            v = v + p["bv"].astype(x.dtype)[None, :, None, :]

    use_rope = cfg.rope_theta > 0 and xa is None
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)

    window = cfg.local_window if local else None

    if mode == "full":
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        new_cache = None
        offset = cache_index if cache_index is not None else 0
        if cache is not None and xa is None:
            if cfg.kv_quant:
                kq, ks = _kv_quantize(k)
                vq, vs = _kv_quantize(v)
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], kq, (0, 0, offset, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], vq, (0, 0, offset, 0)),
                    "k_scale": jax.lax.dynamic_update_slice(
                        cache["k_scale"], ks, (0, 0, offset)),
                    "v_scale": jax.lax.dynamic_update_slice(
                        cache["v_scale"], vs, (0, 0, offset)),
                }
            else:
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype),
                        (0, 0, offset, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype),
                        (0, 0, offset, 0)),
                }
        elif cache is not None:
            # cross-attention: cache precomputed encoder K/V (full length).
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            }

        # Chunked continuation (speculative verify / chunked prefill): when
        # writing at a nonzero offset, queries must attend the cached
        # context too, so the KV source becomes the updated cache; the
        # causal mask (q_pos = offset + i) hides stale higher positions.
        continuation = (cache is not None and xa is None
                        and cache_index is not None)
        if continuation:
            if cfg.kv_quant:
                kk = (new_cache["k"].astype(COMPUTE_DTYPE)
                      * new_cache["k_scale"][..., None].astype(COMPUTE_DTYPE))
                vv = (new_cache["v"].astype(COMPUTE_DTYPE)
                      * new_cache["v_scale"][..., None].astype(COMPUTE_DTYPE))
            else:
                kk, vv = new_cache["k"], new_cache["v"]
            kk = kk.astype(q.dtype)
            vv = vv.astype(q.dtype)
        else:
            kk, vv = k, v
        q_off = (offset + jnp.zeros((B,), jnp.int32)
                 if continuation else jnp.zeros((B,), jnp.int32))

        blk = _pick_block(kk.shape[2], cfg.attn_block_kv)
        if xa is not None or bidir:
            out = _online_softmax_scan(
                q, k, v, causal=False, window=None,
                q_offset=jnp.zeros((B,), jnp.int32),
                block_kv=_pick_block(k.shape[2], cfg.attn_block_kv),
                bidir=True)
        elif local and not continuation and kk.shape[2] % cfg.local_window == 0:
            out = _local_block_attention(q, kk, vv, window=cfg.local_window)
        elif local:
            out = _online_softmax_scan(
                q, kk, vv, causal=True, window=cfg.local_window,
                q_offset=q_off, block_kv=blk)
        else:
            out = _online_softmax_scan(
                q, kk, vv, causal=True, window=window,
                q_offset=q_off, block_kv=blk)
    elif mode == "decode":
        assert cache is not None
        k_scale = v_scale = None
        if xa is None:
            if use_rope:
                k = apply_rope(k, positions, cfg.rope_theta)
            # cache_index: scalar (all rows write one position) or (B,)
            # vector (each batch row writes its own position -- serving
            # slots whose sequence lengths diverge).
            ci = jnp.asarray(cache_index)
            ci_b = (ci + jnp.zeros((B,), jnp.int32) if ci.ndim == 0
                    else ci.astype(jnp.int32))
            if ci.ndim == 0:
                def write(buf, val):
                    idx = (0, 0, cache_index) + (0,) * (buf.ndim - 3)
                    return jax.lax.dynamic_update_slice(
                        buf, val.astype(buf.dtype), idx)
            else:
                b_idx = jnp.arange(B)

                def write(buf, val):
                    # val is (B, K, 1[, hd]); scatter row b at ci_b[b].
                    return buf.at[b_idx, :, ci_b].set(
                        val[:, :, 0].astype(buf.dtype))
            if cfg.kv_quant:
                kq, ks = _kv_quantize(k)
                vq, vs = _kv_quantize(v)
                ck = write(cache["k"], kq)
                cv = write(cache["v"], vq)
                cks = write(cache["k_scale"], ks)
                cvs = write(cache["v_scale"], vs)
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
                k_scale, v_scale = cks, cvs
            else:
                ck = write(cache["k"], k)
                cv = write(cache["v"], v)
                new_cache = {"k": ck, "v": cv}
            kk, vv = ck, cv
            S_max = kk.shape[2]
            kv_pos = jnp.arange(S_max)
            valid = kv_pos[None, :] <= ci_b[:, None]
            if window is not None:
                valid &= (ci_b[:, None] - kv_pos[None, :]) < window
        else:
            # cross-attention decode: cache holds precomputed enc K/V.
            kk, vv = cache["k"], cache["v"]
            if cfg.kv_quant:
                k_scale, v_scale = cache["k_scale"], cache["v_scale"]
            new_cache = cache
            valid = jnp.ones((B, kk.shape[2]), bool)
        G = H // K
        qg = q.reshape(B, K, G, 1, hd)
        # int8 cache: the per-(b,k,s) scale is constant over hd, so it folds
        # *outside* the dots -- the MXU operands stay quantized and the
        # dequantized bf16 cache is never materialized (exact algebra).
        s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kk.astype(q.dtype),
                       preferred_element_type=jnp.float32) / math.sqrt(hd)
        if k_scale is not None:
            s = s * k_scale[:, :, None, None, :]
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        if v_scale is not None:
            pr = pr * v_scale[:, :, None, None, :]
        out = jnp.einsum("bkgqs,bksd->bkgqd", pr.astype(COMPUTE_DTYPE),
                         vv.astype(COMPUTE_DTYPE),
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, H, 1, hd).astype(x.dtype)
    else:
        raise ValueError(mode)

    y = jnp.einsum("bhsk,hkd->bsd", out.astype(x.dtype),
                   p["wo"].astype(x.dtype))
    return y, new_cache


def attn_cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, P]:
    K, hd = cfg.padded_kv_heads, cfg.head_dim
    ax = ("batch", "kv_heads", None, None)
    if cfg.kv_quant:
        sax = ("batch", "kv_heads", None)
        return {
            "k": P((batch, K, seq_len, hd), ax, "zeros", jnp.int8),
            "v": P((batch, K, seq_len, hd), ax, "zeros", jnp.int8),
            "k_scale": P((batch, K, seq_len), sax, "zeros", jnp.float32),
            "v_scale": P((batch, K, seq_len), sax, "zeros", jnp.float32),
        }
    return {"k": P((batch, K, seq_len, hd), ax, "zeros", COMPUTE_DTYPE),
            "v": P((batch, K, seq_len, hd), ax, "zeros", COMPUTE_DTYPE)}


def _kv_quantize(x: jnp.ndarray):
    """(B,K,S,hd) -> (int8 values, f32 scale (B,K,S)).  Symmetric per-token
    per-head scaling; exact dequant is x_q * scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig) -> Dict[str, P]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        return {"wg": P((d, f), ("embed", "ff")),
                "wu": P((d, f), ("embed", "ff")),
                "wd": P((f, d), ("ff", "embed"))}
    return {"wi": P((d, f), ("embed", "ff")),
            "bi": P((f,), ("ff",), "zeros"),
            "wo": P((f, d), ("ff", "embed")),
            "bo": P((d,), ("embed",), "zeros")}


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.act == "silu":
        g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
        u = x @ p["wu"].astype(x.dtype)
        return (g * u) @ p["wd"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["wi"].astype(x.dtype) + p["bi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype) + p["bo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (GShard-style grouped capacity dispatch)
# ---------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig) -> Dict[str, P]:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    return {
        "router": P((d, E), ("embed", "experts")),
        "wg": P((E, d, f), ("experts", "embed", "ff")),
        "wu": P((E, d, f), ("experts", "embed", "ff")),
        "wd": P((E, f, d), ("experts", "ff", "embed")),
    }


def moe_apply(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out, aux_loss).  Token-choice top-k with per-group
    capacity; dispatch/combine as einsums so EP sharding lowers to
    all-to-alls under pjit (DESIGN.md sharding map)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    Sg = min(cfg.moe_group_size, B * S)
    T = B * S
    G = T // Sg
    assert G * Sg == T, "tokens must divide the MoE group size"
    xt = x.reshape(G, Sg, d)
    logits = jnp.einsum("gsd,de->gse", xt, p["router"].astype(x.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    probs, idx = jax.lax.top_k(gates, k)                    # (G,Sg,k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    C = max(int(k * Sg * cfg.capacity_factor / E), 4)

    dispatch = jnp.zeros((G, Sg, E, C), COMPUTE_DTYPE)
    combine = jnp.zeros((G, Sg, E, C), jnp.float32)
    counts = jnp.zeros((G, 1, E), jnp.int32)
    for slot in range(k):
        mask = jax.nn.one_hot(idx[:, :, slot], E, dtype=jnp.int32)  # (G,Sg,E)
        pos = jnp.cumsum(mask, axis=1) - 1 + counts
        keep = (pos < C) & (mask > 0)
        pos1h = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                               dtype=COMPUTE_DTYPE)[..., :C]       # (G,Sg,E,C)
        dispatch = dispatch + pos1h
        combine = combine + pos1h.astype(jnp.float32) * probs[:, :, slot][..., None, None]
        counts = counts + mask.sum(axis=1, keepdims=True)

    ein = jnp.einsum("gsec,gsd->gecd", dispatch, xt,
                     preferred_element_type=COMPUTE_DTYPE)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, p["wg"].astype(ein.dtype)))
    u = jnp.einsum("gecd,edf->gecf", ein, p["wu"].astype(ein.dtype))
    eo = jnp.einsum("gecf,efd->gecd", h * u, p["wd"].astype(ein.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(eo.dtype), eo)

    # Load-balance aux loss (Switch): E * sum_e f_e * P_e.
    f_e = jax.nn.one_hot(idx[:, :, 0], E).mean(axis=(0, 1))
    p_e = gates.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return out.reshape(B, S, d), aux.astype(jnp.float32)
