"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two
    pods.  Uses the first prod(shape) available devices so a 512-way
    host-platform dry-run can build both meshes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} -- "
            "run under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires forced host devices)."""
    shape = ((2, n_data, n_model) if multi_pod else (n_data, n_model))
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_row_mesh(n_shards: int):
    """1-D ``("data",)`` mesh for row-sharded match engines.

    The match stack shards corpus rows over the mesh's row axes (logical
    axis ``rows`` -> ``data`` under the default rules, DESIGN.md
    Sec. 3h); a pure data mesh gives it exactly ``n_shards`` row shards
    with no idle model axis.
    """
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for a {n_shards}-shard row mesh, "
            f"have {len(devices)} -- force host devices via XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N")
    return jax.make_mesh((n_shards,), ("data",), devices=devices[:n_shards])
