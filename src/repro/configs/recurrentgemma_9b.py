"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  Pattern (rglru, rglru, local_attn) x 12 + (rglru, rglru);
local window 2048; tied embeddings.  Sub-quadratic -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256_000,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048, rnn_width=4096,
    rope_theta=1e4, act="gelu", norm="rms", tie_embeddings=True,
    microbatch=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=16, rnn_width=64,
    rope_theta=1e4, act="gelu", tie_embeddings=True,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=16, attn_block_kv=16,
)
