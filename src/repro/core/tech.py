"""Technology parameters for CRAM-PM (paper Table 3), TPU roofline constants,
and the ``CostSource`` abstraction that prices kernel dispatches for the
match planner (static datasheet fallback vs. measured calibration --
DESIGN.md Sec. 3i).

Two MTJ technology points are modeled, exactly as in the paper:

* ``NEAR_TERM``  -- 45 nm interfacial PMTJ, demonstrated-device numbers.
* ``LONG_TERM``  -- 10 nm projected device.

The paper derives gate latency/energy assuming a conservative multiplier on the
50%-switching-probability critical current (2x near-term, 5x long-term) to keep
the write error rate low; we expose that multiplier explicitly.

Peripheral (row decoder / mux / precharge / sense-amp) overheads are modeled
after NVSIM at 22 nm as the paper does.  NVSIM itself is not redistributable,
so the constants below are fixed calibration values chosen to reproduce the
paper's reported shares (Fig. 6: preset 43.86% energy / 97.25% latency,
BL driver <1% energy / 2.7% latency, write <1%/<1%); the calibration is
asserted by ``tests/test_costmodel.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional


@dataclasses.dataclass(frozen=True)
class MTJTech:
    """One column of paper Table 3 (plus the WER guard-band multiplier)."""

    name: str
    mtj_diameter_nm: float
    tmr_pct: float                 # tunnel magneto-resistance ratio
    ra_product_ohm_um2: float
    i_crit_ua: float               # 50%-switching critical current
    i_crit_multiplier: float       # WER guard band (2x near / 5x long, Sec. 4)
    switching_latency_ns: float    # MTJ free-layer switching time
    r_p_kohm: float                # parallel (logic 0) resistance
    r_ap_kohm: float               # anti-parallel (logic 1) resistance
    write_latency_ns: float
    read_latency_ns: float
    write_energy_pj: float         # per cell
    read_energy_pj: float          # per cell

    @property
    def i_crit_eff_ua(self) -> float:
        """Effective switching threshold used for gate design (Sec. 4)."""
        return self.i_crit_ua * self.i_crit_multiplier

    @property
    def r_p_ohm(self) -> float:
        return self.r_p_kohm * 1e3

    @property
    def r_ap_ohm(self) -> float:
        return self.r_ap_kohm * 1e3


NEAR_TERM = MTJTech(
    name="near-term",
    mtj_diameter_nm=45.0,
    tmr_pct=133.0,
    ra_product_ohm_um2=5.0,
    i_crit_ua=100.0,
    i_crit_multiplier=2.0,
    switching_latency_ns=3.0,
    r_p_kohm=3.15,
    r_ap_kohm=7.34,
    write_latency_ns=3.65,
    read_latency_ns=1.21,
    write_energy_pj=0.36,
    read_energy_pj=0.83,
)

LONG_TERM = MTJTech(
    name="long-term",
    mtj_diameter_nm=10.0,
    tmr_pct=500.0,
    ra_product_ohm_um2=1.0,
    i_crit_ua=3.95,
    i_crit_multiplier=5.0,
    switching_latency_ns=1.0,
    r_p_kohm=12.7,
    r_ap_kohm=76.39,
    write_latency_ns=1.72,
    read_latency_ns=1.24,
    write_energy_pj=0.308,
    read_energy_pj=0.78,
)

TECHS = {t.name: t for t in (NEAR_TERM, LONG_TERM)}

# Paper-reported V_gate windows (Table 3) -- used as a sanity reference by the
# gate-model tests (our analytically derived windows must preserve ordering and
# overlap the reported ranges after series-resistance calibration).
PAPER_VGATE_V = {
    "near-term": {
        "INV": (0.84, 1.30), "COPY": (0.84, 1.30), "NOR": (0.68, 0.74),
        "MAJ3": (0.65, 0.69), "MAJ5": (0.61, 0.62), "TH": (0.62, 0.63),
    },
    "long-term": {
        "INV": (0.23, 0.48), "COPY": (0.23, 0.48), "NOR": (0.20, 0.22),
        "MAJ3": (0.20, 0.21), "MAJ5": (0.19, 0.20), "TH": (0.19, 0.20),
    },
}


@dataclasses.dataclass(frozen=True)
class ArrayGeometry:
    """CRAM-PM array geometry (Sec. 3.4 / Sec. 4)."""

    n_rows: int = 512
    n_cols: int = 512
    # Max row width at 22nm with 160nm Cu LL segments (Sec. 3.4): ~2K cells.
    max_row_cells: int = 2048
    # Latency penalty of max-distance LL drive relative to MTJ switching time.
    ll_rc_penalty: float = 0.017


@dataclasses.dataclass(frozen=True)
class Periphery:
    """Peripheral circuit overheads (NVSIM-style, 22 nm), per array access.

    Calibrated so the step-accurate model reproduces the paper's Fig. 6
    shares; see module docstring.
    """

    # Row decoder + mux + precharge latency charged once per micro-op issue.
    decode_latency_ns: float = 0.42
    decode_energy_pj: float = 0.9
    # Bit-line driver: charged per activated BSL column per micro-op.
    bl_drive_latency_ns: float = 0.08
    bl_drive_energy_pj: float = 0.0035
    # Sense amplifier: reads only (computation excludes SAs entirely, Sec 3.4).
    sense_latency_ns: float = 0.30
    sense_energy_pj: float = 0.05
    # SMC micro-instruction issue overhead (decode from LUT + sequencing).
    smc_issue_latency_ns: float = 0.25
    smc_issue_energy_pj: float = 0.4


@dataclasses.dataclass(frozen=True)
class TPURoofline:
    """TPU v5e-class target constants for the roofline analysis (assignment)."""

    peak_bf16_flops: float = 197e12       # per chip
    hbm_bw: float = 819e9                 # bytes/s per chip
    ici_link_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9               # capacity per chip
    vmem_bytes: float = 128 * 2**20       # ~128 MiB VMEM per chip
    mxu_tile: int = 128                   # systolic dimension
    lane_width: int = 128                 # VPU lanes
    sublane_width: int = 8                # VPU sublanes


TPU_V5E = TPURoofline()

# Per-kernel-dispatch overhead (host launch + program switch) the *static*
# cost source charges; calibrated sources replace it with a measured
# per-kernel intercept.  Calibrated order-of-magnitude for a real TPU.
DISPATCH_OVERHEAD_S = 5e-6
# The jnp reference path runs on the host with per-call framework overhead
# well above a fused Pallas launch.
REF_CALL_OVERHEAD_S = 5e-5


class CostSource:
    """Prices one kernel dispatch from its analytic roofline seconds.

    The planner computes each kernel's *analytic* cost -- op and byte
    counts against the ``TPURoofline`` constants, ``max(compute, mem)`` --
    and asks the active source to turn that into wall seconds.  Two
    implementations exist:

    * ``StaticCostSource`` -- the datasheet model: analytic seconds plus a
      fixed per-dispatch overhead.  This is the uncalibrated *fallback*;
      on any substrate other than the one the constants describe (a CPU
      container in interpret mode, a different TPU generation, a different
      host), its absolute numbers -- and therefore its *decisions* -- are
      fiction, exactly the failure mode the paper's Sec. 4 methodology
      (device-level parameter extraction before any system claim) exists
      to avoid.
    * ``CalibratedCostSource`` -- per-kernel curves fitted from
      microbenchmarks of the actual kernels on the current backend
      (``repro.match.calibrate``): measured overhead factor over the
      analytic model plus a measured per-dispatch intercept, so unseen
      shapes interpolate through the same analytic arithmetic instead of
      a lookup table.

    ``tag`` is the provenance string recorded in every ``Plan.reason``
    and BENCH artifact ("static" or "calibrated:<digest8>").
    """

    name = "abstract"

    def price(self, kernel: str, analytic_s: float,
              n_dispatch: int = 1) -> float:
        raise NotImplementedError

    @property
    def tag(self) -> str:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.tag})"


@dataclasses.dataclass(frozen=True)
class StaticCostSource(CostSource):
    """Datasheet pricing: analytic roofline + fixed dispatch overhead."""

    dispatch_overhead_s: float = DISPATCH_OVERHEAD_S
    ref_call_overhead_s: float = REF_CALL_OVERHEAD_S
    name = "static"

    def price(self, kernel: str, analytic_s: float,
              n_dispatch: int = 1) -> float:
        per = (self.ref_call_overhead_s if kernel == "ref"
               else self.dispatch_overhead_s)
        return analytic_s + n_dispatch * per

    @property
    def tag(self) -> str:
        return "static"


@dataclasses.dataclass(frozen=True)
class KernelCurve:
    """One kernel's fitted cost curve: measured = alpha*analytic + beta.

    ``alpha`` is the measured overhead factor over the analytic op/byte
    model (the SNIPPETS.md Sec. 2 idiom: measured cycles / pure-FMACS
    cycles); ``beta`` is the measured per-dispatch intercept (launch,
    program switch, interpreter setup).  Both are fitted under
    positivity constraints, so calibrated pricing inherits the analytic
    model's monotonicity in R, P and Q.
    """

    alpha: float                  # overhead factor (> 0)
    beta: float                   # per-dispatch fixed seconds (>= 0)
    n_samples: int = 0
    rel_err: float = 0.0          # max relative residual of the fit

    def seconds(self, analytic_s: float, n_dispatch: int = 1) -> float:
        return self.alpha * analytic_s + n_dispatch * self.beta


class CalibratedCostSource(CostSource):
    """Measured per-kernel curves; unknown kernels fall back to static."""

    name = "calibrated"

    def __init__(self, curves: Mapping[str, KernelCurve], *, digest: str,
                 meta: Optional[Mapping] = None,
                 fallback: Optional[CostSource] = None):
        self.curves: Dict[str, KernelCurve] = dict(curves)
        self.digest = str(digest)
        self.meta = dict(meta or {})
        self.fallback = fallback or StaticCostSource()

    def price(self, kernel: str, analytic_s: float,
              n_dispatch: int = 1) -> float:
        curve = self.curves.get(kernel)
        if curve is None:
            return self.fallback.price(kernel, analytic_s, n_dispatch)
        return curve.seconds(analytic_s, n_dispatch)

    @property
    def tag(self) -> str:
        return f"calibrated:{self.digest[:8]}"

# Conservative series resistance seen by each cell's current path (access
# transistor on-resistance + LL interconnect segment).  Single calibration
# knob for the analog gate model; chosen so near-term gate windows land on
# the paper's Table 3 values (NOR (0.68,0.74), MAJ3 (0.65,0.69), INV/COPY
# (0.84,1.30) -- see tests/test_gates.py).
R_SERIES_OHM = 1500.0
