"""CRAM-PM pattern matching: Fig. 3 data layout + Algorithm 1.

Each array row holds ``| fragment | pattern | match-string | score/scratch |``
(2 bits per character).  For every alignment location ``loc``:

* **Phase 1 (match)** -- per character: two bit-level XORs (each the 3-step
  NOR/COPY/TH sequence) + one NOR produce one match bit (Fig. 4a).
* **Phase 2 (score)** -- a reduction tree of MAJ-gate full adders pops the
  match string into an N-bit similarity score (Fig. 4b).

One gate executes per row at a time; all rows run in lock step (Sec. 2.4) --
which is exactly what the array interpreter in ``array.py`` implements.

``sliding_scores`` is the NumPy oracle used by tests; the TPU fast path lives
in ``repro.kernels`` (same semantics, packed SWAR / MXU one-hot).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from . import encoding
from .array import CRAMArray, Program
from .isa import CodeGen, ColumnAllocator


@dataclasses.dataclass(frozen=True)
class RowLayout:
    """Column map of one CRAM-PM row (Fig. 3)."""

    fragment_chars: int
    pattern_chars: int
    n_cols: int

    @property
    def frag_lo(self) -> int:
        return 0

    @property
    def pat_lo(self) -> int:
        return 2 * self.fragment_chars

    @property
    def match_lo(self) -> int:
        return self.pat_lo + 2 * self.pattern_chars

    @property
    def scratch_lo(self) -> int:
        return self.match_lo + self.pattern_chars

    @property
    def score_bits(self) -> int:
        return int(np.floor(np.log2(self.pattern_chars))) + 1

    @property
    def n_alignments(self) -> int:
        return self.fragment_chars - self.pattern_chars + 1

    def frag_bit_cols(self, char_idx: int) -> Tuple[int, int]:
        return (2 * char_idx, 2 * char_idx + 1)

    def pat_bit_cols(self, char_idx: int) -> Tuple[int, int]:
        return (self.pat_lo + 2 * char_idx, self.pat_lo + 2 * char_idx + 1)


def plan_layout(n_cols: int, pattern_chars: int,
                scratch_budget: int = 48) -> RowLayout:
    """Maximal fragment length for a given row width (Sec. 3.1: fragment
    length is the design parameter bounded by the ~2K-cell row limit)."""
    score = int(np.floor(np.log2(pattern_chars))) + 1
    avail = n_cols - 2 * pattern_chars - pattern_chars - score - scratch_budget
    frag = avail // 2
    if frag < pattern_chars:
        raise ValueError("row too narrow for this pattern length")
    return RowLayout(frag, pattern_chars, n_cols)


def compile_alignment(layout: RowLayout, loc: int, opt: bool = False
                      ) -> Tuple[Program, List[int]]:
    """Micro-program for one iteration of Algorithm 1 at location ``loc``.

    Returns (program, score_columns little-endian).  ``opt`` selects the
    gang-preset schedule (NaiveOpt/OracularOpt) -- functionally identical,
    priced differently by the cost model.
    """
    if not 0 <= loc < layout.n_alignments:
        raise ValueError("loc out of range")
    # Consumed match-string columns may be recycled by the reduction tree
    # (reuse_lo = match_lo): that is how Phase 2 fits in the ~2K-cell row.
    scratch = ColumnAllocator(layout.scratch_lo, layout.n_cols,
                              reuse_lo=layout.match_lo)
    cg = CodeGen(scratch, opt=opt)
    # Phase 1: aligned comparison -> match string.
    for i in range(layout.pattern_chars):
        f0, f1 = layout.frag_bit_cols(loc + i)
        p0, p1 = layout.pat_bit_cols(i)
        m = cg.char_match(f0, f1, p0, p1)
        # Move the match bit to its dedicated compartment column.
        cg.gate("COPY", (m,), layout.match_lo + i)
        cg.scratch.release([m])
    # Phase 2: similarity score = popcount of the match string.
    match_cols = [layout.match_lo + i for i in range(layout.pattern_chars)]
    score_cols = cg.popcount_tree(match_cols)
    return cg.prog, score_cols


def count_alignment_ops(pattern_chars: int, n_cols: int = 2048,
                        opt: bool = False) -> dict:
    """Static op-count census of one alignment (drives the cost model)."""
    layout = plan_layout(n_cols, pattern_chars)
    prog, score_cols = compile_alignment(layout, 0, opt=opt)
    counts = prog.op_counts()
    counts["TOTAL_LOGIC"] = prog.n_logic_ops()
    gang, row = prog.n_presets()
    counts["PRESETS"] = gang + row
    counts["SCORE_BITS"] = len(score_cols)
    counts["FA_COUNT"] = counts.get("MAJ3", 0)
    return counts


class Matcher:
    """Run Algorithm 1 on a functional CRAM-PM array."""

    def __init__(self, fragments: np.ndarray, pattern_chars: int,
                 n_cols: int | None = None, opt: bool = True):
        fragments = np.asarray(fragments, np.uint8)
        n_rows, frag_chars = fragments.shape
        if n_cols is None:
            # Tight layout: just enough room for this fragment length.
            score = int(np.floor(np.log2(pattern_chars))) + 1
            n_cols = 2 * frag_chars + 3 * pattern_chars + score + 48
        self.layout = RowLayout(frag_chars, pattern_chars, n_cols)
        self.opt = opt
        self.array = CRAMArray(n_rows, n_cols)
        self.array.write_column_rows(0, encoding.codes_to_bits(fragments))
        self._programs: dict[int, Tuple[Program, List[int]]] = {}

    def load_pattern(self, pattern: np.ndarray) -> None:
        """Same pattern distributed across all rows (paper's default)."""
        bits = encoding.codes_to_bits(np.asarray(pattern, np.uint8)[None, :])
        self.array.write_column_rows(
            self.layout.pat_lo, np.repeat(bits, self.array.n_rows, axis=0))

    def load_patterns_per_row(self, patterns: np.ndarray) -> None:
        """Oracular-style: a (possibly) different pattern per row."""
        assert patterns.shape[0] == self.array.n_rows
        self.array.write_column_rows(
            self.layout.pat_lo, encoding.codes_to_bits(patterns))

    def _program_for(self, loc: int) -> Tuple[Program, List[int]]:
        if loc not in self._programs:
            self._programs[loc] = compile_alignment(self.layout, loc, self.opt)
        return self._programs[loc]

    def run(self, locs: range | None = None) -> np.ndarray:
        """Execute Algorithm 1; returns scores (n_rows, n_locs) uint16."""
        locs = locs if locs is not None else range(self.layout.n_alignments)
        scores = np.zeros((self.array.n_rows, len(locs)), np.uint16)
        for j, loc in enumerate(locs):
            prog, score_cols = self._program_for(loc)
            self.array.run(prog)
            bits = np.stack(
                [self.array.read_columns(c, 1)[:, 0] for c in score_cols], -1)
            weights = (1 << np.arange(len(score_cols))).astype(np.uint16)
            scores[:, j] = (bits.astype(np.uint16) * weights).sum(-1)
        return scores


def sliding_scores(fragments: np.ndarray, patterns: np.ndarray) -> np.ndarray:
    """NumPy oracle: per-row, per-alignment character-match counts.

    fragments: (R, F) uint8 codes; patterns: (P,) shared or (R, P) per-row.
    Returns (R, F-P+1) int32.
    """
    fragments = np.asarray(fragments)
    patterns = np.asarray(patterns)
    if patterns.ndim == 1:
        patterns = np.broadcast_to(patterns, (fragments.shape[0],) + patterns.shape)
    R, F = fragments.shape
    P = patterns.shape[1]
    n_locs = F - P + 1
    windows = np.lib.stride_tricks.sliding_window_view(fragments, P, axis=1)
    # windows: (R, n_locs, P)
    return (windows == patterns[:, None, :]).sum(-1).astype(np.int32)[:, :n_locs]


def sliding_scores_masks(fragments: np.ndarray,
                         masks: np.ndarray) -> np.ndarray:
    """NumPy oracle for accept-set predicates (wildcards / IUPAC).

    fragments: (R, F) uint8 codes; masks: (P,) shared or (R, P) per-row
    uint8 accept masks (bit c set iff code c accepted).  Returns
    (R, F-P+1) int32 counts of accepted positions.  One-hot masks reduce
    this to ``sliding_scores`` exactly.
    """
    fragments = np.asarray(fragments)
    masks = np.asarray(masks, np.uint8)
    if masks.ndim == 1:
        masks = np.broadcast_to(masks, (fragments.shape[0],) + masks.shape)
    R, F = fragments.shape
    P = masks.shape[1]
    n_locs = F - P + 1
    windows = np.lib.stride_tricks.sliding_window_view(fragments, P, axis=1)
    hits = (masks[:, None, :] >> windows) & 1
    return hits.sum(-1).astype(np.int32)[:, :n_locs]


def best_alignment(scores: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row (best_loc, best_score) -- what the host extracts (Sec. 3.2)."""
    locs = scores.argmax(axis=1)
    return locs, scores[np.arange(scores.shape[0]), locs]
