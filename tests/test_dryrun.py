"""Dry-run machinery tests.

The production dry-run needs 512 forced host devices, which must be set
before jax initializes -- so these tests exercise it via subprocesses
(exactly how the real launcher runs).  The multi-device sharding tests in
test_sharding.py are also driven here under a forced-device environment.
"""

import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
ENV_BASE = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def run(cmd, env=None, timeout=560):
    return subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, cwd=REPO, env=env or ENV_BASE)


class TestShardingUnderForcedDevices:
    def test_sharding_suite_with_8_devices(self):
        env = dict(ENV_BASE,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        r = run([sys.executable, "-m", "pytest", "tests/test_sharding.py",
                 "-q", "-p", "no:cacheprovider"], env=env)
        assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


class TestProductionDryrun:
    @pytest.mark.parametrize("arch,shape", [
        ("llama3.2-1b", "decode_32k"),
        ("mamba2-130m", "long_500k"),
    ])
    def test_single_cell_compiles(self, tmp_path, arch, shape):
        out = tmp_path / "cell.jsonl"
        r = run([sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", "pod",
                 "--out", str(out)])
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(out.read_text().strip())
        assert rec["status"] == "ok", rec
        assert rec["n_devices"] == 256
        assert rec["hlo_flops_per_dev"] > 0
        assert rec["dominant"] in ("compute", "memory", "collective")

    def test_multipod_mesh_cell(self, tmp_path):
        out = tmp_path / "cell.jsonl"
        r = run([sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", "llama3.2-1b", "--shape", "decode_32k",
                 "--mesh", "multipod", "--out", str(out)])
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(out.read_text().strip())
        assert rec["status"] == "ok"
        assert rec["n_devices"] == 512
        assert rec["mesh"] == "2x16x16"

    def test_skip_recorded_for_full_attention_long(self, tmp_path):
        out = tmp_path / "cell.jsonl"
        r = run([sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", "llama3.2-1b", "--shape", "long_500k",
                 "--mesh", "pod", "--out", str(out)])
        assert r.returncode == 0
        rec = json.loads(out.read_text().strip())
        assert rec["status"] == "skipped"
        assert "sub-quadratic" in rec["reason"]


class TestBaselineSweepRecords:
    """Validates the committed baseline sweep (experiments/dryrun)."""

    def test_all_cells_present_and_ok(self):
        path = REPO / "experiments/dryrun/full.jsonl"
        if not path.exists():
            pytest.skip("baseline sweep not yet generated")
        cells = {}
        for line in path.open():
            r = json.loads(line)
            cells[(r["arch"], r["shape"], r["mesh"])] = r
        for mesh in ("16x16", "2x16x16"):
            stats = [r["status"] for k, r in cells.items() if k[2] == mesh]
            assert stats.count("ok") == 32, mesh
            assert stats.count("skipped") == 8, mesh
