"""Autotuned cost-model calibration (DESIGN.md Sec. 3i).

The paper's evaluation never trusts datasheet numbers: every system-level
claim flows from device-level parameter extraction (Sec. 4).  The
planner's static ``TPU_V5E`` constants are exactly such untrusted
numbers on any substrate but the one they describe -- this container
runs the kernels in Pallas interpret mode on CPU, where the static
model's absolute times are off by orders of magnitude and its relative
*decisions* (mxu vs. swar, tiny-shape ref escapes) are simply wrong.

``autotune()`` closes the loop: microbenchmark the actual kernels
(``match_swar``, ``match_swar_masks``, ``match_mxu``, ``filter_qgram``,
the jnp reference) at a small grid of shapes on the current backend, and
fit, per kernel, the two-parameter overhead curve

    measured = alpha * analytic + beta

where *analytic* is the planner's roofline estimate for the same shape
(``planner.analytic_*_seconds``).  ``alpha`` is the measured overhead
factor over the op/byte model (the SNIPPETS.md Sec. 2 idiom); ``beta``
is the measured per-dispatch intercept.  Fitting a curve over the
analytic model -- not a raw shape-indexed lookup table -- means unseen
shapes interpolate through the same arithmetic, and the calibrated
pricing inherits the analytic model's monotonicity in R, P, Q (the
positivity clamps below make that a hard guarantee).

Fitted parameters are **quantized to quarter-octave log2 bins** (~+-9%)
before use: two back-to-back calibration runs on a quiet machine land in
the same bins, so timing noise cannot flip near-tie plan decisions
nondeterministically (the CI stability gate asserts this).

Tables persist as JSON keyed by (device kind, backend, interpret flag)
under ``<repo>/calibration/`` (override with ``REPRO_CALIBRATION_DIR``);
``load_cost_source()`` returns the matching ``CalibratedCostSource`` or
``None``, so callers degrade to the static fallback when no table fits
the current substrate.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import math
import os
import re
import time
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.tech import (TPU_V5E, CalibratedCostSource, CostSource,
                             KernelCurve, TPURoofline)
from repro.kernels import filter_qgram as _fq
from repro.kernels import match_mxu as _mxu
from repro.kernels import match_swar as _swar
from repro.kernels import ref as _kref
from repro.match.planner import (Planner, _mxu_geometry, _swar_geometry,
                                 analytic_filter_seconds,
                                 analytic_mxu_seconds, analytic_ref_seconds,
                                 analytic_swar_seconds)

TABLE_VERSION = 1
KERNELS = ("swar", "swar_masks", "mxu", "ref", "filter")

# Measurement grid: a handful of shapes per kernel spanning ~2 decades of
# analytic cost, enough to pin a 2-parameter curve.  Shapes are dicts of
# the planner's own vocabulary (R rows, F fragment chars, P pattern
# chars, Q patterns; sig_words for the filter kernel).  Row counts
# respect the kernel tiles (swar: 8, filter: 128).
FULL_GRID: Dict[str, List[dict]] = {
    "swar": [
        dict(R=256, F=128, P=16),
        dict(R=1024, F=128, P=16),
        dict(R=4096, F=128, P=16),
        dict(R=1024, F=256, P=32),
        dict(R=2048, F=512, P=64),
    ],
    "swar_masks": [
        dict(R=256, F=128, P=16),
        dict(R=1024, F=128, P=16),
        dict(R=1024, F=256, P=32),
        dict(R=2048, F=512, P=64),
    ],
    "mxu": [
        dict(R=64, F=128, P=16, Q=128),
        dict(R=256, F=128, P=16, Q=128),
        dict(R=256, F=256, P=32, Q=128),
        dict(R=512, F=256, P=64, Q=128),
    ],
    "ref": [
        dict(R=64, F=128, P=16),
        dict(R=512, F=128, P=16),
        dict(R=1024, F=256, P=32),
    ],
    "filter": [
        dict(R=1024, sig_words=8),
        dict(R=4096, sig_words=8),
        dict(R=16384, sig_words=8),
    ],
}

# Reduced grid for CI: 2 shapes per kernel, cheapest ones, still enough
# for the 2-parameter fit (and the stability gate only needs the same
# *decisions*, not tight curves).
FAST_GRID: Dict[str, List[dict]] = {
    # The third swar/mxu shapes sit in the batched-Q crossover regime the
    # golden matrix probes, so the fast fit interpolates that decision
    # instead of extrapolating into it (extrapolated fast fits flipped
    # near-crossover decisions run to run).
    "swar": [dict(R=256, F=128, P=16), dict(R=2048, F=128, P=16),
             dict(R=512, F=1024, P=100)],
    "swar_masks": [dict(R=256, F=128, P=16), dict(R=2048, F=128, P=16)],
    "mxu": [dict(R=64, F=128, P=16, Q=128), dict(R=256, F=128, P=16, Q=128),
            dict(R=256, F=256, P=32, Q=128)],
    # ref's fixed per-call cost dominates small shapes; the fast pair
    # must reach a slope-resolvable shape or the 2-point fit degenerates.
    "ref": [dict(R=64, F=128, P=16), dict(R=1024, F=256, P=32)],
    "filter": [dict(R=1024, sig_words=8), dict(R=8192, sig_words=8)],
}

# Golden shape matrix for decision-stability and persistence round-trip
# checks: the planner inputs whose *decisions* (kernel choice) must be
# identical across a table save/load and across two back-to-back
# calibration runs.  Spans the regimes where the static and calibrated
# models disagree on this container: tiny shapes (static's TINY_OPS ->
# ref escape), large batched Q (static's mxu crossover), accept-set
# predicates, and plain scans.
GOLDEN_SHAPES: Tuple[dict, ...] = (
    dict(n_rows=2, fragment_chars=20, pattern_chars=8),
    dict(n_rows=64, fragment_chars=128, pattern_chars=16),
    dict(n_rows=512, fragment_chars=1024, pattern_chars=100),
    dict(n_rows=512, fragment_chars=1024, pattern_chars=100, n_patterns=128),
    dict(n_rows=4096, fragment_chars=256, pattern_chars=32, n_patterns=64),
    dict(n_rows=16384, fragment_chars=256, pattern_chars=32),
    dict(n_rows=1024, fragment_chars=256, pattern_chars=48,
         predicate="accept"),
    dict(n_rows=2048, fragment_chars=512, pattern_chars=64, n_patterns=256),
)

# A plan flip between two calibration runs is tolerated only when it is
# cost-neutral: the two choices price within this factor of each other
# under either table.  Quarter-octave quantization makes genuine flips
# of near-ties rare, but two curves can each land one bin apart between
# runs (2^0.25 each, ~1.41 combined); the bound sits just under that so
# it tolerates quantization-edge flips while still failing real ones.
STABILITY_COST_TOL = 1.35


# -- substrate identity -------------------------------------------------------

def device_kind() -> str:
    """Kind string of the default device (e.g. "cpu", "TPU v5e")."""
    return jax.devices()[0].device_kind


def backend_name() -> str:
    return jax.default_backend()


def default_interpret() -> bool:
    return backend_name() != "tpu"


def _slug(s: str) -> str:
    return re.sub(r"[^a-z0-9]+", "-", s.lower()).strip("-") or "unknown"


def table_filename(dev_kind: str, backend: str, interpret: bool) -> str:
    mode = "interp" if interpret else "compiled"
    return f"{_slug(dev_kind)}--{_slug(backend)}--{mode}.json"


def calibration_dir() -> Path:
    """Table directory: ``REPRO_CALIBRATION_DIR`` or ``<repo>/calibration``."""
    env = os.environ.get("REPRO_CALIBRATION_DIR")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "calibration"


# -- measurement --------------------------------------------------------------

def _time_best(fn, repeats: int) -> float:
    """Min-of-N wall time of ``fn`` (first call discarded: jit compile)."""
    jax.block_until_ready(fn())
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _build_call(kernel: str, shape: Mapping, interpret: bool,
                roofline: TPURoofline):
    """(callable, analytic_s) for one (kernel, shape) measurement point."""
    rng = np.random.default_rng(0xC0FFEE)
    R = int(shape["R"])
    if kernel == "filter":
        wb = int(shape["sig_words"])
        rows = jax.numpy.asarray(
            rng.integers(0, 2**32, (R, wb), dtype=np.uint32))
        qsig = jax.numpy.asarray(
            rng.integers(0, 2**32, (1, wb), dtype=np.uint32))
        analytic = analytic_filter_seconds(roofline, R, wb, 1)
        return (lambda: _fq.filter_qgram(rows, qsig, slack=4,
                                         interpret=interpret)), analytic

    F, P = int(shape["F"]), int(shape["P"])
    L = F - P + 1
    if kernel == "ref":
        frags = jax.numpy.asarray(
            rng.integers(0, 4, (R, F), dtype=np.uint8))
        pat = jax.numpy.asarray(rng.integers(0, 4, (P,), dtype=np.uint8))
        analytic = analytic_ref_seconds(roofline, R, L, P, 1)
        return (lambda: _kref.match_scores_ref(frags, pat)), analytic

    if kernel == "mxu":
        Q = int(shape.get("Q", 128))
        l_pad, p_chars, q_pad, f_chars = _mxu_geometry(P, L, Q)
        ref_flat = jax.numpy.asarray(
            rng.integers(0, 2, (R, f_chars * 4)).astype(np.float32),
            jax.numpy.bfloat16)
        pat_mat = jax.numpy.asarray(
            rng.integers(0, 2, (p_chars * 4, q_pad)).astype(np.float32),
            jax.numpy.bfloat16)
        analytic = analytic_mxu_seconds(roofline, R, L, P, Q)
        return (lambda: _mxu.match_mxu(ref_flat, pat_mat, l_pad=l_pad,
                                       interpret=interpret)), analytic

    # swar / swar_masks
    wp, need = _swar_geometry(P, L)
    words = jax.numpy.asarray(
        rng.integers(0, 2**32, (R, need), dtype=np.uint32))
    mask_codes = np.zeros(wp * 16, np.uint32)
    mask_codes[:P] = 1
    from repro.core import encoding
    valid = jax.numpy.asarray(encoding.pack_codes_u32(mask_codes[None, :]))
    if kernel == "swar_masks":
        planes = jax.numpy.asarray(
            rng.integers(0, 2**32, (R, 4 * wp), dtype=np.uint32))
        analytic = analytic_swar_seconds(roofline, R, L, P, 1, "accept")
        return (lambda: _swar.match_swar_masks(
            words, planes, valid, n_locs=L, pattern_chars=P,
            interpret=interpret)), analytic
    pats = jax.numpy.asarray(
        rng.integers(0, 2**32, (R, wp), dtype=np.uint32))
    analytic = analytic_swar_seconds(roofline, R, L, P, 1, "exact")
    return (lambda: _swar.match_swar(
        words, pats, valid, n_locs=L, pattern_chars=P,
        interpret=interpret)), analytic


def measure(kernel: str, shape: Mapping, *, interpret: bool,
            repeats: int = 3,
            roofline: TPURoofline = TPU_V5E) -> Tuple[float, float]:
    """(analytic_s, measured_s) for one kernel at one shape."""
    fn, analytic = _build_call(kernel, shape, interpret, roofline)
    return analytic, _time_best(fn, repeats)


# -- fitting ------------------------------------------------------------------

def quantize_q2(v: float) -> float:
    """Snap ``v`` to the nearest quarter-octave log2 bin (~+-9%).

    Two calibration runs whose raw fits differ by timing noise land in
    the same bin, so the decisions they imply are bit-identical; 0 stays
    0 (a zero intercept is a legitimate fit outcome).
    """
    if v <= 0.0:
        return 0.0
    return float(2.0 ** (round(math.log2(v) * 4.0) / 4.0))


def fit_curve(analytic: Sequence[float],
              measured: Sequence[float]) -> KernelCurve:
    """Fit measured = alpha*analytic + beta, alpha > 0, beta >= 0.

    Weighted least squares with 1/y^2 weights (minimizes *relative*
    error: a 100us shape matters as much as a 100ms one -- exactly the
    property plan comparisons need).  Three constrained candidate models
    are fitted and the lowest-residual one wins:

    * the unconstrained 2-parameter fit, admitted only when it already
      satisfies alpha > 0, beta >= 0;
    * through-origin (beta = 0): right when the data is slope-dominated
      and noise pushed the free intercept negative;
    * constant-dominated (beta = weighted mean, alpha = median residual
      slope): right when the grid's slope signal drowns in the fixed
      per-call cost (the jnp reference path), where a through-origin fit
      would massively underprice small shapes -- and, worse, flip
      decisions between back-to-back runs on fit noise.

    Picking by residual is deterministic in the samples, and both
    parameters are quarter-octave quantized (see ``quantize_q2``), so
    quiet-machine reruns land on identical curves.  The positivity
    constraints make the curve monotone in the analytic estimate --
    hence in R, P, Q.
    """
    x = np.asarray(analytic, np.float64)
    y = np.asarray(measured, np.float64)
    if x.size == 0:
        raise ValueError("cannot fit a curve to zero samples")
    w = 1.0 / np.maximum(y, 1e-12) ** 2
    sxx, sx, s1 = (w * x * x).sum(), (w * x).sum(), w.sum()
    sxy, sy = (w * x * y).sum(), (w * y).sum()
    det = sxx * s1 - sx * sx

    def rel_err_of(a: float, b: float) -> float:
        pred = a * x + b
        return float(np.max(np.abs(pred - y) / np.maximum(y, 1e-12)))

    candidates = []
    if x.size >= 2 and det > 0:
        a2 = (sxy * s1 - sx * sy) / det
        b2 = (sxx * sy - sx * sxy) / det
        if a2 > 0.0 and b2 >= 0.0:
            candidates.append((a2, b2))
    a1 = sxy / max(sxx, 1e-300)           # x, y > 0, so a1 > 0 always
    candidates.append((a1, 0.0))
    bc = sy / s1
    resid = np.maximum(y - bc, 0.0) / np.maximum(x, 1e-300)
    ac = float(np.median(resid))
    if ac <= 0.0:
        # Flat data: keep a vanishing slope so pricing still grows
        # (slowly) past the grid instead of treating all shapes as free.
        ac = bc / (100.0 * float(x.max()))
    candidates.append((ac, bc))
    alpha, beta = min(candidates, key=lambda ab: rel_err_of(*ab))
    alpha, beta = quantize_q2(alpha), quantize_q2(beta)
    return KernelCurve(alpha=alpha, beta=beta, n_samples=int(x.size),
                       rel_err=round(rel_err_of(alpha, beta), 4))


# -- the table ----------------------------------------------------------------

@dataclasses.dataclass
class CalibrationTable:
    """Fitted per-kernel cost curves for one (device, backend, mode)."""

    device_kind: str
    backend: str
    interpret: bool
    curves: Dict[str, KernelCurve]
    samples: Dict[str, List[dict]] = dataclasses.field(default_factory=dict)
    meta: Dict = dataclasses.field(default_factory=dict)

    def _canonical(self) -> str:
        body = {
            "version": TABLE_VERSION,
            "device_kind": self.device_kind,
            "backend": self.backend,
            "interpret": self.interpret,
            "curves": {k: dataclasses.asdict(c)
                       for k, c in sorted(self.curves.items())},
        }
        return json.dumps(body, sort_keys=True, separators=(",", ":"))

    @property
    def digest(self) -> str:
        """Content digest of the decision-relevant fields (stable key)."""
        return hashlib.blake2b(self._canonical().encode(),
                               digest_size=16).hexdigest()

    def cost_source(self) -> CalibratedCostSource:
        return CalibratedCostSource(
            self.curves, digest=self.digest,
            meta={"device_kind": self.device_kind, "backend": self.backend,
                  "interpret": self.interpret})

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "version": TABLE_VERSION,
            "device_kind": self.device_kind,
            "backend": self.backend,
            "interpret": self.interpret,
            "digest": self.digest,
            "curves": {k: dataclasses.asdict(c)
                       for k, c in sorted(self.curves.items())},
            "samples": self.samples,
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, doc: Mapping) -> "CalibrationTable":
        if doc.get("version") != TABLE_VERSION:
            raise ValueError(f"calibration table version "
                             f"{doc.get('version')!r} != {TABLE_VERSION}")
        curves = {k: KernelCurve(**c) for k, c in doc["curves"].items()}
        table = cls(device_kind=doc["device_kind"], backend=doc["backend"],
                    interpret=bool(doc["interpret"]), curves=curves,
                    samples=dict(doc.get("samples", {})),
                    meta=dict(doc.get("meta", {})))
        stored = doc.get("digest")
        if stored and stored != table.digest:
            raise ValueError("calibration table digest mismatch: file "
                             "edited or truncated; re-run autotune")
        return table

    def path(self, directory: Optional[Path] = None) -> Path:
        d = Path(directory) if directory is not None else calibration_dir()
        return d / table_filename(self.device_kind, self.backend,
                                  self.interpret)

    def save(self, directory: Optional[Path] = None) -> Path:
        p = self.path(directory)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_json(), indent=2, sort_keys=True)
                     + "\n")
        return p

    @classmethod
    def load(cls, dev_kind: Optional[str] = None,
             backend: Optional[str] = None,
             interpret: Optional[bool] = None,
             directory: Optional[Path] = None) -> "CalibrationTable":
        dev_kind = dev_kind if dev_kind is not None else device_kind()
        backend = backend if backend is not None else backend_name()
        interpret = (interpret if interpret is not None
                     else default_interpret())
        d = Path(directory) if directory is not None else calibration_dir()
        p = d / table_filename(dev_kind, backend, interpret)
        return cls.from_json(json.loads(p.read_text()))


def load_cost_source(dev_kind: Optional[str] = None,
                     backend: Optional[str] = None,
                     interpret: Optional[bool] = None,
                     directory: Optional[Path] = None
                     ) -> Optional[CalibratedCostSource]:
    """The persisted source for the current substrate, or None (fallback).

    This is the "calibrate once, then serve" entry point: construct the
    engine with ``cost_source=load_cost_source() or None`` -- a missing,
    unreadable, or wrong-substrate table degrades to the static fallback
    instead of failing.
    """
    try:
        return CalibrationTable.load(dev_kind, backend, interpret,
                                     directory).cost_source()
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return None


def bench_provenance(cost_source: Optional[CostSource] = None) -> dict:
    """Provenance block every BENCH_*.json artifact carries.

    ``calibration`` is the cost-source tag that priced the run's planner
    decisions ("static" when no source was loaded), so an artifact can
    finally say what hardware -- and what cost model -- its numbers mean.
    ``n_processes`` / ``n_hosts`` record the controller topology
    (DESIGN.md Sec. 3k): a multi-controller artifact measured collective
    merges, a single-controller one did not -- numbers from the two are
    not comparable without this field.
    """
    return {
        "device_kind": device_kind(),
        "backend": backend_name(),
        "calibration": cost_source.tag if cost_source is not None
        else "static",
        "n_processes": jax.process_count(),
        "n_hosts": len({d.host_id if hasattr(d, "host_id")
                        else d.process_index for d in jax.devices()}),
    }


# -- autotune -----------------------------------------------------------------

def autotune(*, fast: bool = False, interpret: Optional[bool] = None,
             repeats: Optional[int] = None,
             roofline: TPURoofline = TPU_V5E,
             kernels: Sequence[str] = KERNELS,
             verbose: bool = False) -> CalibrationTable:
    """Measure the grid, fit per-kernel curves, return the table."""
    interpret = default_interpret() if interpret is None else interpret
    repeats = 3 if repeats is None else repeats
    grid = FAST_GRID if fast else FULL_GRID
    curves: Dict[str, KernelCurve] = {}
    samples: Dict[str, List[dict]] = {}
    for kernel in kernels:
        xs, ys, rows = [], [], []
        for shape in grid[kernel]:
            analytic, measured = measure(kernel, shape,
                                         interpret=interpret,
                                         repeats=repeats,
                                         roofline=roofline)
            xs.append(analytic)
            ys.append(measured)
            rows.append({**shape, "analytic_s": analytic,
                         "measured_s": round(measured, 6)})
            if verbose:
                print(f"  {kernel} {shape}: analytic {analytic:.3g}s "
                      f"measured {measured:.3g}s "
                      f"(x{measured / max(analytic, 1e-300):.3g})")
        curves[kernel] = fit_curve(xs, ys)
        samples[kernel] = rows
    return CalibrationTable(
        device_kind=device_kind(), backend=backend_name(),
        interpret=interpret, curves=curves, samples=samples,
        meta={"grid": "fast" if fast else "full", "repeats": repeats})


# -- decision stability -------------------------------------------------------

def golden_decisions(source: CostSource) -> List[Tuple[str, str]]:
    """(shape-key, chosen backend) over the golden matrix for one source."""
    planner = Planner(cost_source=source)
    out = []
    for shape in GOLDEN_SHAPES:
        key = ",".join(f"{k}={v}" for k, v in sorted(shape.items()))
        out.append((key, planner.plan(**shape).backend))
    return out


def decisions_stable(src_a: CostSource, src_b: CostSource,
                     tol: float = STABILITY_COST_TOL
                     ) -> Tuple[bool, List[dict]]:
    """Compare plan decisions of two sources over the golden matrix.

    A differing choice is tolerated only when it is cost-neutral: each
    source prices the other's pick within ``tol`` of its own.  Returns
    (all_stable, per-shape report rows).
    """
    pa, pb = Planner(cost_source=src_a), Planner(cost_source=src_b)
    rows, ok = [], True
    for shape in GOLDEN_SHAPES:
        plan_a, plan_b = pa.plan(**shape), pb.plan(**shape)
        stable = plan_a.backend == plan_b.backend
        neutral = False
        if not stable:
            # Price both choices under source A: a flip is harmless if A
            # thinks B's pick costs within tol of its own (and vice
            # versa).
            R = shape["n_rows"]
            P = shape["pattern_chars"]
            L = shape["fragment_chars"] - P + 1
            Q = shape.get("n_patterns", 1)
            pred = shape.get("predicate", "exact")
            a_own = pa.backend_seconds(plan_a.backend, R, L, P, Q, pred)
            a_other = pa.backend_seconds(plan_b.backend, R, L, P, Q, pred)
            b_own = pb.backend_seconds(plan_b.backend, R, L, P, Q, pred)
            b_other = pb.backend_seconds(plan_a.backend, R, L, P, Q, pred)
            neutral = (a_other <= tol * a_own and b_other <= tol * b_own)
        rows.append({"shape": ",".join(f"{k}={v}" for k, v
                                       in sorted(shape.items())),
                     "choice_a": plan_a.backend, "choice_b": plan_b.backend,
                     "stable": stable, "cost_neutral": neutral})
        ok = ok and (stable or neutral)
    return ok, rows


# -- CLI ----------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Microbenchmark the match kernels and fit the "
                    "calibrated cost table for this substrate.")
    ap.add_argument("--fast", action="store_true",
                    help="reduced grid + fewer repeats (CI mode)")
    ap.add_argument("--out", type=Path, default=None,
                    help="directory to write the table (default: "
                         "REPRO_CALIBRATION_DIR or <repo>/calibration)")
    ap.add_argument("--no-save", action="store_true",
                    help="fit and report only")
    ap.add_argument("--check-stability", action="store_true",
                    help="run the autotune twice and require identical "
                         "(or cost-neutral) golden-matrix decisions")
    args = ap.parse_args(argv)

    table = autotune(fast=args.fast, verbose=True)
    for kernel in sorted(table.curves):
        c = table.curves[kernel]
        print(f"CALIB kernel={kernel} alpha={c.alpha:.6g} "
              f"beta={c.beta:.6g} rel_err={c.rel_err:.3g} "
              f"n={c.n_samples}")
    print(f"CALIB table device_kind={table.device_kind!r} "
          f"backend={table.backend} interpret={table.interpret} "
          f"digest={table.digest[:8]}")
    if not args.no_save:
        path = table.save(args.out)
        print(f"CALIB saved {path}")

    if args.check_stability:
        table2 = autotune(fast=args.fast)
        ok, rows = decisions_stable(table.cost_source(),
                                    table2.cost_source())
        for r in rows:
            print(f"CALIB stability shape[{r['shape']}] "
                  f"a={r['choice_a']} b={r['choice_b']} "
                  f"stable={r['stable']} neutral={r['cost_neutral']}")
        if not ok:
            print("CALIB stability FAILED: decisions flipped between "
                  "back-to-back calibration runs")
            return 1
        print("CALIB stability OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
