"""Query planner: workload shape -> kernel + geometry (DESIGN.md Sec. 3b).

Replaces the caller-supplied backend string of the old ``ops.match_scores``
with a selection driven by roofline arithmetic: estimate each kernel's
compute and memory terms, take ``max`` per kernel, pick the minimum.
Structural constraints are applied first (the MXU formulation has no
per-row-pattern path; a batched query on the SWAR kernel re-reads the
corpus per pattern, where the MXU amortizes the reference read across
patterns), and an explicit ``backend=`` override always wins.

Pricing is layered (DESIGN.md Sec. 3i).  The *analytic* layer
(``analytic_*_seconds`` module functions) turns a shape into roofline
seconds against ``TPURoofline`` constants -- pure arithmetic, no
overheads.  The active ``CostSource`` turns analytic seconds into wall
seconds: the static datasheet model (``TPU_V5E`` constants plus a fixed
dispatch overhead -- the uncalibrated fallback) or measured per-kernel
curves fitted by ``repro.match.calibrate``.  A ``FeedbackStore`` of
observed/estimated runtime ratios then re-prices any (kernel,
shape-bucket) whose estimates have drifted past a bound.  Every ``Plan``
records which source priced it (``Plan.cost_source``, also tagged into
``Plan.reason``).

The ``Plan`` carries every derived geometry number (word counts, tile
paddings, chunking) so the executor never re-derives layout -- one source
of truth per query.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.tech import (DISPATCH_OVERHEAD_S, REF_CALL_OVERHEAD_S,
                             TPU_V5E, CostSource, StaticCostSource,
                             TPURoofline)
from repro.kernels import match_mxu as _mxu
from repro.kernels import match_swar as _swar
from repro.match.feedback import FeedbackStore, kernel_key

BACKENDS = ("swar", "mxu", "ref")

# Below this many (row, loc, patchar, query) ops the Pallas launch
# dominates and the plain jnp reference is fastest.  This structural
# escape hatch encodes the *static* model's launch-overhead belief; a
# calibrated source has measured per-kernel intercepts, so under it the
# tiny-shape decision is a genuine three-way price comparison instead.
TINY_OPS = 4096
# SWAR integer ops per (row, loc, word): shift/or/xor/and + popcount tree.
SWAR_OPS_PER_WORD = 12
# Accept-set SWAR variant: four lane-equality tests + plane ANDs replace
# the single XOR (see match_swar_masks) -- ~2.5x the integer work.
SWAR_OPS_PER_WORD_MASKS = 30
# The SWAR kernel runs on the VPU, whose integer throughput is a small
# fraction of MXU bf16 peak (8x128 lanes vs. the systolic array); this
# divisor calibrates swar compute against ``peak_bf16_flops``.
VPU_SLOWDOWN = 64
# Host jnp reference throughput + per-call overhead: only has to rank the
# ref backend sanely against the kernels when pricing batches.
REF_OPS_PER_S = 1e9
# Q-gram filter stage (filter_qgram kernel): and/not + full SWAR popcount
# + compare per signature word.
FILTER_OPS_PER_WORD = 18


def kernel_name(backend: str, predicate: str = "exact") -> str:
    """Cost-model kernel identifier for a (backend, predicate) pair.

    The accept-set SWAR variant is a different kernel with a different
    cost curve (bit-plane operands, ~2.5x the integer ops), so it
    calibrates and feeds back separately from exact-match SWAR.
    """
    if backend == "swar" and predicate == "accept":
        return "swar_masks"
    return backend


# -- analytic layer: shape -> roofline seconds, no overheads ------------------

def analytic_swar_seconds(roofline: TPURoofline, R: int, L: int, P: int,
                          Q: int = 1, predicate: str = "exact") -> float:
    """Roofline seconds for one fused SWAR dispatch over Q pattern sets."""
    wp, need = _swar_geometry(P, L)
    if predicate == "accept":
        ops_per_word, pat_words = SWAR_OPS_PER_WORD_MASKS, 4 * wp
    else:
        ops_per_word, pat_words = SWAR_OPS_PER_WORD, wp
    ops = Q * R * L * wp * ops_per_word
    bytes_hbm = Q * (R * need * 4 + R * pat_words * 4 + R * L * 4)
    t_compute = ops / (roofline.peak_bf16_flops / VPU_SLOWDOWN)
    t_mem = bytes_hbm / roofline.hbm_bw
    return max(t_compute, t_mem)


def analytic_mxu_seconds(roofline: TPURoofline, R: int, L: int, P: int,
                         Q: int = 1) -> float:
    """Roofline seconds for one batched MXU pass over all Q patterns."""
    l_pad, p_chars, q_pad, f_chars = _mxu_geometry(P, L, Q)
    n_chunks = p_chars // _mxu.CHARS_PER_CHUNK
    flops = R * l_pad * (n_chunks * _mxu.K_CHUNK) * 2 * q_pad
    bytes_hbm = (R * f_chars * 4 * 2 + p_chars * 4 * q_pad * 2
                 + R * l_pad * q_pad * 4)
    t_compute = flops / roofline.peak_bf16_flops
    t_mem = bytes_hbm / roofline.hbm_bw
    return max(t_compute, t_mem)


def analytic_ref_seconds(roofline: TPURoofline, R: int, L: int, P: int,
                         Q: int = 1) -> float:
    """Host jnp reference compute for Q passes (overhead priced per call)."""
    del roofline  # host path: independent of the accelerator target
    return Q * R * L * P / REF_OPS_PER_S


def analytic_filter_seconds(roofline: TPURoofline, R: int, sig_words: int,
                            n_queries: int = 1) -> float:
    """Roofline seconds for Q filter-kernel dispatches over R signatures."""
    ops = n_queries * R * sig_words * FILTER_OPS_PER_WORD
    bytes_hbm = n_queries * (R * sig_words * 4 + R * 4)
    t_compute = ops / (roofline.peak_bf16_flops / VPU_SLOWDOWN)
    t_mem = bytes_hbm / roofline.hbm_bw
    return max(t_compute, t_mem)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Everything the executor needs to run one query."""

    backend: str                # "swar" | "mxu" | "ref"
    mode: str                   # "shared" | "per_row" | "batched"
    n_rows: int                 # R (unpadded)
    fragment_chars: int         # F
    pattern_chars: int          # P
    n_patterns: int             # Q (1 unless batched)
    n_locs: int                 # L = F - P + 1
    # SWAR geometry.
    wp: int = 0                 # pattern words
    need_words: int = 0         # min corpus word width incl. look-ahead pad
    # MXU geometry.
    l_pad: int = 0              # alignment rows produced (mult of L_TILE)
    p_chars_pad: int = 0        # pattern chars padded to CHARS_PER_CHUNK
    q_pad: int = 0              # patterns padded to 128
    f_chars: int = 0            # one-hot reference chars needed
    # Streaming.
    chunk_rows: int = 0         # rows per executor chunk (mult of row tile)
    est_seconds: float = 0.0    # roofline estimate for the whole query
    reason: str = ""            # human-readable selection rationale
    # Predicate.
    predicate: str = "exact"    # "exact" | "accept" (accept-set masks)
    # Two-stage execution (DESIGN.md Sec. 3g).
    strategy: str = "scan"      # "scan" | "filter" (filter-then-verify)
    filter_words: int = 0       # signature words per row (filter plans)
    est_survivor_frac: float = 1.0  # estimated post-filter row fraction
    # Sharded execution (DESIGN.md Sec. 3h): kernel terms priced at the
    # per-shard row count (shards run concurrently; the critical path is
    # one shard's work plus the small host merge).
    n_shards: int = 1
    # Device-side merge traffic (DESIGN.md Sec. 3k): estimated cross-
    # shard collective bytes for the reduction (ring all_gather of
    # reduced per-row state, per-chunk top-k candidate exchanges, the
    # threshold hot bitmap).  Priced into est_seconds at ici_link_bw but
    # kept out of the backend comparison -- every backend moves the same
    # reduced state.  MatchResult.collective_bytes is the measured
    # counterpart the feedback loop can hold against this.
    est_collective_bytes: float = 0.0
    # Cost provenance (DESIGN.md Sec. 3i): which source priced this plan
    # ("static" | "calibrated:<digest8>"), the feedback-free estimate of
    # the scan/verify stage (what observed runtimes are recorded against
    # -- see feedback.FeedbackStore), and the filter stage's share of
    # est_seconds when strategy == "filter".
    cost_source: str = "static"
    est_base_seconds: float = 0.0
    est_filter_seconds: float = 0.0
    est_filter_base_seconds: float = 0.0


def _swar_geometry(P: int, L: int) -> tuple[int, int]:
    wp = -(-P // 16)
    need = (L - 1) // 16 + wp + 1
    return wp, need


def _mxu_geometry(P: int, L: int, Q: int) -> tuple[int, int, int, int]:
    n_chunks = -(-P // _mxu.CHARS_PER_CHUNK)
    p_chars = n_chunks * _mxu.CHARS_PER_CHUNK
    l_pad = max(-(-L // _mxu.L_TILE) * _mxu.L_TILE, _mxu.L_TILE)
    q_pad = -(-Q // 128) * 128
    return l_pad, p_chars, q_pad, l_pad + p_chars


@dataclasses.dataclass(frozen=True)
class FilterContext:
    """Filter-stage pricing inputs for one eligible threshold query.

    Built by the engine (``MatchEngine._filter_context``) from the query
    content and the corpus index configuration; the planner prices the
    two-stage pipeline (filter + estimated-survivor verify) against the
    full scan and records the verdict in ``Plan.strategy``.
    """

    sig_words: int              # uint32 signature words per row
    n_queries: int              # filter-kernel dispatches (1 per pattern)
    prunable: bool              # every query can exclude rows
    survivor_frac: float        # estimated post-filter row fraction
    force: bool = False         # query hint filter=True: skip the pricing


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Pricing verdict for Q compatible shared-mode queries (one tick).

    ``coalesced`` means one fused ``mode="batched"`` launch beats Q
    sequential single-query launches; ``plan`` is the plan to execute
    (batched geometry when coalesced, single-query geometry otherwise).
    """

    coalesced: bool
    plan: Plan
    n_queries: int
    est_coalesced_s: float
    est_sequential_s: float
    reason: str


@dataclasses.dataclass(frozen=True)
class BankPlan:
    """Pricing verdict for one ingest batch against a standing bank.

    The inverted regime (DESIGN.md Sec. 3j): the pattern bank is the
    resident axis, the arriving document batch the transient one.
    ``strategy == "scan"`` verifies every live pattern against the batch
    in one fused accept-set SWAR launch; ``"filter"`` first runs one
    ``bank_prefilter`` dispatch (pattern signatures vs. per-doc
    occurrence signatures) and verifies only the estimated survivors.
    Either way the batch costs exactly one verify launch -- the filter
    only shrinks its pattern axis.
    """

    strategy: str               # "scan" | "filter"
    n_docs: int                 # arriving batch size D
    n_patterns: int             # live bank slots Qp
    est_seconds: float          # chosen-path estimate
    est_scan_seconds: float     # full bank scan estimate
    est_filter_seconds: float   # prefilter stage share (0 for scan)
    est_survivor_frac: float    # estimated surviving-pattern fraction
    est_verify_patterns: int    # pattern axis priced into the verify
    reason: str
    cost_source: str = "static"


class Planner:
    """Kernel selection: analytic roofline x cost source x runtime feedback.

    ``cost_source`` prices analytic seconds into wall seconds (static
    datasheet fallback, or measured calibration from
    ``repro.match.calibrate.load_cost_source``).  ``feedback`` multiplies
    in the published observed/estimated factor for the (kernel,
    shape-bucket), so mispredicted buckets heal online; pass
    ``feedback=None`` semantics via a fresh store -- every planner owns
    one unless the caller shares theirs (the engine shares its store so
    compiled plans and ad-hoc queries see the same corrections).
    """

    def __init__(self, roofline: TPURoofline = TPU_V5E,
                 memory_budget_bytes: float = 256 * 2**20,
                 cost_source: Optional[CostSource] = None,
                 feedback: Optional[FeedbackStore] = None):
        self.roofline = roofline
        self.memory_budget_bytes = memory_budget_bytes
        self.cost_source = cost_source or StaticCostSource()
        self.feedback = feedback if feedback is not None else FeedbackStore()

    # -- cost terms -----------------------------------------------------------
    def _price(self, kernel: str, analytic_s: float, n_dispatch: int,
               R: int, x: int, Q: int, base: bool) -> float:
        """Analytic seconds -> wall seconds via source, then feedback.

        ``base=True`` skips the feedback factor: that is the estimate
        observed runtimes are recorded against, so the EWMA converges to
        truth/model rather than chasing its own corrections (the
        geometric-mean trap -- see ``feedback`` module docstring).
        """
        priced = self.cost_source.price(kernel, analytic_s, n_dispatch)
        if base:
            return priced
        return priced * self.feedback.factor(kernel_key(kernel, R, x, Q))

    def swar_seconds(self, R: int, L: int, P: int, Q: int = 1,
                     predicate: str = "exact", *, base: bool = False) -> float:
        """One fused SWAR dispatch over Q pattern sets.

        The executor tiles the corpus chunk Q times and rides each pattern
        as a per-row pattern, so a batched query is a single launch whose
        compute and memory (the corpus is re-read per pattern) scale with
        Q -- where the MXU formulation amortizes the reference read across
        patterns instead.  Accept-set predicates pay ~2.5x the integer ops
        (four lane-equality tests per word) and read 4 plane words per
        pattern word -- the MXU, where wildcards are free, wins sooner.
        """
        analytic = analytic_swar_seconds(self.roofline, R, L, P, Q, predicate)
        return self._price(kernel_name("swar", predicate), analytic, 1,
                           R, P, Q, base)

    def ref_seconds(self, R: int, L: int, P: int, Q: int = 1,
                    *, base: bool = False) -> float:
        """Q jnp reference passes on the host (batched ref still loops Q)."""
        analytic = analytic_ref_seconds(self.roofline, R, L, P, Q)
        return self._price("ref", analytic, Q, R, P, Q, base)

    def filter_seconds(self, R: int, sig_words: int, n_queries: int = 1,
                       *, base: bool = False) -> float:
        """Q filter-kernel dispatches over R row signatures.

        Each dispatch reads ``sig_words`` uint32 per row plus the query
        signature, does a handful of integer ops per word on the VPU, and
        writes one flag per row -- orders of magnitude less data touched
        than the exact scan, which is the whole point of the stage.
        """
        analytic = analytic_filter_seconds(self.roofline, R, sig_words,
                                           n_queries)
        return self._price("filter", analytic, n_queries,
                           R, sig_words, n_queries, base)

    def mxu_seconds(self, R: int, L: int, P: int, Q: int = 1,
                    *, base: bool = False) -> float:
        """One batched MXU pass over all Q patterns.

        Identical for exact and accept-set predicates: a wildcard is just a
        multi-hot column in the pattern matrix, same contraction shape --
        the "wildcards are nearly free on the MXU" property the planner
        exploits.
        """
        analytic = analytic_mxu_seconds(self.roofline, R, L, P, Q)
        return self._price("mxu", analytic, 1, R, P, Q, base)

    def backend_seconds(self, backend: str, R: int, L: int, P: int,
                        Q: int = 1, predicate: str = "exact",
                        *, base: bool = False) -> float:
        """Price any scan backend by name (the verify-stage dispatcher)."""
        if backend == "swar":
            return self.swar_seconds(R, L, P, Q, predicate, base=base)
        if backend == "mxu":
            return self.mxu_seconds(R, L, P, Q, base=base)
        return self.ref_seconds(R, L, P, Q, base=base)

    # -- chunking -------------------------------------------------------------
    def _chunk_rows(self, R_pad: int, plan_bytes_per_row: int,
                    row_tile: int, override: Optional[int],
                    n_shards: int = 1) -> int:
        """Rows per streaming chunk (a multiple of the row tile).

        The memory budget is per device; a sharded chunk spreads its rows
        over ``n_shards`` devices, so the global chunk can be S times
        larger for the same per-device footprint.
        """
        if override is not None:
            chunk = -(-override // row_tile) * row_tile
        else:
            rows = int(self.memory_budget_bytes * n_shards
                       // max(plan_bytes_per_row, 1))
            chunk = max(row_tile, (rows // row_tile) * row_tile)
        return min(chunk, R_pad)

    # -- the planner ----------------------------------------------------------
    def plan(self, *, n_rows: int, fragment_chars: int, pattern_chars: int,
             n_patterns: Optional[int] = None, per_row: bool = False,
             backend: Optional[str] = None,
             chunk_rows: Optional[int] = None,
             predicate: str = "exact",
             filter_ctx: Optional[FilterContext] = None,
             n_shards: int = 1, reduction: Optional[str] = None,
             topk_k: int = 0) -> Plan:
        R, F, P = n_rows, fragment_chars, pattern_chars
        if R < 1:
            raise ValueError("corpus has no rows")
        if P < 1:
            raise ValueError("pattern must have at least one character")
        L = F - P + 1
        if L <= 0:
            raise ValueError("pattern longer than fragment")
        if per_row and n_patterns is not None:
            raise ValueError("per_row and batched are mutually exclusive")
        if predicate not in ("exact", "accept"):
            raise ValueError(f"unknown predicate {predicate!r}")
        Q = 1 if n_patterns is None else int(n_patterns)
        mode = "per_row" if per_row else ("batched" if n_patterns is not None
                                          else "shared")
        if backend is not None and backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "mxu" and per_row:
            raise ValueError("mxu kernel has no per-row-pattern formulation")

        # Shard-aware pricing (DESIGN.md Sec. 3h): the kernels run per
        # shard on R/S rows concurrently, so their roofline terms use the
        # per-shard row count -- the critical path, not the total work.
        # The ref backend scans the host buffer single-threaded and the
        # tiny-workload escape hatch keys on total ops, so both keep R.
        S = max(1, int(n_shards))
        R_shard = -(-R // S)
        t_swar = self.swar_seconds(R_shard, L, P, Q, predicate)
        t_mxu = self.mxu_seconds(R_shard, L, P, Q)

        if backend is not None:
            chosen, reason = backend, "explicit override"
        elif per_row:
            chosen, reason = "swar", "per-row patterns: SWAR only"
        elif (self.cost_source.name == "static"
              and R * L * P * Q <= TINY_OPS):
            # Q multiplies the work: a large batched query on a small corpus
            # is not tiny, and routing it to the Python-loop ref backend
            # would cost Q sequential passes.  This structural rule encodes
            # the static model's launch-overhead belief; a calibrated
            # source has measured per-kernel intercepts, so tiny shapes
            # fall through to the three-way price comparison below.
            chosen, reason = "ref", "tiny workload: launch overhead dominates"
        elif self.cost_source.name != "static":
            # Calibrated: genuine three-way comparison.  The measured
            # intercepts decide the tiny-shape regime (on a host-heavy
            # substrate the jnp reference's per-call overhead can exceed
            # an interpret-mode Pallas launch by orders of magnitude --
            # exactly the kind of fact only calibration can know).
            t_ref = self.ref_seconds(R, L, P, Q)
            chosen, t_best = "swar", t_swar
            if t_mxu < t_best:
                chosen, t_best = "mxu", t_mxu
            if t_ref < t_best:
                chosen, t_best = "ref", t_ref
            reason = (f"measured: {chosen} {t_best:.3g}s (swar {t_swar:.3g}s,"
                      f" mxu {t_mxu:.3g}s, ref {t_ref:.3g}s, Q={Q})")
        elif t_mxu < t_swar:
            chosen = "mxu"
            reason = f"roofline: mxu {t_mxu:.3g}s < swar {t_swar:.3g}s (Q={Q})"
        else:
            chosen = "swar"
            reason = f"roofline: swar {t_swar:.3g}s <= mxu {t_mxu:.3g}s (Q={Q})"

        wp, need = _swar_geometry(P, L)
        l_pad, p_chars, q_pad, f_chars = _mxu_geometry(P, L, Q)
        row_pad = _swar.ROW_TILE * S
        R_pad = -(-R // row_pad) * row_pad

        if chosen == "swar":
            # Batched swar tiles each chunk Q times (one fused launch), so
            # a chunk's footprint scales with Q; accept-set planes are 4
            # words per pattern word.
            pat_words = 4 * wp if predicate == "accept" else wp
            bytes_per_row = (need * 4 + pat_words * 4 + L * 4) * Q
            row_tile = _swar.ROW_TILE
            est = t_swar
            est_base = self.swar_seconds(R_shard, L, P, Q, predicate,
                                         base=True)
        elif chosen == "mxu":
            bytes_per_row = f_chars * 4 * 2 + l_pad * q_pad * 4
            row_tile = 1
            est = t_mxu
            est_base = self.mxu_seconds(R_shard, L, P, Q, base=True)
        else:
            bytes_per_row = F + L * 4 * Q
            row_tile = 1
            est = self.ref_seconds(R, L, P, Q)
            est_base = self.ref_seconds(R, L, P, Q, base=True)
        chunk = self._chunk_rows(R_pad, bytes_per_row,
                                 row_tile if chosen == "ref" else
                                 row_tile * S, chunk_rows, n_shards=S)

        # Two-stage pricing (DESIGN.md Sec. 3g): for an eligible threshold
        # query, compare filter + estimated-survivor verify against the
        # full scan just chosen.  The verify stage keeps the scan's kernel
        # (the packed pattern operands are shared between strategies); the
        # survivor estimate carries the index's measured-selectivity
        # calibration.  A query-level filter=True hint skips the pricing
        # (but never the prunability requirement).
        strategy, filter_words, surv = "scan", 0, 1.0
        est_fil = est_fil_base = 0.0
        if filter_ctx is not None and filter_ctx.prunable:
            frac = filter_ctx.survivor_frac
            # Per-shard pricing: the filter kernel scans R/S signatures
            # per shard, and survivors spread ~uniformly over shards
            # (cyclic placement), so the verify stage is r_surv/S per
            # shard too.
            r_surv = max(1, math.ceil(frac * R / S))
            t_fil = self.filter_seconds(R_shard, filter_ctx.sig_words,
                                        filter_ctx.n_queries)
            t_ver = self.backend_seconds(chosen, r_surv, L, P, Q, predicate)
            if filter_ctx.force or t_fil + t_ver < est:
                strategy = "filter"
                filter_words = filter_ctx.sig_words
                surv = frac
                reason += (f"; filter+verify {t_fil + t_ver:.3g}s "
                           f"{'forced' if filter_ctx.force else '<'} scan "
                           f"{est:.3g}s (est survivors {frac:.3g})")
                est = t_fil + t_ver
                est_fil = t_fil
                est_fil_base = self.filter_seconds(
                    R_shard, filter_ctx.sig_words, filter_ctx.n_queries,
                    base=True)
                est_base = self.backend_seconds(chosen, r_surv, L, P, Q,
                                                predicate, base=True)

        # Collective-merge pricing (DESIGN.md Sec. 3k): cross-shard
        # reductions exchange reduced state on device.  Ring all_gather
        # moves (S-1)/S of the replicated payload per link; the per-row
        # best loc+score pulls (8 bytes/row/query) underlie every scan
        # reduction, top-k adds per-chunk candidate exchanges
        # ((score, row) pairs from S-1 peers), threshold adds the hot
        # bitmap, and "full" replicates the whole score block.  Added to
        # est_seconds *after* the backend choice: every backend moves the
        # same reduced state, so it must not tilt the comparison.
        est_coll = 0.0
        if S > 1 and reduction is not None:
            ring = (S - 1) / S
            if reduction == "full":
                est_coll = R_pad * L * 4.0 * Q * ring
            else:
                est_coll = R_pad * 8.0 * Q * ring
                if reduction == "topk":
                    n_ch = max(1, -(-R_pad // max(chunk, 1)))
                    k_loc = min(max(int(topk_k), 1),
                                max(chunk // S, 1))
                    est_coll += n_ch * (S - 1) * k_loc * Q * 12.0
                elif reduction == "threshold":
                    est_coll += R_pad * 1.0 * ring
            est += est_coll / self.roofline.ici_link_bw

        if S > 1:
            reason += f"; priced per shard (S={S})"
        reason += f" [cost={self.cost_source.tag}]"
        return Plan(backend=chosen, mode=mode, n_rows=R, fragment_chars=F,
                    pattern_chars=P, n_patterns=Q, n_locs=L, wp=wp,
                    need_words=need, l_pad=l_pad, p_chars_pad=p_chars,
                    q_pad=q_pad, f_chars=f_chars, chunk_rows=chunk,
                    est_seconds=est, reason=reason, predicate=predicate,
                    strategy=strategy, filter_words=filter_words,
                    est_survivor_frac=surv, n_shards=S,
                    est_collective_bytes=est_coll,
                    cost_source=self.cost_source.tag,
                    est_base_seconds=est_base,
                    est_filter_seconds=est_fil,
                    est_filter_base_seconds=est_fil_base)

    # -- standing-bank pricing (DESIGN.md Sec. 3j) ----------------------------
    def plan_bank(self, *, n_docs: int, fragment_chars: int,
                  pattern_chars: int, n_patterns: int, sig_words: int,
                  survivor_frac: float, prunable: bool = True,
                  force: Optional[bool] = None) -> BankPlan:
        """Price one ingest batch against the bank: prefilter or full scan.

        The roles are swapped relative to ``plan``: the batch's ``n_docs``
        rides the row axis, the bank's live slots ride the pattern axis,
        and the backend is always the accept-set SWAR kernel (the bank's
        resident operands are bit planes; re-deriving MXU operands per
        batch would repack the resident side, which the residency
        protocol forbids).  The prefilter is a *single* dispatch whose
        work is patterns x docs x signature words, so it is priced
        through the filter kernel's calibrated curve with the doc count
        as the inner extent.  ``force=True`` pins the filtered strategy
        whenever the bank is prunable (never overrides prunability);
        ``force=False`` pins the full scan.
        """
        D, F, P, Qp = int(n_docs), int(fragment_chars), int(pattern_chars), \
            int(n_patterns)
        if D < 1:
            raise ValueError("batch has no documents")
        if Qp < 1:
            raise ValueError("bank has no live patterns")
        L = F - P + 1
        if L <= 0:
            raise ValueError("pattern longer than fragment")
        t_scan = self.swar_seconds(D, L, P, Qp, "accept")
        strategy, est, t_fil, q_surv = "scan", t_scan, 0.0, Qp
        frac = min(1.0, max(float(survivor_frac), 0.0))
        if prunable and force is not False:
            q_surv_est = max(1, math.ceil(frac * Qp))
            analytic = analytic_filter_seconds(self.roofline, Qp,
                                               sig_words, D)
            t_fil = self._price("filter", analytic, 1, Qp, sig_words, D,
                                False)
            t_ver = self.swar_seconds(D, L, P, q_surv_est, "accept")
            if force or t_fil + t_ver < t_scan:
                strategy = "filter"
                est = t_fil + t_ver
                q_surv = q_surv_est
                reason = (f"bank prefilter+verify {est:.3g}s "
                          f"{'forced' if force else '<'} scan "
                          f"{t_scan:.3g}s (est survivors {frac:.3g} of "
                          f"{Qp})")
            else:
                reason = (f"bank scan {t_scan:.3g}s <= prefilter+verify "
                          f"{t_fil + t_ver:.3g}s")
                t_fil = 0.0
        elif force is False:
            reason = f"bank scan forced ({Qp} patterns x {D} docs)"
        else:
            reason = f"bank scan: no prunable patterns ({Qp} x {D} docs)"
        reason += f" [cost={self.cost_source.tag}]"
        return BankPlan(strategy=strategy, n_docs=D, n_patterns=Qp,
                        est_seconds=est, est_scan_seconds=t_scan,
                        est_filter_seconds=t_fil,
                        est_survivor_frac=frac if strategy == "filter"
                        else 1.0,
                        est_verify_patterns=q_surv, reason=reason,
                        cost_source=self.cost_source.tag)

    # -- batch pricing --------------------------------------------------------
    def plan_batch(self, *, n_rows: int, fragment_chars: int,
                   pattern_chars: int, n_queries: int,
                   backend: Optional[str] = None,
                   chunk_rows: Optional[int] = None,
                   predicate: str = "exact",
                   n_shards: int = 1) -> BatchPlan:
        """Price Q compatible shared-mode queries: coalesced vs. sequential.

        Sequential is Q independent single-pattern launches (each paying
        its own dispatch); coalesced is one ``mode="batched"`` plan over
        all Q patterns (a single fused launch on every backend).  Ties go
        to coalesced: beyond the kernel cost, one launch amortizes
        planning, host packing and result assembly, which the roofline
        does not model.
        """
        if n_queries < 1:
            raise ValueError("n_queries must be >= 1")
        single = self.plan(n_rows=n_rows, fragment_chars=fragment_chars,
                           pattern_chars=pattern_chars, backend=backend,
                           chunk_rows=chunk_rows, predicate=predicate,
                           n_shards=n_shards)
        if n_queries == 1:
            return BatchPlan(coalesced=False, plan=single, n_queries=1,
                             est_coalesced_s=single.est_seconds,
                             est_sequential_s=single.est_seconds,
                             reason="single query: nothing to coalesce "
                                    f"[cost={self.cost_source.tag}]")
        batched = self.plan(n_rows=n_rows, fragment_chars=fragment_chars,
                            pattern_chars=pattern_chars,
                            n_patterns=n_queries, backend=backend,
                            chunk_rows=chunk_rows, predicate=predicate,
                            n_shards=n_shards)
        est_seq = n_queries * single.est_seconds
        est_co = batched.est_seconds
        coalesced = est_co <= est_seq
        if coalesced:
            reason = (f"coalesce {n_queries} queries: {batched.backend} "
                      f"{est_co:.3g}s <= {n_queries}x {single.backend} "
                      f"{est_seq:.3g}s")
        else:
            reason = (f"sequential: {n_queries}x {single.backend} "
                      f"{est_seq:.3g}s < {batched.backend} {est_co:.3g}s")
        reason += f" [cost={self.cost_source.tag}]"
        return BatchPlan(coalesced=coalesced,
                         plan=batched if coalesced else single,
                         n_queries=n_queries, est_coalesced_s=est_co,
                         est_sequential_s=est_seq, reason=reason)
