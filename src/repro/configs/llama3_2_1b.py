"""llama3.2-1b [dense]: small Llama-3 (GQA kv=8).

[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256, head_dim=64, rope theta 500k, tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128_256,
    rope_theta=500_000.0, act="silu", norm="rms", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    rope_theta=1e4, tie_embeddings=True,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
