"""repro.optim"""
