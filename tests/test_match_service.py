"""Match service tests: coalescing correctness vs. per-query oracles,
cache hit semantics (including invalidation on corpus writes), pricing,
queue/ticket mechanics, stats.

The load-bearing property is that a caller can never tell whether their
query ran solo or was fused into a batched launch with strangers' queries:
every scattered result must be bit-identical to a direct
``MatchEngine.match`` call.
"""

import numpy as np
import pytest

from repro.match import MatchEngine, MatchService

R, F, P = 24, 96, 16


def make(seed=0, cache_size=256):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (R, F), np.uint8)
    eng = MatchEngine(frags)
    return rng, eng, MatchService(eng, cache_size=cache_size)


def assert_same_result(got, want):
    np.testing.assert_array_equal(got.best_locs, want.best_locs)
    np.testing.assert_array_equal(got.best_scores, want.best_scores)
    for f in ("scores", "topk_rows", "topk_scores", "hits"):
        a, b = getattr(got, f), getattr(want, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(a, b, err_msg=f)


class TestCoalescingCorrectness:
    @pytest.mark.parametrize("reduction", ["best", "full"])
    def test_fused_equals_oracle(self, reduction):
        rng, eng, svc = make(1)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(6)]
        tickets = [svc.submit(p, reduction=reduction) for p in pats]
        svc.flush()
        assert svc.stats.n_coalesced_launches == 1
        assert svc.stats.n_launches == 1
        for t, p in zip(tickets, pats):
            assert_same_result(t.result, eng.match(p, reduction=reduction))

    def test_fused_topk_per_query_k(self):
        rng, eng, svc = make(2)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(5)]
        ks = [1, 3, 7, 2, 50]                     # includes k > R
        tickets = [svc.submit(p, reduction="topk", k=k)
                   for p, k in zip(pats, ks)]
        svc.flush()
        for t, p, k in zip(tickets, pats, ks):
            want = eng.match(p, reduction="topk", k=k)
            np.testing.assert_array_equal(t.result.topk_scores,
                                          want.topk_scores)
            assert t.result.topk_rows.shape == want.topk_rows.shape

    def test_fused_threshold_per_query_threshold(self):
        rng, eng, svc = make(3)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(5)]
        thrs = [6, 8, 10, 7, 9]
        tickets = [svc.submit(p, reduction="threshold", threshold=t)
                   for p, t in zip(pats, thrs)]
        svc.flush()
        for t, p, thr in zip(tickets, pats, thrs):
            want = eng.match(p, reduction="threshold", threshold=thr)
            np.testing.assert_array_equal(t.result.hits, want.hits)

    def test_rows_subsets_do_not_cross_coalesce(self):
        """Different row subsets are incompatible groups; results still
        match the per-query oracle."""
        rng, eng, svc = make(4)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(4)]
        subs = [None, [3, 1, 8], None, [3, 1, 8]]
        tickets = [svc.submit(p, rows=s) for p, s in zip(pats, subs)]
        svc.flush()
        assert svc.stats.n_launches == 2          # one group per subset
        for t, p, s in zip(tickets, pats, subs):
            assert_same_result(t.result, eng.match(p, rows=s))

    def test_empty_subset_through_service(self):
        rng, eng, svc = make(5)
        pat = rng.integers(0, 4, P, np.uint8)
        res = svc.match(pat, rows=np.array([], dtype=int))
        assert res.best_locs.shape == (0,)

    def test_mixed_pattern_lengths_grouped_separately(self):
        rng, eng, svc = make(6)
        p16 = [rng.integers(0, 4, 16, np.uint8) for _ in range(3)]
        p32 = [rng.integers(0, 4, 32, np.uint8) for _ in range(3)]
        ts = [svc.submit(p) for p in p16 + p32]
        svc.flush()
        assert svc.stats.n_launches == 2
        for t, p in zip(ts, p16 + p32):
            assert_same_result(t.result, eng.match(p))

    def test_two_dim_patterns_pass_through(self):
        rng, eng, svc = make(7)
        pats = rng.integers(0, 4, (4, P), np.uint8)
        res = svc.match(pats, mode="batched")
        assert_same_result(res, eng.match(pats, mode="batched"))

    def test_same_tick_duplicates_share_one_query(self):
        rng, eng, svc = make(8)
        pat = rng.integers(0, 4, P, np.uint8)
        other = rng.integers(0, 4, P, np.uint8)
        ts = [svc.submit(pat), svc.submit(other), svc.submit(pat)]
        svc.flush()
        assert svc.stats.n_launches == 1
        assert ts[0].result is ts[2].result       # deduped within the tick
        assert_same_result(ts[0].result, eng.match(pat))


class TestCacheSemantics:
    def test_cache_hit_on_repeat(self):
        rng, eng, svc = make(10)
        pat = rng.integers(0, 4, P, np.uint8)
        first = svc.match(pat)
        hit = svc.submit(pat)
        svc.tick()
        assert hit.cached and hit.result is first
        assert svc.stats.n_cache_hits == 1
        assert svc.stats.n_launches == 1          # no second launch

    def test_different_k_not_conflated(self):
        rng, eng, svc = make(11)
        pat = rng.integers(0, 4, P, np.uint8)
        a = svc.match(pat, reduction="topk", k=2)
        b = svc.match(pat, reduction="topk", k=5)
        assert a.topk_rows.shape == (2,) and b.topk_rows.shape == (5,)
        assert svc.stats.n_cache_hits == 0

    def test_set_rows_invalidates(self):
        rng, eng, svc = make(12)
        pat = rng.integers(0, 4, P, np.uint8)
        stale = svc.match(pat)
        gen = eng.corpus.generation
        eng.corpus.set_rows(0, rng.integers(0, 4, (R, F), np.uint8))
        assert eng.corpus.generation > gen
        fresh = svc.submit(pat)
        svc.tick()
        assert not fresh.cached
        assert_same_result(fresh.result, eng.match(pat))
        with pytest.raises(AssertionError):
            np.testing.assert_array_equal(fresh.result.best_scores,
                                          stale.best_scores)

    def test_lru_eviction(self):
        rng, eng, svc = make(13, cache_size=2)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(3)]
        for p in pats:
            svc.match(p)                          # fills, evicts pats[0]
        svc.match(pats[0])
        assert svc.stats.n_cache_hits == 0
        svc.match(pats[0])                        # now resident
        assert svc.stats.n_cache_hits == 1


class TestPricingAndStats:
    def test_coalesced_launch_counted(self):
        rng, eng, svc = make(20)
        for p in [rng.integers(0, 4, P, np.uint8) for _ in range(8)]:
            svc.submit(p)
        svc.tick()
        s = svc.stats.snapshot()
        assert s["n_coalesced_launches"] == 1
        assert s["n_coalesced_queries"] == 8
        assert s["n_completed"] == 8
        assert s["avg_latency_s"] > 0 and s["qps"] > 0

    def test_singleton_group_runs_solo(self):
        rng, eng, svc = make(21)
        svc.match(rng.integers(0, 4, P, np.uint8))
        assert svc.stats.n_coalesced_launches == 0
        assert svc.stats.n_launches == 1

    def test_tick_returns_completed_count(self):
        rng, eng, svc = make(22)
        for p in [rng.integers(0, 4, P, np.uint8) for _ in range(3)]:
            svc.submit(p)
        assert svc.tick() == 3
        assert svc.tick() == 0

    def test_bad_request_does_not_poison_tick(self):
        """One tenant's malformed query fails its own ticket; everyone
        else's requests in the same tick still complete."""
        rng, eng, svc = make(24)
        good = svc.submit(rng.integers(0, 4, P, np.uint8))
        bad = svc.submit(np.zeros(F + 1, np.uint8))   # longer than fragment
        done = svc.tick()
        assert done == 2 and good.done and bad.done
        assert good.error is None and good.result is not None
        assert isinstance(bad.error, ValueError)
        with pytest.raises(ValueError, match="longer"):
            bad.wait()
        assert svc.stats.n_failed == 1

    def test_explicit_shared_mode_coalesces(self):
        """mode='shared' on a 1-D pattern is the default spelled out; it
        must coalesce and share cache entries with mode=None."""
        rng, eng, svc = make(25)
        pat = rng.integers(0, 4, P, np.uint8)
        other = rng.integers(0, 4, P, np.uint8)
        svc.submit(pat, mode="shared")
        svc.submit(other)
        svc.tick()
        assert svc.stats.n_coalesced_launches == 1
        hit = svc.submit(pat)
        svc.tick()
        assert hit.cached

    def test_submit_validates(self):
        rng, eng, svc = make(23)
        with pytest.raises(ValueError, match="unknown reduction"):
            svc.submit(np.zeros(P, np.uint8), reduction="nope")
        with pytest.raises(ValueError, match="requires a threshold"):
            svc.submit(np.zeros(P, np.uint8), reduction="threshold")
