"""Sharding rules + HLO analysis unit tests (no big meshes needed: a tiny
forced-host-device mesh exercises the full pjit path)."""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec

from repro.distributed import hlo_analysis, sharding


def tiny_mesh():
    devs = jax.devices()
    if len(devs) < 4:
        pytest.skip("needs >=4 devices (run under forced host device count)")
    return jax.make_mesh((2, 2), ("data", "model"), devices=devs[:4])


class TestRules:
    def test_divisible_dims_shard(self):
        mesh = tiny_mesh()
        spec = sharding.spec_for(("vocab", "embed"), (64, 32), mesh)
        assert spec == PartitionSpec("model", "data")

    def test_indivisible_falls_back_to_replication(self):
        mesh = tiny_mesh()
        spec = sharding.spec_for(("heads", None), (3, 7), mesh)
        assert spec == PartitionSpec(None, None)

    def test_axis_used_once(self):
        mesh = tiny_mesh()
        # both dims map to model -> second one must replicate
        spec = sharding.spec_for(("vocab", "ff"), (64, 64), mesh)
        assert spec == PartitionSpec("model", None)

    def test_batch_composite_axis(self):
        devs = jax.devices()
        if len(devs) < 8:
            pytest.skip("needs 8 devices")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             devices=devs[:8])
        spec = sharding.spec_for(("batch", None), (8, 4), mesh)
        assert spec == PartitionSpec(("pod", "data"), None)

    def test_partial_fallback_drops_leading_axis(self):
        devs = jax.devices()
        if len(devs) < 8:
            pytest.skip("needs 8 devices")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             devices=devs[:8])
        # batch=2 cannot shard over pod*data (4) but can over data (2)
        spec = sharding.spec_for(("batch",), (2,), mesh)
        assert spec == PartitionSpec("data")


class TestHloShapes:
    def test_shape_bytes(self):
        assert hlo_analysis._shape_bytes(
            hlo_analysis._parse_shapes("bf16[4,8]{1,0}")) == 64
        assert hlo_analysis._shape_bytes(
            hlo_analysis._parse_shapes("(f32[2,2]{1,0}, s32[3]{0})")) == 28
        assert hlo_analysis._shape_bytes(
            hlo_analysis._parse_shapes("f32[]")) == 4

    def test_split_rhs(self):
        t = hlo_analysis._split_rhs(
            "bf16[16,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}")
        assert t[0] == "bf16[16,128]{1,0}"
        assert t[1] == "dot"
        assert "lhs_contracting_dims" in t[3]

    def test_split_rhs_tuple_type(self):
        t = hlo_analysis._split_rhs(
            "(f32[2]{0}, s32[]) while(%init), condition=%c, body=%b")
        assert t[1] == "while"


class TestWalker:
    def test_while_trip_multiplication(self):
        """A jitted scan's flops must be multiplied by the trip count."""
        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        x = jax.ShapeDtypeStruct((64, 64), np.float32)
        text = jax.jit(f).lower(x).compile().as_text()
        cost = hlo_analysis.analyze_hlo(text)
        want = 7 * 2 * 64 * 64 * 64   # 7 iterations of a 64^3 matmul
        assert cost.flops == pytest.approx(want, rel=0.3)

    def test_collectives_detected_under_pjit(self):
        mesh = tiny_mesh()
        from jax.sharding import NamedSharding

        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((32, 64), np.float32)
        b = jax.ShapeDtypeStruct((64, 16), np.float32)
        sa = NamedSharding(mesh, PartitionSpec("data", "model"))
        sb = NamedSharding(mesh, PartitionSpec("model", None))
        out_s = NamedSharding(mesh, PartitionSpec("data", None))
        comp = jax.jit(f, in_shardings=(sa, sb), out_shardings=out_s) \
            .lower(a, b).compile()
        cost = hlo_analysis.analyze_hlo(comp.as_text())
        # contraction over the model axis must reduce across shards
        assert cost.total_coll_bytes > 0

    def test_dot_flops_partitioned(self):
        mesh = tiny_mesh()
        from jax.sharding import NamedSharding
        a = jax.ShapeDtypeStruct((32, 64), np.float32)
        b = jax.ShapeDtypeStruct((64, 16), np.float32)
        rep = NamedSharding(mesh, PartitionSpec())
        comp = jax.jit(lambda x, y: x @ y, in_shardings=(rep, rep),
                       out_shardings=rep).lower(a, b).compile()
        cost = hlo_analysis.analyze_hlo(comp.as_text())
        assert cost.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.2)


class TestEndToEndTinyMesh:
    def test_elastic_checkpoint_restore_onto_mesh(self, tmp_path):
        """A checkpoint written without any mesh restores sharded onto a
        2x2 mesh (elastic reshard-on-load)."""
        mesh = tiny_mesh()
        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(tmp_path, async_write=False)
        tree = {"w": jnp.arange(64.0).reshape(8, 8),
                "b": jnp.ones((4,))}
        mgr.save(3, tree, blocking=True)
        shardings = {
            "w": NamedSharding(mesh, PartitionSpec("data", "model")),
            "b": NamedSharding(mesh, PartitionSpec()),
        }
        restored, step = mgr.restore(tree, shardings=shardings)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.spec == PartitionSpec("data", "model")
        assert len(restored["w"].sharding.device_set) == 4

    def test_smoke_model_shards_and_runs(self):
        """A reduced arch trains one jitted step on a real 2x2 mesh."""
        mesh = tiny_mesh()
        from repro.configs import get_config
        from repro.distributed import context as dc
        from repro.models import model
        from repro.models.spec import tree_axes
        from repro.optim import adamw
        from repro.runtime import steps as rsteps

        cfg = get_config("llama3.2-1b", smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        pspecs = model.param_specs(cfg)
        shard = sharding.shardings_for(tree_axes(pspecs), params, mesh)
        params = jax.tree.map(jax.device_put, params, shard)
        opt_state = adamw.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab, (4, 16)),
                 "labels": rng.integers(0, cfg.vocab, (4, 16))}
        batch = {k: jax.device_put(v, sharding.batch_sharding(mesh))
                 for k, v in batch.items()}
        step = jax.jit(rsteps.make_train_step(cfg, adamw.OptConfig()))
        with dc.activation_sharding(mesh):
            new_params, _, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
