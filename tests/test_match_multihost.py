"""Multi-controller match stack (DESIGN.md Sec. 3k).

Two test groups:

* ``TestCpuDistributed`` spawns a real 2-process CPU ``jax.distributed``
  job (4 forced host devices per process -> the same 8-shard mesh a
  single process gets) via ``repro.launch.cluster.run_cpu_demo`` and
  asserts the bit-identity gates: threshold / forced-filter / IUPAC /
  top-k / best results identical to the 1-process-8-shard baseline,
  zero false negatives on planted needles, flat per-host pack counters
  -- including after ``append_rows`` growth and tombstone compaction.

* ``TestTransferLedger`` is the single-process regression for the
  per-chunk host-transfer fix: a sharded threshold scan must keep its
  reduction state device-side (per-row reduced pulls + hot-row gathers
  only), never pulling the full (rows, locs) score block per chunk.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.launch.cluster import run_cpu_demo          # noqa: E402
from repro.launch.mesh import make_row_mesh            # noqa: E402
from repro.match import (MatchEngine, MatchQuery,      # noqa: E402
                         MatchService)

N_PROCESSES = 2
LOCAL_DEVICES = 4


@pytest.fixture(scope="module")
def demo():
    """One 2-process jax.distributed run + 1-process baseline (shared:
    the subprocess spawn dominates, ~15 s)."""
    return run_cpu_demo(n_processes=N_PROCESSES,
                        local_devices=LOCAL_DEVICES)


class TestCpuDistributed:
    def test_gate_bit_identical(self, demo):
        assert demo["identical"], demo["mismatches"]
        assert demo["n_shards"] == N_PROCESSES * LOCAL_DEVICES

    @pytest.mark.parametrize("stage", [
        "threshold_scan", "threshold_filtered", "iupac_wildcard", "topk",
        "best", "threshold_after_append", "topk_after_append",
        "threshold_after_tombstone", "threshold_after_compact",
        "best_after_compact"])
    def test_stage_matches_single_process(self, demo, stage):
        multi = demo["multiprocess"][0]["results"][stage]
        single = demo["single"]["results"][stage]
        for key in single:
            if key == "collective_bytes":
                # Byte accounting legitimately differs across controller
                # topologies (a multi-controller gather is a collective);
                # results must not.
                continue
            assert multi[key] == single[key], (stage, key)

    def test_processes_agree(self, demo):
        # SPMD contract: every controller computes the same replicated
        # answer -- including the transfer ledger.
        assert (demo["multiprocess"][1]["results"]
                == demo["multiprocess"][0]["results"])

    def test_merges_device_side(self, demo):
        for run in (*demo["multiprocess"], demo["single"]):
            assert run["merge_path"] == "device"
            assert run["collective_bytes"] > 0
            assert run["n_collectives"] > 0

    def test_zero_false_negatives(self, demo):
        # The workload plants a 32-char needle at known (row, loc)
        # positions; _demo_workload raises in-process if any goes
        # missing, so worker exit 0 is the gate -- re-assert the hits
        # here on the returned records for a readable failure.
        hits = {(r, l) for r, l, _ in
                demo["multiprocess"][0]["results"]["threshold_scan"]["hits"]}
        assert {(3, 5), (500, 5), (1021, 5), (11, 10)} <= hits
        grown = {(r, l) for r, l, _ in
                 demo["multiprocess"][0]["results"]
                 ["threshold_after_append"]["hits"]}
        assert (1024 + 40, 20) in grown

    def test_tombstone_then_compact(self, demo):
        res = demo["multiprocess"][0]["results"]
        after_tomb = {r for r, _, _ in res["threshold_after_tombstone"]
                      ["hits"]}
        assert 3 not in after_tomb and 500 not in after_tomb
        after_comp = {(r, l) for r, l, _ in res["threshold_after_compact"]
                      ["hits"]}
        # ids above the two reclaimed rows shift down.
        assert {(10, 10), (1019, 5), (1062, 20)} <= after_comp

    def test_pack_counters_flat_per_host(self, demo):
        # Each process packs only its own shard blocks, exactly once,
        # through the whole append/tombstone/compact sequence.
        for run in demo["multiprocess"]:
            assert run["pack_counts"]["swar"] == 1
            assert run["pack_counts"]["host_total"] == \
                demo["single"]["pack_counts"]["host_total"]
        assert (demo["multiprocess"][0]["pack_counts"]
                == demo["single"]["pack_counts"])


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs >= 8 devices")
class TestTransferLedger:
    R, F, P = 4096, 64, 32

    @pytest.fixture()
    def engine(self):
        rng = np.random.default_rng(3)
        frags = rng.integers(0, 4, (self.R, self.F), np.uint8)
        self.pat = rng.integers(0, 4, self.P, np.uint8)
        for r in (7, 1100, 4000):
            frags[r, 9:9 + self.P] = self.pat
        return MatchEngine(frags, mesh=make_row_mesh(8),
                           record_runtimes=False)

    def test_threshold_scan_stays_device_side(self, engine):
        q = MatchQuery.exact(self.pat, reduction="threshold",
                             threshold=float(self.P), filter=False)
        res = engine.match(q)
        assert {r for r, _, _ in res.hits} >= {7, 1100, 4000}
        assert res.merge_path == "device"
        assert res.collective_bytes > 0
        m = engine.merger
        # The old path pulled the full (chunk, L) score block every
        # chunk: R * L * 4 bytes for the whole scan.  The fix pulls only
        # per-row reduced state and the hot rows' score vectors.
        L = self.F - self.P + 1
        full_block = self.R * L * 4
        pulled = m.reduced_pull_bytes + m.block_pull_bytes
        assert pulled < full_block // 4, (pulled, full_block)

    def test_topk_merges_on_device(self, engine):
        res = engine.match(MatchQuery.exact(self.pat, reduction="topk", k=5))
        assert set(res.topk_rows[:3].tolist()) == {7, 1100, 4000}
        assert res.merge_path == "device"
        assert res.collective_bytes > 0

    def test_unsharded_engine_reports_host_path(self):
        rng = np.random.default_rng(3)
        e1 = MatchEngine(rng.integers(0, 4, (256, 64), np.uint8))
        res = e1.match(MatchQuery.exact(
            rng.integers(0, 4, 16, np.uint8), reduction="best"))
        assert res.merge_path == "host"
        assert res.collective_bytes == 0

    def test_service_stats_surface_merge_path(self, engine):
        svc = MatchService(engine)
        svc.submit(self.pat, reduction="threshold",
                   threshold=float(self.P))
        svc.flush()
        snap = svc.stats.snapshot()
        assert snap["merge_path"] == "device"
        assert snap["collective_bytes"] > 0
