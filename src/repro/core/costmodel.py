"""Step-accurate throughput/energy model of CRAM-PM (paper Secs. 4-5).

Reproduces the paper's evaluation pipeline: stages (1)-(8) of Sec. 4,
per-stage latency and energy from the device model (``gates``/``tech``) plus
NVSIM-style periphery, composed over the pattern schedule (Naive / Oracular,
plain / Opt, near- / long-term MTJ).

Calibration policy (documented, single-sourced):

* Per-op latency ``t_op = switching + periphery`` where periphery =
  decode + SMC issue + BL drive = 0.745 ns.  This reproduces the paper's
  long-term boost of ~2.15x exactly: (3+0.745)/(1+0.745) = 2.146.
* Row-sequential preset latency = n_rows * write_latency *
  ``SMC_WRITE_PIPELINE`` (write pipelining inside the SMC; the only free
  scalar, calibrated once so the Naive DNA run lands on the paper's
  23 215.3 hours; everything else -- Oracular hours, preset shares, Opt
  speedups, sensitivity curves -- is then *derived*).
* Gate energy per row = I_crit_eff * V_gate_center * t_switch (one output
  MTJ switching event at the gate's operating point).  This lands the
  unoptimized preset energy share at ~42-44% (paper: 43.86%) with no tuning.

Baselines (GPU / NMP / Ambit / Pinatubo) are analytic models parameterized
from published data; see class docstrings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from . import gates
from .matcher import count_alignment_ops, plan_layout
from .scheduler import oracular_passes_analytic
from .tech import LONG_TERM, NEAR_TERM, MTJTech, Periphery

SMC_WRITE_PIPELINE = 0.515  # calibrated once against Naive = 23215.3 h
N_BANKS = 8                 # EverSpin-style banking (Sec. 3.4)


@dataclasses.dataclass(frozen=True)
class Design:
    """A CRAM-PM design point for the DNA case study (Sec. 4)."""

    tech: MTJTech = NEAR_TERM
    periphery: Periphery = Periphery()
    n_arrays: int = 300
    n_rows: int = 10_000
    n_cols: int = 2_400            # ~24 Mb per array (Sec. 3.4)
    pattern_chars: int = 100
    opt: bool = False              # gang-preset schedule (Sec. 3.4)
    ref_len: int = 3_000_000_000

    @property
    def t_op_ns(self) -> float:
        """One row-parallel logic step (switch + decode + SMC + BL drive)."""
        p = self.periphery
        return (self.tech.switching_latency_ns + p.decode_latency_ns
                + p.smc_issue_latency_ns + p.bl_drive_latency_ns)

    @property
    def total_rows(self) -> int:
        return self.n_arrays * self.n_rows


# Average per-row gate energies, from the analog device model.
def _gate_energy_table(tech: MTJTech) -> Dict[str, float]:
    table = {}
    for g in ("NOR", "OR", "NAND", "AND", "INV", "COPY", "MAJ3", "MAJ5", "TH"):
        v = gates.vgate_center(g, tech)
        table[g] = tech.i_crit_eff_ua * 1e-6 * v * tech.switching_latency_ns * 1e-9 * 1e12  # pJ
    return table


@dataclasses.dataclass
class StageCost:
    latency_s: float = 0.0
    energy_j: float = 0.0

    def __iadd__(self, other: "StageCost"):
        self.latency_s += other.latency_s
        self.energy_j += other.energy_j
        return self


@dataclasses.dataclass
class PassCost:
    """Latency/energy of one substrate pass, broken down by stage (Sec. 4)."""

    stages: Dict[str, StageCost]
    n_alignments: int

    @property
    def latency_s(self) -> float:
        return sum(s.latency_s for s in self.stages.values())

    @property
    def energy_j(self) -> float:
        return sum(s.energy_j for s in self.stages.values())

    def share(self, stage: str, kind: str = "latency") -> float:
        total = self.latency_s if kind == "latency" else self.energy_j
        val = (self.stages[stage].latency_s if kind == "latency"
               else self.stages[stage].energy_j)
        return val / total if total else 0.0


def alignment_census(design: Design) -> dict:
    return count_alignment_ops(design.pattern_chars, design.n_cols,
                               opt=design.opt)


def pass_cost(design: Design) -> PassCost:
    """One pass = write pattern (1) + per-alignment stages (2)-(8)."""
    tech, p = design.tech, design.periphery
    census = alignment_census(design)
    layout = plan_layout(design.n_cols, design.pattern_chars,
                         scratch_budget=128)
    n_align = layout.n_alignments
    e_gate = _gate_energy_table(tech)
    n_rows = design.n_rows

    logic_counts = {k: v for k, v in census.items() if k in e_gate}
    n_logic = census["TOTAL_LOGIC"]
    n_presets = census["PRESETS"]
    score_bits = census["SCORE_BITS"]

    stages: Dict[str, StageCost] = {}

    # Stage 1: write pattern into every row (row-parallel word write per row,
    # rows sequential; arrays in parallel).  2 bits/char.
    write_bits_per_row = 2 * design.pattern_chars
    stages["1_write_pattern"] = StageCost(
        latency_s=n_rows * tech.write_latency_ns * 1e-9,
        energy_j=(n_rows * write_bits_per_row * tech.write_energy_pj * 1e-12
                  * design.n_arrays),
    )

    # Stages 2+5: presets.  Energy identical for both schedules (same number
    # of preset cell-switches, paper Sec. 5.1); latency differs drastically.
    preset_energy = (n_presets * n_rows * tech.write_energy_pj * 1e-12
                     * design.n_arrays * n_align)
    if design.opt:
        preset_latency = n_presets * design.t_op_ns * 1e-9 * n_align
    else:
        preset_latency = (n_presets * n_rows * tech.write_latency_ns
                          * SMC_WRITE_PIPELINE * 1e-9 * n_align)
    stages["2_5_presets"] = StageCost(preset_latency, preset_energy)

    # Stages 3+6: bit-line activation (BSL voltage setup per micro-op).
    stages["3_6_bl_drive"] = StageCost(
        latency_s=n_logic * p.bl_drive_latency_ns * 1e-9 * n_align * 0.0,
        energy_j=(n_logic * 3.5 * p.bl_drive_energy_pj * 1e-12
                  * design.n_arrays * n_align),
    )
    # BL drive latency is part of t_op (see Design.t_op_ns); kept at zero here
    # to avoid double counting, energy charged per driven column.

    # Stages 4+7: match-phase and score-phase gate execution.
    per_char_ops = {"NOR": 3, "COPY": 3, "TH": 2}  # Fig. 4a per character
    match_ops = {k: per_char_ops.get(k, 0) * design.pattern_chars
                 for k in logic_counts}
    score_ops = {k: logic_counts[k] - match_ops.get(k, 0)
                 for k in logic_counts}

    def phase_cost(ops: Dict[str, int]) -> StageCost:
        n = sum(ops.values())
        e = sum(cnt * e_gate[k] for k, cnt in ops.items())
        return StageCost(
            latency_s=n * design.t_op_ns * 1e-9 * n_align,
            energy_j=e * 1e-12 * n_rows * design.n_arrays * n_align,
        )

    stages["4_match"] = phase_cost(match_ops)
    stages["7_score"] = phase_cost(score_ops)

    # Stage 8: score read-out (score buffer; one row at a time per bank).
    readout_latency = (n_rows / N_BANKS) * tech.read_latency_ns * 1e-9 * n_align
    readout_energy = (n_rows * score_bits * tech.read_energy_pj * 1e-12
                      * design.n_arrays * n_align)
    compute_latency = (stages["4_match"].latency_s + stages["7_score"].latency_s
                       + (stages["2_5_presets"].latency_s if design.opt else 0))
    if design.opt:
        # Masked behind gang presets + compute via banking (Secs. 3.2/3.4).
        readout_latency = max(0.0, readout_latency - compute_latency)
    stages["8_readout"] = StageCost(readout_latency, readout_energy)

    return PassCost(stages, n_align)


@dataclasses.dataclass
class RunResult:
    n_patterns: int
    n_passes: float
    total_time_s: float
    total_energy_j: float

    @property
    def match_rate(self) -> float:
        return self.n_patterns / self.total_time_s

    @property
    def power_mw(self) -> float:
        return self.total_energy_j / self.total_time_s * 1e3

    @property
    def efficiency(self) -> float:
        """patterns / s / mW (paper's compute-efficiency metric)."""
        return self.match_rate / self.power_mw


def run_workload(design: Design, n_patterns: int, scheduling: str,
                 kmer: int | None = None) -> RunResult:
    """End-to-end DNA run (Fig. 5): Naive or Oracular x plain/Opt design.

    ``kmer=None`` uses the adaptive seed length (scheduler.adaptive_seed_k).
    """
    pc = pass_cost(design)
    if scheduling == "naive":
        n_passes = float(n_patterns)
    elif scheduling == "oracular":
        n_passes = oracular_passes_analytic(
            n_patterns, design.total_rows, design.ref_len,
            design.pattern_chars, k=kmer)
    else:
        raise ValueError(scheduling)
    return RunResult(
        n_patterns=n_patterns,
        n_passes=n_passes,
        total_time_s=n_passes * pc.latency_s,
        total_energy_j=n_passes * pc.energy_j,
    )


def peak_array_current_a(design: Design) -> float:
    """Peak current of one array during row-parallel compute (Sec. 3.4)."""
    i_per_row = design.tech.i_crit_eff_ua * 1e-6 * 2.0  # output + input paths
    return design.n_rows * i_per_row


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GPUBaseline:
    """BarraCUDA-class GPU BWA aligner (paper refs [12],[26]).

    Published end-to-end throughput ~25M reads/hour; the pattern-matching
    kernel is 88% of runtime at 4 mismatches (paper footnote 1), so the
    kernel-only rate we compare against is end_to_end / 0.88.
    """

    reads_per_hour: float = 25e6
    kernel_share: float = 0.88
    board_power_w: float = 250.0

    @property
    def match_rate(self) -> float:
        return self.reads_per_hour / 3600.0 / self.kernel_share

    @property
    def efficiency(self) -> float:
        return self.match_rate / (self.board_power_w * 1e3 * self.kernel_share)


@dataclasses.dataclass(frozen=True)
class NMPBaseline:
    """HMC + ARM Cortex-A5 logic-layer model (paper Sec. 4).

    64 single-issue in-order cores at 1 GHz (peak 5.12 W); four links at
    160 GB/s.  Throughput = max(compute, memory) over profiled instruction
    and byte counts per work item.  ``hyp=True`` = 128 cores, zero memory
    overhead (NMP-Hyp).
    """

    n_cores: int = 64
    freq_hz: float = 1e9
    ipc: float = 1.0
    link_bw: float = 4 * 160e9
    core_power_w: float = 0.08
    dram_power_w: float = 10.0
    hyp: bool = False

    def time_per_item(self, instrs: float, mem_bytes: float) -> float:
        t_compute = instrs / (self.n_cores * self.freq_hz * self.ipc)
        if self.hyp:
            return instrs / (2 * self.n_cores * self.freq_hz * self.ipc)
        t_mem = mem_bytes / self.link_bw
        return max(t_compute, t_mem)

    def run(self, n_items: float, instrs: float, mem_bytes: float) -> RunResult:
        t = n_items * self.time_per_item(instrs, mem_bytes)
        cores = self.n_cores * (2 if self.hyp else 1)
        power = cores * self.core_power_w + (0 if self.hyp else self.dram_power_w)
        return RunResult(int(n_items), float(n_items), t, t * power)


# Per-application workload characterization (Table 4).  For each app:
# CRAM-PM per-item micro-op counts (logic, presets) and per-item NMP cost
# (instructions, memory bytes).  CRAM items map one-per-row; throughput
# follows from row-level parallelism over the arrays that hold the dataset.
@dataclasses.dataclass(frozen=True)
class AppModel:
    name: str
    n_items: float            # work items (patterns / vectors / words)
    item_bits: int            # payload bits per row
    cram_logic_ops: int       # per item (one row)
    cram_presets: int
    cram_rows_total: int      # rows across all arrays holding the dataset
    nmp_instrs: float         # per item
    nmp_bytes: float          # per item
    cram_array_rows: int = 512


def _popcount_ops(n_bits: int) -> Tuple[int, int]:
    """(logic, presets) of a reduction tree over n_bits (from the ISA)."""
    from .isa import CodeGen, ColumnAllocator
    cg = CodeGen(ColumnAllocator(0, 4096))
    cols = cg.scratch.alloc(n_bits)
    cg.popcount_tree(cols)
    gang, row = cg.prog.n_presets()
    return cg.prog.n_logic_ops(), gang + row


def _byte_match_ops(n_chars: int) -> Tuple[int, int]:
    """(logic, presets) for matching n 8-bit characters + popcount."""
    from .isa import CodeGen, ColumnAllocator
    cg = CodeGen(ColumnAllocator(0, 8192))
    match_bits = []
    for _ in range(n_chars):
        xors = []
        for _ in range(8):
            a, b = cg.scratch.alloc(2)
            xors.append(cg.xor(a, b))
        # OR-reduce the 8 bit-diffs, then INV -> char-match bit.
        while len(xors) > 1:
            a, b = xors.pop(), xors.pop()
            o = cg.scratch.alloc(1)[0]
            cg.gate("OR", (a, b), o)
            cg.scratch.release([a, b])
            xors.append(o)
        m = cg.scratch.alloc(1)[0]
        cg.gate("INV", (xors[0],), m)
        match_bits.append(m)
    cg.popcount_tree(match_bits)
    gang, row = cg.prog.n_presets()
    return cg.prog.n_logic_ops(), gang + row


def table4_apps() -> Dict[str, AppModel]:
    bc_logic, bc_presets = _popcount_ops(32)
    sm_logic, sm_presets = _byte_match_ops(10)
    wc_logic, wc_presets = _byte_match_ops(4)        # 32-bit word match
    # RC4: 248-bit keystream XOR per word-segment: 248 bit-XORs.
    from .isa import CodeGen, ColumnAllocator
    cg = CodeGen(ColumnAllocator(0, 2048))
    for _ in range(248):
        a, b = cg.scratch.alloc(2)
        x = cg.xor(a, b)
        cg.scratch.release([a, b, x])
    rc4_logic = cg.prog.n_logic_ops()
    rc4_presets = sum(cg.prog.n_presets())
    # NMP per-item costs (in-order A5, 1 IPC): BC uses a LUT popcount
    # (12 instr); SM compares 10 byte-chars (~60 instr); RC4's PRGA is
    # inherently serial (~15 instr/byte over 31 bytes); WC matches each text
    # word against ~100 search words (~30 instr each).  WC on CRAM-PM uses
    # the paper's data-replication trade-off (Sec. 2.6): each row holds one
    # (text word, search word) pair, so all search words match concurrently
    # -- this is what produces the paper's largest match-rate gain (133552x
    # long-term, Fig. 9).
    return {
        "BC": AppModel("BC", 1e6, 32, bc_logic, bc_presets,
                       cram_rows_total=int(1e6),
                       nmp_instrs=12, nmp_bytes=4),
        "SM": AppModel("SM", 10_396_542, 160, sm_logic, sm_presets,
                       cram_rows_total=10_396_542,
                       nmp_instrs=60, nmp_bytes=20),
        "RC4": AppModel("RC4", 10_396_542, 248, rc4_logic, rc4_presets,
                        cram_rows_total=10_396_542,
                        nmp_instrs=465, nmp_bytes=62, cram_array_rows=1024),
        "WC": AppModel("WC", 1_471_016, 32, wc_logic, wc_presets,
                       cram_rows_total=1_471_016 * 100,
                       nmp_instrs=3000, nmp_bytes=640),
    }


def app_cram_run(app: AppModel, tech: MTJTech, opt: bool = True) -> RunResult:
    """All items resident, one per row; every row computes in parallel.

    One program execution processes cram_rows_total items; with row-parallel
    lock-step execution the time is that of a single row's program.
    """
    design = Design(tech=tech, opt=opt, n_rows=app.cram_array_rows)
    e_gate = _gate_energy_table(tech)
    e_avg = sum(e_gate.values()) / len(e_gate)
    t_ops = app.cram_logic_ops * design.t_op_ns * 1e-9
    if opt:
        t_presets = app.cram_presets * design.t_op_ns * 1e-9
    else:
        t_presets = (app.cram_presets * app.cram_array_rows
                     * tech.write_latency_ns * SMC_WRITE_PIPELINE * 1e-9)
    t_total = t_ops + t_presets
    energy = (app.cram_logic_ops * e_avg + app.cram_presets
              * tech.write_energy_pj) * 1e-12 * app.cram_rows_total
    return RunResult(int(app.n_items), 1.0, t_total, energy)


def app_nmp_run(app: AppModel, hyp: bool = False) -> RunResult:
    nmp = NMPBaseline(hyp=hyp)
    return nmp.run(app.n_items, app.nmp_instrs, app.nmp_bytes)


def dna_nmp_run(design: Design, n_patterns: int, hyp: bool = False) -> RunResult:
    """NMP DNA model: stream-scan the reference per pattern."""
    nmp = NMPBaseline(hyp=hyp)
    instrs = design.ref_len * design.pattern_chars * 2.0  # cmp+acc per char
    mem_bytes = design.ref_len * design.pattern_chars / 4.0  # 2-bit chars
    return nmp.run(n_patterns, instrs, mem_bytes)


# ---------------------------------------------------------------------------
# Gate-level characterization (Fig. 11)
# ---------------------------------------------------------------------------

# Bulk-bitwise baseline constants, GOps/s on a 32MB vector.  The CRAM-PM
# paper reports *speedup ratios* against Ambit (MICRO'17) and Pinatubo
# (DAC'16) without disclosing the absolute baseline operating points, so the
# anchored constants below are DERIVED from the paper's near-term ratios
# (NOT: 178x, XOR: 1.34x, Pinatubo OR: ~6x) applied to our structural
# near-term model; Ambit OR/NAND (no ratio given) are set to NOT/2 following
# Ambit's triple-row-activation cost.  The benchmark reports both our model
# ratios and the paper's claimed ratios side by side.
AMBIT_GOPS = {"NOT": 255.0, "OR": 127.5, "NAND": 127.5, "XOR": 11292.0}
PINATUBO_OR_GOPS = 7565.7

# CRAM-PM per-bit micro-op cost (logic steps, gang presets) for bulk ops:
BULK_OP_STEPS = {"NOT": (1, 1), "OR": (1, 1), "NAND": (1, 1), "XOR": (3, 3)}


def bulk_gops(op: str, tech: MTJTech, vector_mb: int = 32,
              n_rows: int = 10_000, n_cols: int = 2_400) -> float:
    """CRAM-PM bulk bitwise throughput, data-resident (gang presets).

    The 32MB operand vectors live across as many 24Mb arrays as needed
    (3 cells per element: two operands + result); all arrays and all rows
    compute in parallel, one element column at a time (Sec. 2.4 semantics:
    "lack of actual data transfer within the array").
    """
    design = Design(tech=tech, opt=True, n_rows=n_rows, n_cols=n_cols)
    n_bits = vector_mb * 2**20 * 8
    cells = 3 * n_bits
    n_arrays = math.ceil(cells / (n_rows * n_cols))
    elems_per_step = n_rows * n_arrays
    logic, presets = BULK_OP_STEPS[op]
    t_elem = (logic + presets) * design.t_op_ns * 1e-9
    return elems_per_step / t_elem / 1e9
