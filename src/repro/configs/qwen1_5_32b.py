"""qwen1.5-32b [dense]: MHA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064, head_dim=128, QKV bias.  40 heads pad to 48 for TP=16
(Megatron-style head padding; DESIGN.md sharding map).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab=152_064,
    qkv_bias=True, rope_theta=1e6, act="silu", norm="rms",
    microbatch=4,
)

SMOKE = ModelConfig(
    name="qwen1.5-32b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    qkv_bias=True, rope_theta=1e4,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
