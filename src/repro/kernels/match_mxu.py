"""One-hot correlation string match on the MXU -- Pallas TPU kernel.

Hardware-codesign variant (DESIGN.md Sec. 2b): score(r, o, q) =
sum_i sum_c ref1h[r, o+i, c] * pat1h[q, i, c] is a sliding contraction.
Where CRAM-PM spends 7 gate steps per character, the systolic array
contracts 128 character-channels of 128+ alignments against Q patterns per
pass.  The trick that makes it MXU-shaped: in char-major one-hot layout the
im2col window matrix is a *stride-4 view* of the flat reference row,

    A[l, k] = flat[(o0 + i0 + l) * 4 + k],   k in [0, 128)

so a (L_TILE, 128) operand tile is assembled from 32 static slices, and the
whole alignment tile reduces to ceil(4P/128) MXU matmuls of
(L_TILE, 128) @ (128, Q).

Inputs:
  ref_flat (R, F4)      bf16 -- one-hot reference rows, char-major flattened
                                (F4 = 4*F_padded), zero padded.
  pat_mat  (P4, Q)      bf16 -- one-hot patterns, (i*4+c, q), zero padded to
                                a multiple of 128 rows.
  out      (R, L_pad, Q) f32 -- scores (caller trims to L).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

L_TILE = 256
K_CHUNK = 128            # = 32 characters * 4 channels
CHARS_PER_CHUNK = K_CHUNK // 4


def _mxu_kernel(ref_ref, pat_ref, out_ref, *, n_chunks: int, q: int):
    loc0 = pl.program_id(1) * L_TILE
    acc = jnp.zeros((L_TILE, q), jnp.float32)
    for chunk in range(n_chunks):
        start = (loc0 + chunk * CHARS_PER_CHUNK) * 4
        seg = ref_ref[0, pl.ds(start, (L_TILE + CHARS_PER_CHUNK) * 4)]
        seg2 = seg.reshape(L_TILE + CHARS_PER_CHUNK, 4)
        # A[l, j*4+c] = seg2[l+j, c] -- 32 static slices, no data movement
        # beyond VMEM shuffles.
        a = jnp.concatenate(
            [seg2[j:j + L_TILE] for j in range(CHARS_PER_CHUNK)], axis=1)
        b = pat_ref[pl.ds(chunk * K_CHUNK, K_CHUNK), :]
        acc += jnp.dot(a, b, preferred_element_type=jnp.float32)
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("l_pad", "interpret"))
def match_mxu(ref_flat: jnp.ndarray, pat_mat: jnp.ndarray, *, l_pad: int,
              interpret: bool = False) -> jnp.ndarray:
    """ref_flat (R, F4) bf16, pat_mat (P4, Q) bf16 -> (R, l_pad, Q) f32.

    ``l_pad`` (multiple of L_TILE) alignment rows are produced; the caller
    must pad ref_flat so every window read stays in bounds
    (F4 >= (l_pad + P4/4) * 4) -- use ``ops.match_scores`` which handles all
    padding and trimming.
    """
    R, F4 = ref_flat.shape
    P4, Q = pat_mat.shape
    if P4 % K_CHUNK or Q % 128:
        raise ValueError("pattern rows must be padded to 128, Q to 128")
    if l_pad % L_TILE:
        raise ValueError("l_pad must be a multiple of L_TILE")
    n_chunks = P4 // K_CHUNK
    deepest = (l_pad - L_TILE + (n_chunks - 1) * CHARS_PER_CHUNK
               + L_TILE + CHARS_PER_CHUNK) * 4
    if deepest > F4:
        raise ValueError(f"ref_flat too short: need {deepest}, have {F4}")
    grid = (R, l_pad // L_TILE)
    kernel = functools.partial(_mxu_kernel, n_chunks=n_chunks, q=Q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, F4), lambda r, t: (r, 0)),
            pl.BlockSpec((P4, Q), lambda r, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L_TILE, Q), lambda r, t: (r, t, 0)),
        out_shape=jax.ShapeDtypeStruct((R, l_pad, Q), jnp.float32),
        interpret=interpret,
    )(ref_flat, pat_mat)
