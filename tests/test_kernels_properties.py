"""Randomized property tests for the Pallas kernels (hypothesis-driven).

Split out of ``test_kernels.py`` so a missing ``hypothesis`` install skips
only this module instead of erroring the whole suite at collection; install
dev deps with ``pip install -r requirements-dev.txt``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.matcher import sliding_scores  # noqa: E402
from repro.kernels import ops  # noqa: E402

from test_kernels import random_case  # noqa: E402


class TestMatchSwarProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 6), st.integers(2, 80), st.data())
    def test_property_matches_oracle(self, r, f, data):
        p = data.draw(st.integers(1, f))
        seed = data.draw(st.integers(0, 2**31))
        frags, pat = random_case(r, f, p, seed=seed)
        got = np.asarray(ops.match_scores(frags, pat, method="swar"))
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_score_bounds_and_exact_hit(self, seed):
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (4, 60), np.uint8)
        pat = rng.integers(0, 4, 12, np.uint8)
        loc = int(rng.integers(0, 49))
        frags[2, loc:loc + 12] = pat
        s = np.asarray(ops.match_scores(frags, pat, method="swar"))
        assert (s >= 0).all() and (s <= 12).all()
        assert s[2, loc] == 12


class TestMatchMXUProperties:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_agrees_with_swar(self, seed):
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (3, 90), np.uint8)
        pat = rng.integers(0, 4, int(rng.integers(4, 40)), np.uint8)
        a = np.asarray(ops.match_scores(frags, pat, method="swar"))
        b = np.asarray(ops.match_scores(frags, pat, method="mxu"))
        np.testing.assert_array_equal(a, b)


class TestPopcountProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
    def test_property_single_words(self, vals):
        words = np.array(vals, np.uint32)[:, None]
        got = np.asarray(ops.popcount(words))
        want = np.array([bin(v).count("1") for v in vals], np.int32)
        np.testing.assert_array_equal(got, want)
