"""Multi-tenant match query service (DESIGN.md Sec. 3d).

``MatchService`` fronts a shared ``MatchEngine`` for many concurrent
callers.  Each caller's query is tiny; what kills throughput at scale is
that every one of them pays a full kernel dispatch -- exactly the
launch-overhead regime the planner's roofline flags as worst.  The paper's
substrate amortizes this by searching many patterns against the resident
reference in lock step (Sec. 3.4); the service is the TPU analogue:

* **Queue + tick.**  ``submit`` enqueues a request and returns a
  ``MatchTicket``; ``tick`` drains the queue once.  The service is
  cooperative (no threads): callers drive it via ``tick`` / ``flush`` /
  ``MatchTicket.wait``.
* **Declarative requests.**  Every submission is normalized to a frozen
  ``MatchQuery`` at the door (legacy kwargs ride the ``as_query`` shim),
  so validation happens at submit time and both the result cache and the
  coalescing groups key off the query IR itself (content equality;
  ``MatchQuery.digest`` is the external spelling), not an ad-hoc kwarg
  tuple.
* **Coalescing.**  Pending shared-mode queries that are compatible -- same
  corpus generation (always true within one tick), same pattern length,
  same predicate kind, same reduction, same row subset (by content), same
  backend override -- are grouped, priced by ``Planner.plan_batch`` (one
  fused ``mode="batched"`` launch vs. Q sequential launches), and executed
  the cheaper way.  Per-request results are scattered back from the
  batched tensors, bit-identical to what Q separate ``MatchEngine.match``
  calls would return.
* **Result cache.**  An LRU keyed by the query.  The cache is dropped
  whenever ``PackedCorpus.generation`` changes (``append_rows`` /
  ``set_rows`` / ``invalidate``), so a row write or an ingested document
  never serves stale scores.
* **Online ingestion.**  ``ingest`` enqueues new corpus rows next to the
  query queue; each tick applies all pending ingests as **one** batched
  in-place ``append_rows`` (amortizing the device splice), then serves
  the tick's queries against the grown corpus.  The corpus never repacks
  its resident rows and the engine (with its compile cache) survives
  growth -- the store ingests while serving, the regime the paper's
  resident-reference design exists for (DESIGN.md Sec. 3f).
* **Standing queries** (DESIGN.md Sec. 3j).  With a ``PatternBank``
  attached, every tick's fused ingest batch is scanned against the whole
  bank in **one** roles-swapped batched launch *before* it splices into
  the corpus (TTL-expired patterns are retired first); hits ride the
  ``IngestTicket`` and the bank's per-pattern callbacks.  ``window_rows``
  turns the corpus into a sliding window: after each append the oldest
  live rows beyond the window are tombstoned (reductions mask them; the
  standing scan already fired for them at ingest) and the corpus
  compacts once the dead fraction crosses ``compact_dead_frac``.
* **Stats.**  Per-request latency (a log-bucketed histogram: exact
  bucket counts over the whole run, so the snapshot reports p50/p95/p99,
  not just a mean) plus launch/coalescing/cache/ingest
  counters, per-tick launch counts, cache hit-rate, and q-gram filter
  routing (filtered-launch count, hit-rate, measured survivor fraction --
  the engine routes eligible threshold queries through the
  ``CorpusIndex`` transparently, DESIGN.md Sec. 3g);
  ``ServiceStats.snapshot()`` is what the service benchmark and the
  launcher report.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import LogHistogram

from .engine import MatchEngine, MatchResult
from .planner import BatchPlan
from .query import _UNSET, MatchQuery, as_query


@dataclasses.dataclass
class ServiceStats:
    """Counters + latency record for one service instance."""

    n_submitted: int = 0
    n_completed: int = 0
    n_cache_hits: int = 0
    n_launches: int = 0               # engine.match calls issued
    n_coalesced_launches: int = 0     # launches that fused >= 2 queries
    n_coalesced_queries: int = 0      # queries served by fused launches
    n_sequential_fallback: int = 0    # grouped queries the pricing split up
    n_failed: int = 0                 # requests completed with an error
    n_ingested_rows: int = 0          # corpus rows appended via ingest
    n_ingest_batches: int = 0         # append_rows calls (one per tick max)
    n_ticks: int = 0                  # tick() calls
    launches_last_tick: int = 0       # engine launches in the latest tick
    n_filtered_launches: int = 0      # launches that ran filter-then-verify
    sum_survivor_frac: float = 0.0    # running sum over filtered launches
    # Per-request latency distribution: a log-bucketed histogram (exact
    # bucket counts over the whole run, O(#occupied buckets) state)
    # replaces the old running-sum-only accounting, so the snapshot can
    # report p50/p95/p99 -- which a long-tail launch distribution needs;
    # the mean alone buried the tail.  ``total_latency_s`` and
    # ``avg_latency_s`` remain below as thin views over it.
    latency_hist: LogHistogram = dataclasses.field(
        default_factory=LogHistogram, repr=False)
    n_shards: int = 1                 # engine row shards (mesh-resident)
    shard_rows: Optional[List[int]] = None   # live rows per shard
    # Cross-shard merge accounting (DESIGN.md Sec. 3k): which path the
    # engine's reductions combine on ("device" = collectives under
    # shard_map, "host" = single-shard pulls) and the cumulative
    # estimated collective bytes those merges moved -- the measured
    # counterpart of Plan.est_collective_bytes, so mispriced merges are
    # visible in the same snapshot the feedback loop reads.
    merge_path: str = "host"
    collective_bytes: int = 0
    # Cost-model provenance (DESIGN.md Sec. 3i): which source prices the
    # planner's decisions ("static" | "calibrated:<digest8>") and the
    # runtime-feedback state (observation/misprediction counters, number
    # of re-priced shape buckets) -- refreshed per tick from the planner.
    cost_source: str = "static"
    feedback: Optional[Dict] = None
    # Standing-query / windowed-corpus counters (DESIGN.md Sec. 3j):
    # bank launch counts mirror the attached PatternBank per tick, so
    # "one ingest batch = one fused bank launch" is auditable here.
    n_bank_launches: int = 0          # fused bank verify dispatches
    n_bank_prefilter_launches: int = 0
    n_bank_hits: int = 0              # standing hits delivered via ingest
    n_evicted_rows: int = 0           # rows tombstoned by the window
    n_compactions: int = 0            # corpus compactions triggered
    bank: Optional[Dict] = None       # PatternBank.stats() snapshot
    # Obs-layer views (DESIGN.md Sec. 3l), refreshed per tick: per-stage
    # wall seconds summed over the latest tick's launches (from the
    # ``MatchResult.timings`` span breakdowns) and the registry's
    # plan-vs-actual accounting, so "where did the tick go" and "how
    # wrong were the estimates" read out of the same snapshot the
    # benchmarks and the launcher already grep.
    timings_last_tick: Optional[Dict] = None
    plan_actual: Optional[Dict] = None
    plan_mispredict_rate: float = 0.0
    _t_first_submit: Optional[float] = None
    _t_last_complete: Optional[float] = None

    @property
    def total_latency_s(self) -> float:
        """Deprecated running-sum view; kept for callers of the old
        field.  The histogram is the source of truth now -- prefer
        ``latency_hist`` / the snapshot percentiles."""
        return self.latency_hist.sum

    @property
    def avg_latency_s(self) -> float:
        return (self.total_latency_s / self.n_completed
                if self.n_completed else 0.0)

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of completed requests served from the result cache."""
        return (self.n_cache_hits / self.n_completed
                if self.n_completed else 0.0)

    @property
    def avg_launches_per_tick(self) -> float:
        return self.n_launches / self.n_ticks if self.n_ticks else 0.0

    @property
    def filter_hit_rate(self) -> float:
        """Fraction of engine launches routed through the q-gram filter."""
        return (self.n_filtered_launches / self.n_launches
                if self.n_launches else 0.0)

    @property
    def avg_survivor_frac(self) -> float:
        """Mean measured post-filter row fraction over filtered launches."""
        return (self.sum_survivor_frac / self.n_filtered_launches
                if self.n_filtered_launches else 0.0)

    @property
    def shard_balance(self) -> float:
        """Max/min live-row ratio across shards (1.0 = perfectly even).

        Cyclic row placement keeps this <= (j+1)/j for per-shard count j,
        so it converges to 1.0 as the corpus grows; the shard benchmark
        asserts <= 1.1 after ingest.
        """
        if not self.shard_rows or len(self.shard_rows) < 2:
            return 1.0
        lo = min(self.shard_rows)
        return float(max(self.shard_rows)) / lo if lo else float("inf")

    @property
    def qps(self) -> float:
        """Completed queries per second of wall time, submit to done."""
        if (self._t_first_submit is None or self._t_last_complete is None
                or self._t_last_complete <= self._t_first_submit):
            return 0.0
        return self.n_completed / (self._t_last_complete
                                   - self._t_first_submit)

    def snapshot(self) -> Dict[str, float]:
        return {
            "n_submitted": self.n_submitted,
            "n_completed": self.n_completed,
            "n_cache_hits": self.n_cache_hits,
            "n_launches": self.n_launches,
            "n_coalesced_launches": self.n_coalesced_launches,
            "n_coalesced_queries": self.n_coalesced_queries,
            "n_sequential_fallback": self.n_sequential_fallback,
            "n_failed": self.n_failed,
            "n_ingested_rows": self.n_ingested_rows,
            "n_ingest_batches": self.n_ingest_batches,
            "n_ticks": self.n_ticks,
            "launches_last_tick": self.launches_last_tick,
            "avg_launches_per_tick": round(self.avg_launches_per_tick, 2),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "n_filtered_launches": self.n_filtered_launches,
            "filter_hit_rate": round(self.filter_hit_rate, 4),
            "avg_survivor_frac": round(self.avg_survivor_frac, 4),
            "avg_latency_s": round(self.avg_latency_s, 6),
            "latency_p50_s": round(self.latency_hist.quantile(0.50), 6),
            "latency_p95_s": round(self.latency_hist.quantile(0.95), 6),
            "latency_p99_s": round(self.latency_hist.quantile(0.99), 6),
            "qps": round(self.qps, 1),
            "n_shards": self.n_shards,
            "shard_rows": list(self.shard_rows or []),
            "shard_balance": (round(self.shard_balance, 4)
                              if self.shard_rows else 1.0),
            "merge_path": self.merge_path,
            "collective_bytes": self.collective_bytes,
            "cost_source": self.cost_source,
            "misprediction_rate": (self.feedback or {}).get(
                "misprediction_rate", 0.0),
            "feedback": dict(self.feedback or {}),
            "n_bank_launches": self.n_bank_launches,
            "n_bank_prefilter_launches": self.n_bank_prefilter_launches,
            "n_bank_hits": self.n_bank_hits,
            "n_evicted_rows": self.n_evicted_rows,
            "n_compactions": self.n_compactions,
            "bank": dict(self.bank) if self.bank is not None else None,
            "timings": dict(self.timings_last_tick or {}),
            "plan_actual": dict(self.plan_actual or {}),
            "plan_mispredict_rate": round(self.plan_mispredict_rate, 4),
        }


def _drive_until_done(ticket, max_ticks: int, what: str) -> None:
    """Tick the ticket's service until it completes (shared wait loop)."""
    ticks = 0
    while not ticket.done:
        if ticks >= max_ticks:
            raise RuntimeError(f"{what} did not complete "
                               f"within {max_ticks} ticks")
        ticket._service.tick()
        ticks += 1


class MatchTicket:
    """Handle for one submitted query; fill by driving ``service.tick``.

    A request that fails at execution time (e.g. a pattern longer than the
    fragment) completes with ``error`` set instead of poisoning the tick
    for unrelated tenants; ``wait`` re-raises it for this caller only.
    """

    __slots__ = ("_service", "done", "result", "cached", "latency_s",
                 "error")

    def __init__(self, service: "MatchService"):
        self._service = service
        self.done = False
        self.result: Optional[MatchResult] = None
        self.cached = False
        self.latency_s: Optional[float] = None
        self.error: Optional[Exception] = None

    def wait(self, max_ticks: int = 1024) -> MatchResult:
        """Drive the service until this ticket completes."""
        _drive_until_done(self, max_ticks, "ticket")
        if self.error is not None:
            raise self.error
        return self.result


class IngestTicket:
    """Handle for one ``ingest`` submission; fills on the next tick.

    ``start`` / ``n`` give the corpus row range the submission landed in
    once ``done``; rows from all same-tick submissions are appended in
    submission order by one batched ``append_rows``.  With a standing
    ``PatternBank`` attached, ``bank_ticket`` carries the tick's shared
    ``HitTicket`` (one fused scan covers every same-tick submission;
    filter its ``corpus_rows`` by ``[start, start + n)`` for this
    submission's hits).
    """

    __slots__ = ("_service", "done", "start", "n", "bank_ticket")

    def __init__(self, service: "MatchService", n: int):
        self._service = service
        self.done = False
        self.start: Optional[int] = None
        self.n = n
        self.bank_ticket = None

    def wait(self, max_ticks: int = 1024) -> int:
        """Drive the service until the rows are appended; returns start."""
        _drive_until_done(self, max_ticks, "ingest")
        return self.start


@dataclasses.dataclass
class _Pending:
    ticket: MatchTicket
    query: MatchQuery
    t_submit: float
    group_key: Optional[Tuple]         # None -> not coalescible


class MatchService:
    """Micro-batched multi-tenant front end over one shared ``MatchEngine``.

    Single-threaded by design: ``submit`` never blocks, ``tick`` does all
    the work.  Results handed out (and cached) are shared arrays -- treat
    them as read-only.
    """

    def __init__(self, engine: MatchEngine, *, cache_size: int = 256,
                 bank=None, window_rows: Optional[int] = None,
                 compact_dead_frac: float = 0.5):
        """``bank`` attaches a ``PatternBank`` scanned at every ingest;
        ``window_rows`` bounds the corpus to a sliding window (oldest live
        rows are tombstoned past it, and the corpus compacts once
        ``n_dead / n_rows`` reaches ``compact_dead_frac``)."""
        self.engine = engine
        # One observability surface per stack: the service records into
        # the engine's tracer/registry, never a second one.
        self.obs = engine.obs
        self.cache_size = int(cache_size)
        if bank is not None and (bank.fragment_chars
                                 != engine.corpus.fragment_chars):
            raise ValueError(
                f"bank fragment_chars={bank.fragment_chars} != corpus "
                f"fragment_chars={engine.corpus.fragment_chars}")
        self.bank = bank
        if bank is not None:
            # One transfer ledger per service: bank pulls count alongside
            # the engine's cross-shard merges (DESIGN.md Sec. 3k) -- and
            # one obs surface, so bank scan spans nest in the same trace.
            bank.merger = engine.merger
            bank.obs = engine.obs
        if window_rows is not None and int(window_rows) < 1:
            raise ValueError("window_rows must be >= 1")
        self.window_rows = None if window_rows is None else int(window_rows)
        if not (0.0 < float(compact_dead_frac) <= 1.0):
            raise ValueError("compact_dead_frac must be in (0, 1]")
        self.compact_dead_frac = float(compact_dead_frac)
        self.stats = ServiceStats()
        self._tick_timings: Dict[str, float] = {}
        self._queue: List[_Pending] = []
        self._ingest_queue: List[Tuple[IngestTicket, np.ndarray]] = []
        self._cache: "OrderedDict[MatchQuery, MatchResult]" = OrderedDict()
        self._cache_generation = engine.corpus.generation
        self._note_shards()
        self._note_calibration()

    # -- submission -----------------------------------------------------------
    def submit(self, patterns, *, reduction=_UNSET, k=_UNSET,
               threshold=_UNSET, rows=_UNSET, backend=_UNSET,
               mode=_UNSET, filter=_UNSET) -> MatchTicket:
        """Enqueue one query; returns a ticket (drive ``tick`` to fill it).

        ``patterns`` is a ``MatchQuery`` (any explicit kwarg alongside it
        is rejected) or a uint8 code array with the legacy kwargs
        (defaults: reduction="best", k=10; normalized through
        ``as_query``, so malformed queries -- unknown reduction,
        out-of-range codes -- fail *here*, at submit).  Only shared-mode
        (1-D pattern) queries coalesce; 2-D (per-row / batched) queries
        pass through as singleton launches.
        """
        tr = self.obs.tracer
        with tr.span("service.enqueue"):
            query = as_query(patterns, reduction=reduction, k=k,
                             threshold=threshold, rows=rows,
                             backend=backend, mode=mode, filter=filter)
            # Coalescing key straight off the IR: 1-D queries whose fused
            # batched execution is well-defined group by everything that
            # must agree for one launch to serve them all.  Predicate kind
            # is part of the key so exact groups keep riding the exact
            # kernels; the filter hint is part of it so the fused query
            # inherits one unambiguous routing decision (the engine
            # filters fused batched threshold queries with a survivor
            # union, so coalesced groups still ride the index
            # transparently).
            coalescible = len(query.shape) == 1
            group_key = ((query.pattern_chars, query.reduction,
                          query.rows_b, query.backend, query.chunk_rows,
                          query.is_exact, query.filter)
                         if coalescible else None)
            ticket = MatchTicket(self)
            now = time.perf_counter()
            self._queue.append(_Pending(ticket=ticket, query=query,
                                        t_submit=now, group_key=group_key))
            self.stats.n_submitted += 1
            if self.stats._t_first_submit is None:
                self.stats._t_first_submit = now
        return ticket

    def ingest(self, rows) -> IngestTicket:
        """Enqueue corpus rows for online, in-place appending.

        ``rows`` is a (n, F) or (F,) uint8 code array.  Appends are
        batched per tick: ``tick`` concatenates every pending submission
        and applies them with **one** ``PackedCorpus.append_rows`` call
        before running that tick's queries, so queries submitted in the
        same tick see the grown corpus and the result cache invalidates
        exactly once (generation-keyed).  Width is validated here, at the
        door, like query validation in ``submit``.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        F = self.engine.corpus.fragment_chars
        if rows.ndim != 2 or rows.shape[1] != F:
            raise ValueError(f"ingested rows must be (n, {F}); got shape "
                             f"{rows.shape}")
        ticket = IngestTicket(self, rows.shape[0])
        if rows.shape[0] == 0:
            # Empty batch: a complete no-op.  Queueing it would charge an
            # ingest batch, a zero-row append launch, a generation bump
            # and therefore a spurious result-cache drop at the next tick.
            ticket.start = self.engine.corpus.n_rows
            ticket.done = True
            return ticket
        # Copy: the append happens at tick time and the caller's buffer
        # must not mutate underneath the queue.
        self._ingest_queue.append((ticket, np.array(rows)))
        return ticket

    def match(self, patterns, **kw) -> MatchResult:
        """Blocking convenience: submit + tick until done."""
        return self.submit(patterns, **kw).wait()

    def flush(self, max_ticks: int = 1024) -> None:
        """Tick until the query and ingest queues drain."""
        ticks = 0
        while self._queue or self._ingest_queue:
            if ticks >= max_ticks:
                raise RuntimeError("queue did not drain")
            self.tick()
            ticks += 1

    # -- cache ----------------------------------------------------------------
    def _cache_get(self, key: MatchQuery) -> Optional[MatchResult]:
        res = self._cache.get(key)
        if res is not None:
            self._cache.move_to_end(key)
        return res

    def _cache_put(self, key: MatchQuery, res: MatchResult) -> None:
        self._cache[key] = res
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    # -- completion -----------------------------------------------------------
    def _complete(self, pend: _Pending, res: Optional[MatchResult],
                  cached: bool, error: Optional[Exception] = None) -> None:
        t = pend.ticket
        t.result = res
        t.cached = cached
        t.error = error
        t.done = True
        now = time.perf_counter()
        t.latency_s = now - pend.t_submit
        self.stats.latency_hist.record(t.latency_s)
        self.stats.n_completed += 1
        self.stats.n_cache_hits += int(cached)
        self.stats.n_failed += int(error is not None)
        self.stats._t_last_complete = now

    # -- execution ------------------------------------------------------------
    def _note_filter(self, res: MatchResult) -> None:
        """Fold one completed launch's routing into the filter counters.

        ``n_launches`` itself counts *attempted* launches and increments
        before the engine call (a failing tenant still paid a launch);
        only the filter-routing counters need the result.
        """
        if res.survivor_frac is not None:
            self.stats.n_filtered_launches += 1
            self.stats.sum_survivor_frac += res.survivor_frac

    def _note_merge(self, res: MatchResult) -> None:
        """Fold one launch's cross-shard merge accounting into the stats."""
        self.stats.merge_path = res.merge_path
        self.stats.collective_bytes += int(res.collective_bytes)

    def _note_timings(self, res: MatchResult) -> None:
        """Fold one launch's per-stage span breakdown into the tick's.

        Only present when the tracer is enabled (``MatchResult.timings``
        is ``None`` otherwise); accumulated once per *launch*, so a
        coalesced group charges its stages once, not per scattered view.
        """
        if res.timings is None:
            return
        acc = self._tick_timings
        for stage, secs in res.timings.items():
            acc[stage] = acc.get(stage, 0.0) + secs

    def _run_single(self, pend: _Pending) -> MatchResult:
        self.stats.n_launches += 1
        res = self.engine.match(pend.query)
        self._note_filter(res)
        self._note_merge(res)
        self._note_timings(res)
        return res

    def _scatter(self, res: MatchResult, q: int, n_q: int,
                 k_q: int) -> MatchResult:
        """Per-query view of one fused batched result (column ``q``).

        Bit-identical to the single shared-mode query: the batched kernels
        score each pattern column independently, so slicing column ``q``
        out of the (R, ..., Q) tensors reproduces the solo run exactly.
        """
        out = MatchResult(plan=res.plan,
                          best_locs=np.ascontiguousarray(
                              res.best_locs[:, q]),
                          best_scores=np.ascontiguousarray(
                              res.best_scores[:, q]),
                          n_chunks=res.n_chunks,
                          survivor_rows=res.survivor_rows,
                          survivor_frac=res.survivor_frac,
                          n_shards=res.n_shards,
                          merge_path=res.merge_path,
                          collective_bytes=res.collective_bytes)
        # Scatter views share the fused launch's stage breakdown: the
        # stages ran once for the whole group.
        out.timings = res.timings
        if res.scores is not None:
            out.scores = np.ascontiguousarray(res.scores[:, :, q])
        if res.topk_rows is not None:
            kk = min(k_q, res.topk_rows.shape[0])
            out.topk_rows = np.ascontiguousarray(res.topk_rows[:kk, q])
            out.topk_scores = np.ascontiguousarray(res.topk_scores[:kk, q])
        if res.hits is not None:
            mine = res.hits[res.hits[:, 2] == q]
            out.hits = np.ascontiguousarray(mine[:, [0, 1, 3]])
        return out

    def _fuse_queries(self, members: List[List[_Pending]]) -> MatchQuery:
        """Stack one group's shared-mode queries into one batched query.

        Pure IR-to-IR lowering: stacked accept masks + per-query k /
        threshold vectors; everything else (rows, backend, chunking) is
        identical across the group by construction of the group key.
        """
        first = members[0][0].query
        stacked = np.stack([m[0].query.masks for m in members])
        kw = dict(mode="batched", reduction=first.reduction,
                  rows=first.rows, backend=first.backend,
                  chunk_rows=first.chunk_rows, filter=first.filter)
        if first.reduction == "topk":
            kw["k"] = [m[0].query.k[0] for m in members]
        if first.reduction == "threshold":
            kw["threshold"] = [m[0].query.threshold[0] for m in members]
        return MatchQuery.from_masks(stacked, **kw)

    def _run_group(self, grp: List[_Pending]) -> None:
        """Execute one compatible group: coalesced or sequential.

        Within the group, requests with identical queries share one
        executed column (same-tick dedup).
        """
        uniq: "OrderedDict[MatchQuery, List[_Pending]]" = OrderedDict()
        for p in grp:
            uniq.setdefault(p.query, []).append(p)
        members = list(uniq.values())
        n_q = len(members)
        first = members[0][0].query
        n_rows = (len(first.rows) if first.rows is not None
                  else self.engine.corpus.n_rows)
        bp: Optional[BatchPlan] = None
        if n_q > 1 and n_rows > 0:
            # Empty subsets skip pricing: the engine answers them without
            # a launch, and the planner (rightly) rejects 0-row workloads.
            bp = self.engine.planner.plan_batch(
                n_rows=n_rows,
                fragment_chars=self.engine.corpus.fragment_chars,
                pattern_chars=first.pattern_chars, n_queries=n_q,
                backend=first.backend, chunk_rows=first.chunk_rows,
                predicate=first.predicate,
                n_shards=self.engine.n_shards)
        if bp is not None and bp.coalesced:
            tr = self.obs.tracer
            with tr.span("service.coalesce",
                         {"n_queries": len(grp), "n_uniq": n_q}
                         if tr.enabled else None):
                fused = self._fuse_queries(members)
                self.stats.n_launches += 1
                self.stats.n_coalesced_launches += 1
                self.stats.n_coalesced_queries += len(grp)
                batched = self.engine.match(fused)
                self._note_filter(batched)
                self._note_merge(batched)
                self._note_timings(batched)
                for q, mem in enumerate(members):
                    k_q = mem[0].query.k[0] if mem[0].query.k else 0
                    res = self._scatter(batched, q, n_q, k_q)
                    self._cache_put(mem[0].query, res)
                    for p in mem:
                        self._complete(p, res, cached=False)
        else:
            if n_q > 1:
                self.stats.n_sequential_fallback += len(grp)
            for mem in members:
                res = self._run_single(mem[0])
                self._cache_put(mem[0].query, res)
                for p in mem:
                    self._complete(p, res, cached=False)

    def _note_shards(self) -> None:
        """Refresh per-shard placement stats from the engine.

        Cyclic placement (DESIGN.md Sec. 3h) appends row n to shard
        n % S -- always the shard with the fewest live rows -- so ingest
        is balanced by construction; the snapshot makes that auditable.
        """
        self.stats.n_shards = self.engine.n_shards
        self.stats.shard_rows = [
            int(x) for x in self.engine.shard_live_rows()]

    def _note_calibration(self) -> None:
        """Refresh cost-model provenance + feedback state from the planner.

        Taken per tick (like the shard stats) so a feedback re-pricing
        that lands mid-session shows up in the next snapshot, not only at
        construction time.
        """
        planner = self.engine.planner
        self.stats.cost_source = planner.cost_source.tag
        self.stats.feedback = planner.feedback.snapshot()

    def _apply_ingests(self) -> None:
        """Append all pending ingest rows as one batched in-place write.

        With a bank attached, the fused batch is scanned against every
        live standing pattern first -- one roles-swapped launch covering
        all same-tick submissions -- so alerts fire before the rows even
        splice in (and regardless of any later window eviction).
        """
        batch, self._ingest_queue = self._ingest_queue, []
        if not batch:
            return
        rows = (batch[0][1] if len(batch) == 1
                else np.concatenate([r for _, r in batch], 0))
        scan = None
        if self.bank is not None:
            scan = self.bank.scan(rows, base_row=self.engine.corpus.n_rows)
            self.stats.n_bank_hits += scan.hits.shape[0]
        start = self.engine.corpus.append_rows(rows)
        self.stats.n_ingest_batches += 1
        self.stats.n_ingested_rows += rows.shape[0]
        for ticket, r in batch:
            ticket.start = start
            ticket.done = True
            ticket.bank_ticket = scan
            start += r.shape[0]
        self._evict()

    def _evict(self) -> None:
        """Enforce the sliding window: tombstone past it, compact lazily.

        Tombstoned rows stay physically resident (reductions mask them;
        no repack, no splice); compaction -- which does pay one
        touched-rows splice -- runs only when the dead fraction crosses
        the configured threshold, amortizing it over many evictions.
        """
        if self.window_rows is None:
            return
        corpus = self.engine.corpus
        excess = corpus.n_live - self.window_rows
        if excess > 0:
            corpus.tombstone(corpus.live_row_ids()[:excess])
            self.stats.n_evicted_rows += excess
        if (corpus.n_dead
                and corpus.n_dead / corpus.n_rows >= self.compact_dead_frac):
            corpus.compact()

    def _note_bank(self) -> None:
        """Mirror bank + window counters into the stats snapshot."""
        self.stats.n_compactions = self.engine.corpus.n_compactions
        if self.bank is not None:
            self.stats.n_bank_launches = self.bank.n_bank_launches
            self.stats.n_bank_prefilter_launches = \
                self.bank.n_prefilter_launches
            self.stats.bank = self.bank.stats()

    def _note_obs(self) -> None:
        """Mirror per-tick service health into the metrics registry.

        Gauges carry the service-level facts no single span shows (queue
        depth, hit rates, shard balance); the stats snapshot pulls the
        registry's plan-vs-actual accounting back so estimate drift per
        (kernel, shape-bucket) reads out of ``ServiceStats.snapshot()``.
        """
        m = self.obs.metrics
        s = self.stats
        m.gauge("service.queue_depth").set(len(self._queue))
        m.gauge("service.cache_hit_rate").set(s.cache_hit_rate)
        m.gauge("service.launches_last_tick").set(s.launches_last_tick)
        m.gauge("service.avg_survivor_frac").set(s.avg_survivor_frac)
        m.gauge("service.shard_balance").set(s.shard_balance)
        m.gauge("service.collective_bytes").set(s.collective_bytes)
        m.gauge("service.n_evicted_rows").set(s.n_evicted_rows)
        m.gauge("service.n_compactions").set(s.n_compactions)
        s.timings_last_tick = (dict(self._tick_timings)
                               if self._tick_timings else None)
        s.plan_actual = m.plan_actual_summary() or None
        s.plan_mispredict_rate = m.mispredict_rate()

    def tick(self) -> int:
        """Drain the queues once: ingests, cache hits, grouped launches.

        Ingests apply first (one batched append), so this tick's queries
        run against the grown corpus and the generation-keyed cache drop
        below covers the append.  Returns the number of requests completed
        this tick.
        """
        tr = self.obs.tracer
        if not tr.enabled:
            return self._tick()
        with tr.span("service.tick", {"tick": self.stats.n_ticks}) as sp:
            n = self._tick()
            sp.set("n_completed", n)
            return n

    def _tick(self) -> int:
        """The tick body behind ``tick()`` (span-instrumented)."""
        if self.bank is not None:
            # Retire TTL-expired standing patterns before this tick's
            # ingest scan: a pattern past its deadline must not fire.
            self.bank.expire()
        self._apply_ingests()
        self._note_shards()
        self._note_calibration()
        self._note_bank()
        gen = self.engine.corpus.generation
        if gen != self._cache_generation:
            self._cache.clear()
            self._cache_generation = gen
        self.stats.n_ticks += 1
        launches_before = self.stats.n_launches
        self._tick_timings = {}
        pending, self._queue = self._queue, []
        if not pending:
            self.stats.launches_last_tick = 0
            self._note_obs()
            return 0
        before = self.stats.n_completed
        groups: "OrderedDict[Tuple, List[_Pending]]" = OrderedDict()
        for p in pending:
            hit = self._cache_get(p.query)
            if hit is not None:
                self._complete(p, hit, cached=True)
                continue
            # Non-coalescible (2-D / batched) queries group by query
            # content, not ticket identity: same-tick duplicates share one
            # launch (the `uniq` dedup in _run_group) instead of paying a
            # full launch each.
            key = p.group_key if p.group_key is not None else (
                "solo", p.query)
            groups.setdefault(key, []).append(p)
        for grp in groups.values():
            try:
                self._run_group(grp)
            except Exception as e:      # noqa: BLE001 -- tenant isolation
                # One tenant's bad query (pattern longer than the
                # fragment, rows out of range, ...) must not poison the
                # tick for everyone else: fail this group's tickets,
                # keep serving the rest.
                for p in grp:
                    if not p.ticket.done:
                        self._complete(p, None, cached=False, error=e)
        self.stats.launches_last_tick = (self.stats.n_launches
                                         - launches_before)
        self._note_obs()
        return self.stats.n_completed - before
