"""Match service throughput bench: sequential loop vs. coalesced service.

The multi-tenant regime from DESIGN.md Sec. 3d: Q independent small
shared-mode queries against one resident corpus.  The sequential baseline
is what callers did before the service existed -- Q separate
``MatchEngine.match`` calls, each paying planning, pattern packing, kernel
dispatch and result assembly.  The coalesced path submits all Q to a
``MatchService``, which fuses them into one ``mode="batched"`` launch and
scatters per-request results back.

Both paths run the SWAR kernel (``backend="swar"``): on this CPU container
the Pallas kernels execute via the interpreter, where MXU bf16 matmuls are
emulated and their timings are meaningless (see ``kernel_bench``); holding
the kernel fixed makes the comparison measure exactly the service layer.
Results are asserted bit-identical to the per-query oracles before any
timing is reported.

Emits ``BENCH_match_service.json`` at the repo root and exits nonzero if
the record is malformed.  CI runs ``--smoke`` as a schema guard: same
pipeline and validation on a reduced shape, without overwriting the
committed full-run artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_service.json"

FULL = dict(R=48, F=256, P=32, q_levels=(1, 8, 64, 256), repeats=5)
SMOKE = dict(R=48, F=128, P=16, q_levels=(1, 8, 16), repeats=1)
BACKEND = "swar"

REQUIRED_KEYS = ("shape", "kernel_backend", "device_kind", "backend",
                 "calibration", "n_processes", "n_hosts", "interpret",
                 "smoke", "q_levels", "results")
REQUIRED_RESULT_KEYS = ("Q", "seq_s", "svc_s", "seq_qps", "svc_qps",
                        "speedup", "identical", "coalesced_launches")


def bench_level(eng, Q: int, P: int, rng, repeats: int) -> dict:
    from repro.match import MatchService

    pats = rng.integers(0, 4, (Q, P), np.uint8)
    warm = rng.integers(0, 4, (Q, P), np.uint8)
    # Warm both paths at the exact shapes to be timed (jit compile cache).
    for p in warm[: min(2, Q)]:
        eng.match(p, backend=BACKEND)
    if Q > 1:
        eng.match(warm, mode="batched", backend=BACKEND)

    t_seq = t_svc = float("inf")
    oracle = tickets = svc = None
    # Best-of-N per path: this container's CPU timings are noisy; the
    # minimum is the least-contended observation of the same work.
    for _ in range(repeats):
        t0 = time.perf_counter()
        oracle = [eng.match(p, backend=BACKEND) for p in pats]
        t_seq = min(t_seq, time.perf_counter() - t0)

        svc = MatchService(eng)      # fresh: no result-cache crossover
        t0 = time.perf_counter()
        tickets = [svc.submit(p, backend=BACKEND) for p in pats]
        svc.flush()
        t_svc = min(t_svc, time.perf_counter() - t0)

    identical = all(
        np.array_equal(t.result.best_scores, o.best_scores)
        and np.array_equal(t.result.best_locs, o.best_locs)
        for t, o in zip(tickets, oracle))
    return {
        "Q": Q,
        "seq_s": round(t_seq, 4),
        "svc_s": round(t_svc, 4),
        "seq_qps": round(Q / t_seq, 1),
        "svc_qps": round(Q / t_svc, 1),
        "speedup": round(t_seq / t_svc, 2),
        "identical": bool(identical),
        "coalesced_launches": svc.stats.n_coalesced_launches,
        "service_stats": svc.stats.snapshot(),
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if not record["results"]:
        raise ValueError("BENCH record has no results")
    for row in record["results"]:
        for key in REQUIRED_RESULT_KEYS:
            if key not in row:
                raise ValueError(f"result row missing key {key!r}: {row}")
        if not row["identical"]:
            raise ValueError(f"Q={row['Q']}: service results diverged from "
                             "per-query oracles")
        if row["seq_qps"] <= 0 or row["svc_qps"] <= 0:
            raise ValueError(f"Q={row['Q']}: non-positive throughput")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.match import MatchEngine

    cfg = SMOKE if smoke else FULL
    R, F, P = cfg["R"], cfg["F"], cfg["P"]
    rng = np.random.default_rng(7)
    eng = MatchEngine(rng.integers(0, 4, (R, F), np.uint8))
    results = [bench_level(eng, Q, P, rng, cfg["repeats"])
               for Q in cfg["q_levels"]]
    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {"R": R, "F": F, "P": P},
        "kernel_backend": BACKEND,
        **bench_provenance(eng.planner.cost_source),
        "interpret": eng.interpret,
        "smoke": smoke,
        "q_levels": list(cfg["q_levels"]),
        "results": results,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with reduced Q levels.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    return [
        (f"service/coalesced_Q{row['Q']}",
         round(row["svc_s"] / row["Q"] * 1e6, 1),
         f"svc_qps={row['svc_qps']} seq_qps={row['seq_qps']} "
         f"speedup={row['speedup']}x identical={row['identical']}")
        for row in record["results"]
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cases = " ".join(f"Q{r['Q']}:svc_qps={r['svc_qps']}:"
                     f"speedup={r['speedup']}x" for r in rec["results"])
    return f"{BENCH_JSON.name} {cases}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + reduced Q levels (CI schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for row in record["results"]:
        print(f"Q={row['Q']:>4}  seq={row['seq_qps']:>8.1f} qps  "
              f"svc={row['svc_qps']:>8.1f} qps  "
              f"speedup={row['speedup']:.2f}x  identical={row['identical']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
