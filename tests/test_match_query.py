"""Query-IR tests: MatchQuery construction/canonicalization/digests,
wildcard + IUPAC predicate oracle equivalence on every backend, compiled
query reuse across corpus generations, early code validation, and the
legacy kwarg deprecation shims.

The load-bearing property: an accept-mask query must be bit-identical to
the NumPy accept-mask oracle (``matcher.sliding_scores_masks``) on every
backend, and a one-hot accept mask must be indistinguishable from the
exact query it encodes -- same scores, same digest.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import encoding
from repro.core.matcher import sliding_scores, sliding_scores_masks
from repro.match import (CompiledMatch, MatchEngine, MatchQuery,
                         MatchService, Planner, as_query)


def mask_case(r, f, p, *, q=None, per_row=False, n_wild=3, seed=0):
    """Random fragments + exact-derived masks with some wildcard positions."""
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (r, f), np.uint8)
    if q is not None:
        codes = rng.integers(0, 4, (q, p), np.uint8)
    elif per_row:
        codes = rng.integers(0, 4, (r, p), np.uint8)
    else:
        codes = rng.integers(0, 4, p, np.uint8)
    masks = (np.uint8(1) << codes).astype(np.uint8)
    flat = masks.reshape(-1)
    idx = rng.integers(0, flat.size, min(n_wild, flat.size))
    flat[idx] = rng.integers(1, 16, len(idx), np.uint8)
    return frags, masks


class TestMatchQueryIR:
    def test_frozen_hashable_and_digest_stable(self):
        pat = np.array([0, 1, 2, 3], np.uint8)
        a = MatchQuery.exact(pat, reduction="topk", k=3)
        b = MatchQuery.exact(pat, reduction="topk", k=3)
        assert a == b and hash(a) == hash(b) and a.digest == b.digest
        assert {a: 1}[b] == 1
        c = MatchQuery.exact(pat, reduction="topk", k=4)
        assert c != a and c.digest != a.digest

    def test_exact_and_onehot_masks_canonicalize_identically(self):
        """Two spellings of the same query -> same IR, same digest."""
        pat = np.array([2, 0, 3, 1], np.uint8)
        via_codes = MatchQuery.exact(pat)
        via_masks = MatchQuery.from_masks(
            (np.uint8(1) << pat).astype(np.uint8))
        assert via_codes == via_masks
        assert via_codes.digest == via_masks.digest
        assert via_masks.is_exact and via_masks.predicate == "exact"
        np.testing.assert_array_equal(via_masks.codes, pat)

    def test_wildcard_query_is_accept_predicate(self):
        masks = encoding.encode_iupac("ACNGT")
        q = MatchQuery.from_masks(masks)
        assert not q.is_exact and q.predicate == "accept"
        with pytest.raises(ValueError, match="only defined for exact"):
            q.codes

    def test_iupac_constructor_matches_encode_iupac(self):
        q = MatchQuery.iupac("ACGRN")
        np.testing.assert_array_equal(q.masks,
                                      encoding.encode_iupac("ACGRN"))
        qb = MatchQuery.iupac(["ACGR", "NNTT"], mode="batched")
        assert qb.shape == (2, 4) and qb.mode == "batched"

    def test_validation(self):
        pat = np.zeros(4, np.uint8)
        with pytest.raises(ValueError, match="unknown reduction"):
            MatchQuery.exact(pat, reduction="nope")
        with pytest.raises(ValueError, match="requires a threshold"):
            MatchQuery.exact(pat, reduction="threshold")
        with pytest.raises(ValueError, match="unknown backend"):
            MatchQuery.exact(pat, backend="gpu")
        with pytest.raises(ValueError, match="1-D patterns are 'shared'"):
            MatchQuery.exact(pat, mode="batched")
        with pytest.raises(ValueError, match="per-query k"):
            MatchQuery.exact(pat, reduction="topk", k=[1, 2])
        with pytest.raises(ValueError, match="accept masks"):
            MatchQuery.from_masks(np.zeros(4, np.uint8))   # 0 accepts nothing
        with pytest.raises(ValueError, match="at least one character"):
            MatchQuery.exact(np.zeros(0, np.uint8))

    def test_out_of_range_codes_rejected_at_construction(self):
        with pytest.raises(ValueError, match="pattern codes must be < 4"):
            MatchQuery.exact(np.array([0, 1, 7], np.uint8))
        with pytest.raises(ValueError, match="pattern codes must be < 4"):
            MatchQuery.exact(np.array([[0, 1], [4, 2]], np.uint8))

    def test_shared_mode_canonicalized(self):
        pat = np.zeros(4, np.uint8)
        assert MatchQuery.exact(pat, mode="shared") == MatchQuery.exact(pat)
        # Only 1-D patterns are shared: 2-D + mode='shared' stays a hard
        # error (silently inferring per_row/batched would be
        # shape-dependent semantics).
        with pytest.raises(ValueError, match="per_row"):
            MatchQuery.exact(np.zeros((2, 4), np.uint8), mode="shared")

    def test_k_only_kept_for_topk(self):
        pat = np.zeros(4, np.uint8)
        assert MatchQuery.exact(pat, k=7) == MatchQuery.exact(pat, k=99)
        assert MatchQuery.exact(pat, reduction="topk", k=7) != \
            MatchQuery.exact(pat, reduction="topk", k=99)

    def test_rows_in_digest(self):
        pat = np.zeros(4, np.uint8)
        a = MatchQuery.exact(pat, rows=[1, 2])
        b = MatchQuery.exact(pat, rows=[2, 1])
        assert a != b and a.digest != b.digest
        np.testing.assert_array_equal(a.rows, [1, 2])

    def test_as_query_rejects_query_plus_kwargs(self):
        q = MatchQuery.exact(np.zeros(4, np.uint8))
        assert as_query(q) is q
        with pytest.raises(ValueError, match="keyword overrides"):
            as_query(q, reduction="topk")
        # Explicitly passing a *default* value is still an override: the
        # shim must never silently drop a kwarg the caller spelled out.
        with pytest.raises(ValueError, match="keyword overrides"):
            as_query(q, reduction="best")
        rng = np.random.default_rng(0)
        eng = MatchEngine(rng.integers(0, 4, (8, 40), np.uint8))
        qq = MatchQuery.exact(np.zeros(4, np.uint8), reduction="topk", k=5)
        with pytest.raises(ValueError, match="keyword overrides"):
            eng.match(qq, reduction="best")


class TestEncodingSatellites:
    def test_encode_dna_raises_on_invalid(self):
        with pytest.raises(ValueError, match="invalid character"):
            encoding.encode_dna("ACGTN")
        with pytest.raises(ValueError, match="invalid character"):
            encoding.encode_dna("ACG-T")
        # Non-ASCII input must raise the documented ValueError, not
        # IndexError from byte-offset indexing into the str.
        with pytest.raises(ValueError, match="invalid character"):
            encoding.encode_dna("ACGTé")
        with pytest.raises(ValueError, match="invalid IUPAC"):
            encoding.encode_iupac("ACGN€")

    def test_encode_dna_roundtrip_still_works(self):
        s = "ACGTACGTTGCA"
        assert encoding.decode_dna(encoding.encode_dna(s)) == s
        np.testing.assert_array_equal(encoding.encode_dna("acgt"),
                                      [0, 1, 2, 3])

    def test_encode_iupac_table(self):
        np.testing.assert_array_equal(
            encoding.encode_iupac("ACGT"), [1, 2, 4, 8])
        assert encoding.encode_iupac("N")[0] == 0b1111
        assert encoding.encode_iupac("R")[0] == 0b0101   # A|G
        assert encoding.encode_iupac("Y")[0] == 0b1010   # C|T
        assert encoding.encode_iupac("U")[0] == 0b1000   # RNA T
        assert encoding.encode_iupac("n")[0] == 0b1111   # lowercase
        with pytest.raises(ValueError, match="invalid IUPAC"):
            encoding.encode_iupac("ACGX")

    def test_iupac_semantics_through_oracle(self):
        """R accepts A and G only; N accepts everything."""
        frags = np.array([[0, 1, 2, 3]], np.uint8)        # A C G T
        scores = sliding_scores_masks(frags, encoding.encode_iupac("RN"))
        # windows: AC, CG, GT -> R matches A/G, N matches all.
        np.testing.assert_array_equal(scores, [[2, 1, 2]])


class TestPredicateOracleEquivalence:
    """Wildcard/IUPAC queries bit-identical to the NumPy oracle."""

    @pytest.mark.parametrize("r,f,p", [
        (3, 33, 16), (13, 70, 20),               # R not multiple of 8
        (8, 64, 64),                             # P == F
        (5, 128, 1), (7, 257, 31),
    ])
    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref", None])
    def test_shared_wildcard(self, r, f, p, backend):
        frags, masks = mask_case(r, f, p, seed=r * f + p)
        q = MatchQuery.from_masks(masks, reduction="full", backend=backend)
        got = np.asarray(MatchEngine(frags).match(q).scores)
        np.testing.assert_array_equal(got, sliding_scores_masks(frags,
                                                                masks))

    @pytest.mark.parametrize("r,f,p,q", [(2, 40, 8, 3), (5, 300, 100, 4)])
    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_batched_wildcard(self, r, f, p, q, backend):
        frags, masks = mask_case(r, f, p, q=q, n_wild=6, seed=r + f + p)
        mq = MatchQuery.from_masks(masks, mode="batched", reduction="full",
                                   backend=backend)
        got = np.asarray(MatchEngine(frags).match(mq).scores)
        want = np.stack([sliding_scores_masks(frags, masks[i])
                         for i in range(q)], -1)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("backend", ["swar", "ref"])
    def test_per_row_wildcard(self, backend):
        frags, masks = mask_case(9, 120, 48, per_row=True, n_wild=12,
                                 seed=21)
        mq = MatchQuery.from_masks(masks, mode="per_row", reduction="full",
                                   backend=backend)
        got = np.asarray(MatchEngine(frags).match(mq).scores)
        np.testing.assert_array_equal(got, sliding_scores_masks(frags,
                                                                masks))

    def test_all_n_pattern_scores_full_everywhere(self):
        frags = np.random.default_rng(5).integers(0, 4, (4, 30), np.uint8)
        q = MatchQuery.iupac("N" * 6, reduction="full")
        got = np.asarray(MatchEngine(frags).match(q).scores)
        assert (got == 6).all()

    def test_onehot_masks_equal_exact_scores_all_backends(self):
        rng = np.random.default_rng(6)
        frags = rng.integers(0, 4, (6, 80), np.uint8)
        pat = rng.integers(0, 4, 24, np.uint8)
        for backend in ("swar", "mxu", "ref"):
            exact = np.asarray(MatchEngine(frags).scores(pat,
                                                         backend=backend))
            via_masks = np.asarray(MatchEngine(frags).match(
                MatchQuery.from_masks((np.uint8(1) << pat).astype(np.uint8),
                                      reduction="full",
                                      backend=backend)).scores)
            np.testing.assert_array_equal(exact, via_masks)
            np.testing.assert_array_equal(exact, sliding_scores(frags, pat))

    def test_wildcard_reductions_match_oracle(self):
        frags, masks = mask_case(14, 72, 18, seed=31)
        oracle = sliding_scores_masks(frags, masks)
        eng = MatchEngine(frags)
        res = eng.match(MatchQuery.from_masks(masks, reduction="best",
                                              backend="swar"))
        np.testing.assert_array_equal(res.best_scores, oracle.max(1))
        thr = int(oracle.max()) - 1
        res = eng.match(MatchQuery.from_masks(masks, reduction="threshold",
                                              threshold=thr,
                                              backend="swar"))
        want = np.argwhere(oracle >= thr)
        np.testing.assert_array_equal(res.hits[:, :2], want)
        res = eng.match(MatchQuery.from_masks(masks, reduction="topk", k=4,
                                              backend="swar"))
        np.testing.assert_array_equal(np.sort(res.topk_scores),
                                      np.sort(np.sort(oracle.max(1))[-4:]))

    def test_wildcard_rows_subset(self):
        frags, masks = mask_case(20, 80, 16, seed=32)
        sub = [17, 3, 11]
        q = MatchQuery.from_masks(masks, rows=sub, reduction="full",
                                  backend="swar")
        got = np.asarray(MatchEngine(frags).match(q).scores)
        np.testing.assert_array_equal(
            got, sliding_scores_masks(frags[sub], masks))


class TestCompiledReuse:
    def test_compile_cache_hit_same_object(self):
        rng = np.random.default_rng(40)
        eng = MatchEngine(rng.integers(0, 4, (10, 60), np.uint8))
        pat = rng.integers(0, 4, 12, np.uint8)
        q1 = MatchQuery.exact(pat, reduction="topk", k=2)
        q2 = MatchQuery.exact(pat.copy(), reduction="topk", k=2)
        cm = eng.compile(q1)
        assert isinstance(cm, CompiledMatch)
        assert eng.compile(q2) is cm               # content-keyed
        assert eng.compile(q1, cached=False) is not cm

    def test_compiled_reuse_across_generations(self):
        """One CompiledMatch serves every corpus generation: set_rows
        changes the answer, never the program, and never repacks."""
        rng = np.random.default_rng(41)
        frags = rng.integers(0, 4, (10, 60), np.uint8)
        eng = MatchEngine(frags)
        pat = rng.integers(0, 4, 12, np.uint8)
        cm = eng.compile(MatchQuery.exact(pat, backend="swar"))
        r1 = cm.run()
        np.testing.assert_array_equal(
            r1.best_scores, sliding_scores(frags, pat).max(1))
        gen = eng.corpus.generation
        new_row = rng.integers(0, 4, 60, np.uint8)
        new_row[7:19] = pat                        # plant an exact hit
        eng.corpus.set_rows(4, new_row)
        assert eng.corpus.generation > gen
        r2 = cm.run()
        assert r2.best_scores[4] == 12 and r2.best_locs[4] == 7
        np.testing.assert_array_equal(
            r2.best_scores,
            sliding_scores(eng.corpus.fragments, pat).max(1))
        assert eng.corpus.swar_pack_count == 1     # packed once, ever

    def test_compiled_wildcard_reuse_no_repack(self):
        rng = np.random.default_rng(42)
        frags, masks = mask_case(12, 64, 16, seed=42)
        eng = MatchEngine(frags)
        cm = eng.compile(MatchQuery.from_masks(masks, backend="swar"))
        for _ in range(3):
            res = cm()
        np.testing.assert_array_equal(
            res.best_scores, sliding_scores_masks(frags, masks).max(1))
        assert eng.corpus.swar_pack_count == 1
        assert res.plan.predicate == "accept"

    def test_compile_rejects_non_query(self):
        rng = np.random.default_rng(43)
        eng = MatchEngine(rng.integers(0, 4, (8, 40), np.uint8))
        with pytest.raises(TypeError, match="MatchQuery"):
            eng.compile(np.zeros(4, np.uint8))

    def test_compile_cache_bounded(self):
        rng = np.random.default_rng(44)
        eng = MatchEngine(rng.integers(0, 4, (8, 40), np.uint8),
                          compile_cache_size=2)
        for i in range(5):
            eng.compile(MatchQuery.exact(
                rng.integers(0, 4, 8, np.uint8)))
        assert len(eng._compiled) == 2


class TestPlannerPredicates:
    def test_accept_swar_priced_higher(self):
        pl = Planner()
        exact = pl.swar_seconds(512, 900, 100)
        accept = pl.swar_seconds(512, 900, 100, predicate="accept")
        assert accept > exact
        assert pl.mxu_seconds(512, 900, 100) == pl.mxu_seconds(512, 900,
                                                               100)

    def test_plan_carries_predicate(self):
        pl = Planner()
        p = pl.plan(n_rows=64, fragment_chars=256, pattern_chars=32,
                    predicate="accept")
        assert p.predicate == "accept"
        assert "accept" not in (pl.plan(
            n_rows=64, fragment_chars=256,
            pattern_chars=32).predicate)

    def test_unknown_predicate_rejected(self):
        with pytest.raises(ValueError, match="unknown predicate"):
            Planner().plan(n_rows=8, fragment_chars=64, pattern_chars=16,
                           predicate="fuzzy")

    def test_wildcards_tip_selection_toward_mxu(self):
        """At a Q where exact swar still wins, the accept-predicate cost
        premium must never flip the choice *away* from mxu."""
        pl = Planner()
        kw = dict(n_rows=256, fragment_chars=512, pattern_chars=64)
        for q in (1, 4, 16, 64):
            exact_backend = pl.plan(**kw, n_patterns=q).backend
            accept_backend = pl.plan(**kw, n_patterns=q,
                                     predicate="accept").backend
            if exact_backend == "mxu":
                assert accept_backend == "mxu"


class TestServicePredicates:
    def setup_method(self):
        rng = np.random.default_rng(50)
        self.rng = rng
        self.frags = rng.integers(0, 4, (24, 96), np.uint8)
        self.eng = MatchEngine(self.frags)
        self.svc = MatchService(self.eng)

    def test_wildcard_queries_coalesce_bit_identical(self):
        masks = []
        for s in range(6):
            m = mask_case(1, 1, 16, seed=s)[1]
            m[0] = 0b1111                  # guarantee non-exact: one group
            masks.append(m)
        tickets = [self.svc.submit(MatchQuery.from_masks(m))
                   for m in masks]
        self.svc.flush()
        assert self.svc.stats.n_coalesced_launches == 1
        for t, m in zip(tickets, masks):
            want = self.eng.match(MatchQuery.from_masks(m))
            np.testing.assert_array_equal(t.result.best_scores,
                                          want.best_scores)
            np.testing.assert_array_equal(t.result.best_locs,
                                          want.best_locs)

    def test_exact_and_wildcard_group_separately(self):
        pat = self.rng.integers(0, 4, 16, np.uint8)
        masks = mask_case(1, 1, 16, seed=9)[1]
        masks[0] = 0b1111                  # guarantee non-exact
        self.svc.submit(MatchQuery.exact(pat))
        self.svc.submit(MatchQuery.from_masks(masks))
        self.svc.tick()
        assert self.svc.stats.n_launches == 2
        assert self.svc.stats.n_coalesced_launches == 0

    def test_submit_rejects_bad_codes_early(self):
        with pytest.raises(ValueError, match="pattern codes must be < 4"):
            self.svc.submit(np.array([0, 9], np.uint8))

    def test_wildcard_cache_hit(self):
        masks = mask_case(1, 1, 16, seed=10)[1]
        q = MatchQuery.from_masks(masks)
        self.svc.match(q)
        t = self.svc.submit(q)
        self.svc.tick()
        assert t.cached
        assert self.svc.stats.n_cache_hits == 1


class TestDeprecationShims:
    def test_ops_method_kwarg_warns_and_matches(self):
        rng = np.random.default_rng(60)
        frags = rng.integers(0, 4, (6, 50), np.uint8)
        pat = rng.integers(0, 4, 10, np.uint8)
        from repro.kernels import ops
        with pytest.warns(DeprecationWarning, match="method="):
            old = np.asarray(ops.match_scores(frags, pat, method="swar"))
        new = np.asarray(ops.match_scores(frags, pat, backend="swar"))
        np.testing.assert_array_equal(old, new)
        np.testing.assert_array_equal(old, sliding_scores(frags, pat))

    def test_ops_accepts_query(self):
        rng = np.random.default_rng(61)
        frags = rng.integers(0, 4, (6, 50), np.uint8)
        masks = mask_case(1, 1, 10, seed=61)[1]
        from repro.kernels import ops
        got = np.asarray(ops.match_scores(frags,
                                          MatchQuery.from_masks(masks)))
        np.testing.assert_array_equal(got,
                                      sliding_scores_masks(frags, masks))

    def test_engine_kwargs_roundtrip_to_query(self):
        """The legacy kwarg surface and the query IR are the same query:
        same results, and the shim hits the same compile cache entry."""
        rng = np.random.default_rng(62)
        frags = rng.integers(0, 4, (12, 64), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        eng = MatchEngine(frags)
        via_kwargs = eng.match(pat, reduction="topk", k=3, backend="swar")
        q = MatchQuery.exact(pat, reduction="topk", k=3, backend="swar")
        via_query = eng.match(q)
        np.testing.assert_array_equal(via_kwargs.topk_scores,
                                      via_query.topk_scores)
        np.testing.assert_array_equal(via_kwargs.topk_rows,
                                      via_query.topk_rows)
        assert eng.compile(q) is eng.compile(q)

    def test_service_kwargs_roundtrip(self):
        rng = np.random.default_rng(63)
        frags = rng.integers(0, 4, (12, 64), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        eng = MatchEngine(frags)
        svc = MatchService(eng)
        a = svc.match(pat, reduction="threshold", threshold=8)
        b = svc.submit(MatchQuery.exact(pat, reduction="threshold",
                                        threshold=8))
        svc.tick()
        assert b.cached                    # same query -> cache hit
        np.testing.assert_array_equal(a.hits, b.result.hits)

    def test_dedup_method_kwarg_warns(self):
        from repro.data.dedup import CRAMDedup
        with pytest.warns(DeprecationWarning, match="method="):
            CRAMDedup(method="swar")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            CRAMDedup(backend="swar")      # new spelling: no warning

    def test_scores_accepts_query_and_forces_full(self):
        rng = np.random.default_rng(64)
        frags = rng.integers(0, 4, (6, 40), np.uint8)
        masks = mask_case(1, 1, 8, seed=64)[1]
        q = MatchQuery.from_masks(masks, reduction="topk", k=2)
        got = np.asarray(MatchEngine(frags).scores(q))
        np.testing.assert_array_equal(got,
                                      sliding_scores_masks(frags, masks))


class TestQueryBenchSchema:
    def test_smoke_record_validates(self):
        import pathlib
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent
                               .parent / "benchmarks"))
        try:
            import query_bench
        finally:
            sys.path.pop(0)
        record = query_bench.run_bench(smoke=True)
        assert record["smoke"] is True
        assert {r["predicate"] for r in record["results"]} == \
            {"exact", "wildcard"}
        for row in record["results"]:
            assert row["identical"] and row["oracle_ok"]
