"""Q-gram filter index bench: filter-then-verify vs. full scan.

The regime from DESIGN.md Sec. 3g: selective threshold queries (a needle
pattern planted in a small fraction of a large resident corpus) should
not pay for touching every byte of every row.  The filtered path runs the
``CorpusIndex`` signature kernel (a few words per row), gathers the
surviving candidates, and verifies only those through the exact path; the
baseline is the same query with ``filter=False`` (the pre-index full
scan).  Dense queries (low threshold: every row could qualify) must make
the planner fall back to the full scan on its own cost model.

Correctness gates before any timing is reported:

* **no-false-negative oracle check** -- filtered ``hits`` are asserted
  bit-identical to the full scan's *and* to the NumPy oracle
  (``matcher.sliding_scores``) ``argwhere``;
* **survivor fraction** -- the filter must actually prune (asserted far
  below 1); the full run additionally asserts >= 2x measured speedup.

Emits ``BENCH_match_filter.json`` at the repo root and exits nonzero if
the record is malformed.  CI runs ``--smoke`` as a schema guard: same
pipeline and validation on a reduced shape (where the roofline would
rightly keep scanning, so the smoke filter path is forced with the
``filter=True`` query hint), without overwriting the committed artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_filter.json"

# Selective cases sweep the slack path too: thr_off=0 is the exact-
# occurrence filter (zero mismatch budget), thr_off=1 grants one mismatch
# (slack = q signature bits may be absent).
FULL = dict(R=16384, F=256, P=32, planted=96, thr_offs=(0, 1),
            dense_thr=8, repeats=3, force=False)
SMOKE = dict(R=1024, F=128, P=16, planted=12, thr_offs=(0,),
             dense_thr=4, repeats=1, force=True)

REQUIRED_KEYS = ("shape", "device_kind", "backend", "calibration",
                 "n_processes", "n_hosts", "interpret", "smoke", "index",
                 "dense_strategy", "results")
REQUIRED_RESULT_KEYS = ("case", "strategy", "scan_s", "filtered_s",
                        "speedup", "survivor_frac", "n_hits", "identical",
                        "oracle_ok")


def make_corpus(cfg: dict, rng) -> tuple[np.ndarray, np.ndarray]:
    """Random corpus with the needle planted in a few rows."""
    R, F, P = cfg["R"], cfg["F"], cfg["P"]
    frags = rng.integers(0, 4, (R, F), np.uint8)
    pat = rng.integers(0, 4, P, np.uint8)
    rows = rng.choice(R, cfg["planted"], replace=False)
    for r in rows:
        off = int(rng.integers(0, F - P + 1))
        frags[r, off:off + P] = pat
    return frags, pat


def bench_case(eng, pat, oracle, thr: float, repeats: int,
               force: bool) -> dict:
    from repro.match import MatchQuery

    P = len(pat)
    q_fil = MatchQuery.exact(pat, reduction="threshold", threshold=thr,
                             filter=True if force else None)
    q_scan = MatchQuery.exact(pat, reduction="threshold", threshold=thr,
                              filter=False)
    # Warm both lowered programs (jit compile + corpus/index packs).
    res_fil = eng.match(q_fil)
    res_scan = eng.match(q_scan)

    t_fil = t_scan = float("inf")
    # Best-of-N per path: CPU-container timings are noisy; the minimum is
    # the least-contended observation of the same work.
    for _ in range(repeats):
        t0 = time.perf_counter()
        res_scan = eng.match(q_scan)
        t_scan = min(t_scan, time.perf_counter() - t0)
        t0 = time.perf_counter()
        res_fil = eng.match(q_fil)
        t_fil = min(t_fil, time.perf_counter() - t0)

    identical = bool(np.array_equal(res_fil.hits, res_scan.hits))
    want = np.argwhere(oracle >= thr)
    oracle_ok = bool(
        np.array_equal(res_scan.hits[:, :2], want)
        and np.array_equal(res_scan.hits[:, 2], oracle[tuple(want.T)]))
    return {
        "case": f"selective_thr_{thr:g}",
        "strategy": res_fil.plan.strategy,
        "scan_s": round(t_scan, 4),
        "filtered_s": round(t_fil, 4),
        "speedup": round(t_scan / t_fil, 2),
        "survivor_frac": (None if res_fil.survivor_frac is None
                          else round(res_fil.survivor_frac, 5)),
        "n_hits": int(res_fil.hits.shape[0]),
        "identical": identical,
        "oracle_ok": oracle_ok,
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if not record["results"]:
        raise ValueError("BENCH record has no results")
    if record["dense_strategy"] != "scan":
        raise ValueError("planner did not fall back to full scan on the "
                         f"dense query: {record['dense_strategy']!r}")
    for row in record["results"]:
        for key in REQUIRED_RESULT_KEYS:
            if key not in row:
                raise ValueError(f"result row missing key {key!r}: {row}")
        if not row["identical"]:
            raise ValueError(f"{row['case']}: filtered hits diverged from "
                             "the full scan (false negatives!)")
        if not row["oracle_ok"]:
            raise ValueError(f"{row['case']}: scan hits diverged from the "
                             "NumPy oracle")
        if row["strategy"] != "filter":
            raise ValueError(f"{row['case']}: selective query did not take "
                             f"the filtered path ({row['strategy']!r})")
        if row["survivor_frac"] is None or row["survivor_frac"] > 0.25:
            raise ValueError(f"{row['case']}: filter did not prune "
                             f"(survivor_frac={row['survivor_frac']})")
        if row["n_hits"] < 1:
            raise ValueError(f"{row['case']}: planted needle produced no "
                             "hits")
        if not record["smoke"] and row["speedup"] < 2.0:
            raise ValueError(
                f"{row['case']}: filtered path only {row['speedup']}x over "
                "full scan (acceptance floor is 2x)")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.core.matcher import sliding_scores
    from repro.match import MatchEngine, MatchQuery

    cfg = SMOKE if smoke else FULL
    rng = np.random.default_rng(11)
    frags, pat = make_corpus(cfg, rng)
    eng = MatchEngine(frags)
    oracle = sliding_scores(frags, pat)
    P = cfg["P"]

    results = [bench_case(eng, pat, oracle, float(P - off),
                          cfg["repeats"], cfg["force"])
               for off in cfg["thr_offs"]]
    # Dense query: every row is within reach of the low threshold, so the
    # two-stage pipeline cannot prune -- the planner must keep the full
    # scan.  Compile only: the verdict is the plan, and a dense threshold
    # at this shape would materialize millions of hits.
    dense = eng.compile(MatchQuery.exact(
        pat, reduction="threshold", threshold=float(cfg["dense_thr"])))
    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {"R": cfg["R"], "F": cfg["F"], "P": P,
                  "planted_rows": cfg["planted"]},
        **bench_provenance(eng.planner.cost_source),
        "interpret": eng.interpret,
        "smoke": smoke,
        "forced": cfg["force"],
        "index": eng.index.stats(),
        "dense_strategy": dense.plan.strategy,
        "results": results,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with the reduced shape.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    return [
        (f"filter/{row['case']}",
         round(row["filtered_s"] * 1e6, 1),
         f"scan_us={row['scan_s']*1e6:.1f} speedup={row['speedup']}x "
         f"survivors={row['survivor_frac']} hits={row['n_hits']} "
         f"identical={row['identical']}")
        for row in record["results"]
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cases = " ".join(
        f"{r['case']}:speedup={r['speedup']}x:surv={r['survivor_frac']}"
        for r in rec["results"])
    return (f"{BENCH_JSON.name} R={rec['shape']['R']} "
            f"dense={rec['dense_strategy']} {cases}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + forced filter hint (CI schema "
                         "guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for row in record["results"]:
        print(f"{row['case']:>20}  scan={row['scan_s']*1e3:8.1f}ms  "
              f"filtered={row['filtered_s']*1e3:8.1f}ms  "
              f"speedup={row['speedup']:.2f}x  "
              f"survivors={row['survivor_frac']}  "
              f"identical={row['identical']}")
    print(f"dense query strategy: {record['dense_strategy']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
