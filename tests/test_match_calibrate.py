"""Calibrated cost model: fitting, persistence, feedback (DESIGN.md 3i).

No microbenchmarks run here -- tables are built synthetically (curve
fitting and persistence are pure functions of the samples) so the suite
stays fast and deterministic.  The measured path is covered by
``benchmarks/calibrate_bench.py`` and the CI autotune job.
"""

import json

import numpy as np
import pytest

from repro.core.tech import (DISPATCH_OVERHEAD_S, REF_CALL_OVERHEAD_S,
                             CalibratedCostSource, KernelCurve,
                             StaticCostSource)
from repro.match import MatchEngine, MatchQuery
from repro.match.calibrate import (GOLDEN_SHAPES, TABLE_VERSION,
                                   CalibrationTable, bench_provenance,
                                   fit_curve, golden_decisions,
                                   load_cost_source, quantize_q2,
                                   table_filename)
from repro.match.feedback import (EwmaRatio, FeedbackStore, kernel_key,
                                  octave)
from repro.match.planner import Planner


def make_table(alphas=None) -> CalibrationTable:
    """Synthetic table with interpret-mode-like overhead factors."""
    alphas = alphas or {"swar": 256.0, "swar_masks": 181.0, "mxu": 4096.0,
                        "ref": 2.83, "filter": 16.0}
    curves = {k: KernelCurve(alpha=a, beta=1e-5, n_samples=4, rel_err=0.1)
              for k, a in alphas.items()}
    return CalibrationTable(device_kind="cpu", backend="cpu",
                            interpret=True, curves=curves)


# -- fitting ------------------------------------------------------------------

class TestFit:
    def test_recovers_linear_data_within_quantization(self):
        x = np.array([1e-6, 1e-5, 1e-4, 1e-3])
        y = 37.0 * x + 2e-5
        c = fit_curve(x, y)
        assert c.alpha == pytest.approx(37.0, rel=0.10)
        assert c.beta == pytest.approx(2e-5, rel=0.10)
        assert c.n_samples == 4

    def test_negative_intercept_clamps_to_origin(self):
        x = np.array([1e-4, 1e-3, 1e-2])
        y = 10.0 * x - 5e-5          # noise made the intercept negative
        c = fit_curve(x, y)
        assert c.beta == 0.0
        assert c.alpha > 0.0

    def test_positivity_makes_curve_monotone(self):
        rng = np.random.default_rng(3)
        x = np.sort(rng.uniform(1e-6, 1e-2, 6))
        y = 50.0 * x * rng.uniform(0.5, 2.0, 6)   # very noisy
        c = fit_curve(x, y)
        assert c.alpha > 0.0 and c.beta >= 0.0
        grid = np.linspace(1e-7, 1e-1, 32)
        priced = [c.seconds(a) for a in grid]
        assert all(b >= a for a, b in zip(priced, priced[1:]))

    def test_single_sample_median_fallback(self):
        c = fit_curve([1e-4], [3e-3])
        assert c.alpha == pytest.approx(30.0, rel=0.10)
        assert c.beta == 0.0

    def test_zero_samples_raises(self):
        with pytest.raises(ValueError):
            fit_curve([], [])

    def test_quantize_quarter_octave(self):
        assert quantize_q2(0.0) == 0.0
        for v in (3e-5, 1.0, 37.0, 4096.0):
            q = quantize_q2(v)
            assert q == pytest.approx(v, rel=0.10)
            assert quantize_q2(q) == q            # idempotent
        # Values within ~4% land in the same bin (noise immunity).
        assert quantize_q2(100.0) == quantize_q2(103.0)


# -- cost sources -------------------------------------------------------------

class TestCostSources:
    def test_static_pricing_matches_legacy_constants(self):
        s = StaticCostSource()
        assert s.price("swar", 1e-4, 3) == pytest.approx(
            1e-4 + 3 * DISPATCH_OVERHEAD_S)
        assert s.price("ref", 1e-4, 1) == pytest.approx(
            1e-4 + REF_CALL_OVERHEAD_S)
        assert s.tag == "static"

    def test_calibrated_unknown_kernel_falls_back_to_static(self):
        src = CalibratedCostSource({"swar": KernelCurve(10.0, 1e-6)},
                                   digest="ab" * 16)
        assert src.price("swar", 1e-4) == pytest.approx(1e-3 + 1e-6)
        assert src.price("mxu", 1e-4) == pytest.approx(
            StaticCostSource().price("mxu", 1e-4))
        assert src.tag == "calibrated:abababab"


# -- persistence --------------------------------------------------------------

class TestPersistence:
    def test_roundtrip_identical_decisions_on_golden_matrix(self, tmp_path):
        table = make_table()
        path = table.save(tmp_path)
        assert path.name == table_filename("cpu", "cpu", True)
        loaded = CalibrationTable.load("cpu", "cpu", True, tmp_path)
        assert loaded.digest == table.digest
        assert golden_decisions(loaded.cost_source()) == \
            golden_decisions(table.cost_source())

    def test_load_cost_source_missing_table_is_none(self, tmp_path):
        assert load_cost_source("cpu", "cpu", True, tmp_path) is None

    def test_load_cost_source_corrupt_json_is_none(self, tmp_path):
        p = tmp_path / table_filename("cpu", "cpu", True)
        p.write_text("{not json")
        assert load_cost_source("cpu", "cpu", True, tmp_path) is None

    def test_tampered_digest_rejected(self, tmp_path):
        table = make_table()
        p = table.save(tmp_path)
        doc = json.loads(p.read_text())
        doc["curves"]["swar"]["alpha"] *= 2      # edit without re-digesting
        p.write_text(json.dumps(doc))
        with pytest.raises(ValueError, match="digest"):
            CalibrationTable.load("cpu", "cpu", True, tmp_path)
        assert load_cost_source("cpu", "cpu", True, tmp_path) is None

    def test_version_mismatch_rejected(self):
        doc = make_table().to_json()
        doc["version"] = TABLE_VERSION + 1
        with pytest.raises(ValueError, match="version"):
            CalibrationTable.from_json(doc)

    def test_digest_tracks_decision_relevant_fields_only(self):
        a, b = make_table(), make_table()
        b.samples = {"swar": [{"R": 1}]}
        b.meta = {"grid": "different"}
        assert a.digest == b.digest
        c = make_table({"swar": 999.0, "swar_masks": 181.0, "mxu": 4096.0,
                        "ref": 2.83, "filter": 16.0})
        assert c.digest != a.digest

    def test_bench_provenance_shape(self):
        prov = bench_provenance()
        assert set(prov) == {"device_kind", "backend", "calibration",
                             "n_processes", "n_hosts"}
        assert prov["calibration"] == "static"
        assert prov["n_processes"] >= 1 and prov["n_hosts"] >= 1
        tagged = bench_provenance(make_table().cost_source())
        assert tagged["calibration"].startswith("calibrated:")


# -- planner integration ------------------------------------------------------

class TestPlannerIntegration:
    def test_plans_carry_cost_source_tag(self):
        p = Planner()
        plan = p.plan(n_rows=1024, fragment_chars=256, pattern_chars=32)
        assert plan.cost_source == "static"
        assert "[cost=static]" in plan.reason

        src = make_table().cost_source()
        pc = Planner(cost_source=src)
        plan_c = pc.plan(n_rows=1024, fragment_chars=256, pattern_chars=32)
        assert plan_c.cost_source == src.tag
        assert f"[cost={src.tag}]" in plan_c.reason
        assert plan_c.reason.startswith("measured:")

    def test_tiny_escape_is_static_only(self):
        # Static keeps the TINY_OPS ref escape; a calibrated source does a
        # genuine three-way comparison and (with interpret-mode ref
        # overhead) picks the kernel instead.
        shape = dict(n_rows=2, fragment_chars=20, pattern_chars=8)
        assert Planner().plan(**shape).backend == "ref"
        src = make_table().cost_source()
        assert Planner(cost_source=src).plan(**shape).backend == "swar"

    def test_engine_repr_shows_cost_tag(self):
        eng = MatchEngine(np.zeros((4, 32), np.uint8))
        assert "cost=static" in repr(eng)
        src = make_table().cost_source()
        eng_c = MatchEngine(np.zeros((4, 32), np.uint8), cost_source=src)
        assert f"cost={src.tag}" in repr(eng_c)
        # record_runtimes defaults on for calibrated, off for static.
        assert eng_c.record_runtimes and not eng.record_runtimes

    def test_golden_decisions_cover_all_shapes(self):
        dec = golden_decisions(StaticCostSource())
        assert len(dec) == len(GOLDEN_SHAPES)
        assert all(b in ("swar", "mxu", "ref") for _, b in dec)


# -- feedback store -----------------------------------------------------------

class TestFeedback:
    KEY = kernel_key("swar", 1024, 32, 1)

    def test_octave_bucketing(self):
        assert octave(0) == 0 and octave(1) == 0
        assert octave(1024) == 10 and octave(2047) == 10
        assert kernel_key("swar", 1024, 32, 1) == \
            kernel_key("swar", 2000, 60, 1)
        assert kernel_key("swar", 1024, 32, 1) != \
            kernel_key("mxu", 1024, 32, 1)

    def test_warmup_observation_discarded(self):
        fb = FeedbackStore()
        fb.observe(self.KEY, 1e-3, 1.0)          # compile-paying outlier
        assert fb.n_observations == 0
        assert fb.factor(self.KEY) == 1.0

    def test_min_samples_gates_repricing(self):
        fb = FeedbackStore(min_samples=3)
        for _ in range(3):                       # warmup + 2 observations
            fb.observe(self.KEY, 1e-3, 1e-1)
        assert fb.factor(self.KEY) == 1.0
        fb.observe(self.KEY, 1e-3, 1e-1)         # third post-warmup
        assert fb.factor(self.KEY) == pytest.approx(100.0, rel=0.2)
        assert fb.version >= 1
        assert self.KEY in fb.repriced()

    def test_within_bound_keeps_model_price(self):
        fb = FeedbackStore(drift_bound=2.0)
        for _ in range(6):
            fb.observe(self.KEY, 1e-3, 1.5e-3)   # 1.5x: inside the bound
        assert fb.factor(self.KEY) == 1.0
        assert fb.misprediction_rate == 0.0
        assert fb.version == 0

    def test_misprediction_counting_and_snapshot(self):
        fb = FeedbackStore()
        for _ in range(4):
            fb.observe(self.KEY, 1e-3, 5e-3)     # 5x off: mispredictions
        snap = fb.snapshot()
        assert snap["n_observations"] == 3       # first was warmup
        assert snap["n_mispredictions"] == 3
        assert snap["misprediction_rate"] == 1.0
        assert snap["n_buckets"] == 1
        assert snap["n_repriced"] == 1
        assert snap["version"] == fb.version >= 1

    def test_nonpositive_observations_ignored(self):
        fb = FeedbackStore()
        fb.observe(self.KEY, 0.0, 1.0)
        fb.observe(self.KEY, 1.0, 0.0)
        assert not fb._cells

    def test_ewma_ratio_clamps_single_outliers(self):
        e = EwmaRatio(decay=0.5, clamp=(0.1, 10.0))
        assert e.value is None
        e.update(1e9)                            # clamped to 10
        assert e.value == pytest.approx(5.5)     # (1 + 10)/2

    def test_planner_applies_published_factor(self):
        p = Planner()
        R, L, P = 1024, 225, 32
        base = p.swar_seconds(R, L, P, base=True)
        before = p.swar_seconds(R, L, P)
        key = kernel_key("swar", R, P, 1)
        for _ in range(5):
            p.feedback.observe(key, base, base * 50.0)
        after = p.swar_seconds(R, L, P)
        assert before == pytest.approx(base)     # static == base pre-drift
        assert after == pytest.approx(base * 50.0, rel=0.3)
        # base pricing must stay feedback-free (the anti-geometric-mean
        # invariant: observations are recorded against it).
        assert p.swar_seconds(R, L, P, base=True) == pytest.approx(base)

    def test_feedback_repricing_flips_plan(self):
        # Make the static winner (swar) look 1000x worse than measured;
        # the next plan must flip to the alternative.
        p = Planner()
        shape = dict(n_rows=4096, fragment_chars=256, pattern_chars=32,
                     n_patterns=64)
        first = p.plan(**shape)
        assert first.backend == "swar"
        base = p.swar_seconds(-(-4096 // first.n_shards), 225, 32, 64,
                              base=True)
        key = kernel_key("swar", 4096, 32, 64)
        for _ in range(5):
            p.feedback.observe(key, base, base * 1000.0)
        assert p.plan(**shape).backend == "mxu"

    def test_engine_records_and_reprices(self):
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (64, 96), np.uint8)
        eng = MatchEngine(frags, record_runtimes=True)
        q = MatchQuery.exact(frags[0, :16].copy(), backend="swar")
        first_est = eng.compile(q).plan.est_seconds
        for _ in range(6):
            eng.match(q)
        snap = eng.planner.feedback.snapshot()
        assert snap["n_observations"] >= 4       # warmup discarded
        # Static pricing in interpret mode is off by orders of magnitude,
        # so the hot bucket must have been re-priced and the compiled
        # plan revalidated against the bumped version.
        assert snap["n_repriced"] >= 1
        # Freeze the store, then one more run: the compiled plan must
        # revalidate against the bumped feedback version.
        eng.record_runtimes = False
        eng.match(q)
        cm = eng.compile(q)
        assert cm._fb_version == eng.planner.feedback.version
        assert cm.plan.est_seconds > first_est

    def test_static_engine_does_not_record_by_default(self):
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (64, 96), np.uint8)
        eng = MatchEngine(frags)
        q = MatchQuery.exact(frags[0, :16].copy())
        for _ in range(3):
            eng.match(q)
        assert eng.planner.feedback.snapshot()["n_buckets"] == 0
