"""CRAM-PM TPU kernels: Pallas implementations + jnp oracles.

Perf-critical compute hot-spots of the paper's workload, adapted to the TPU
memory hierarchy (see DESIGN.md Sec. 2):

* ``match_swar``  -- VPU bit-parallel sliding match (2-bit packed SWAR).
* ``match_mxu``   -- MXU one-hot correlation matcher (batched patterns).
* ``popcount``    -- bulk bitcount (the Fig. 4b adder tree, SWAR form).
* ``bitwise``     -- bulk NOT/OR/NAND/XOR (Fig. 11 gate-level analogue).

``ref`` holds the pure-jnp oracles.  Matching workloads enter through the
engine layer ``repro.match`` (planner + device-resident packed corpus +
streaming executor; DESIGN.md Sec. 3); ``ops`` keeps thin one-shot compat
wrappers plus the bulk-op entry points.
"""
