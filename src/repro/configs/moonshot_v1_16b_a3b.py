"""moonshot-v1-16b-a3b [moe]: Moonlight-style 64-expert top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=163840, MoE 64e top-6, head_dim=128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab=163_840,
    block_pattern=("moe",),
    n_experts=64, top_k=6, moe_d_ff=1408, capacity_factor=1.25,
    moe_group_size=256,
    rope_theta=1e6, act="silu", norm="rms",
    microbatch=4,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=256,
    block_pattern=("moe",),
    n_experts=8, top_k=2, moe_d_ff=32, moe_group_size=32,
    capacity_factor=4.0,   # E/top_k: no token drops -> exact equivalences
    rope_theta=1e4,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
