"""Step functions: train_step (with microbatch grad accumulation),
prefill_step, decode step -- the three programs the dry-run lowers.

The microbatch loop is a ``lax.scan`` accumulating f32 grads; with
reduce-scatter-friendly output shardings XLA overlaps the cross-replica
grad reduction with the next microbatch's backward pass (the
compute/communication overlap lever recorded in EXPERIMENTS §Perf).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    def sp(x):
        if getattr(x, "ndim", 0) == 0:
            return x
        b = x.shape[0]
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig
                    ) -> Callable[[Any, dict, Dict[str, Any]],
                                  Tuple[Any, dict, dict]]:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def grads_of(params, mb):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, mb))(params)
        return loss, grads

    def train_step(params, opt_state, batch):
        n_mb = max(cfg.microbatch, 1)
        if n_mb > 1:
            mbs = _split_microbatches(batch, n_mb)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss_sum / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
        else:
            loss, grads = grads_of(params, batch)

        grads = adamw.decompress(opt_cfg, adamw.compress(opt_cfg, grads))
        new_params, new_opt, metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        caches = batch["caches"]
        inputs = {k: v for k, v in batch.items() if k != "caches"}
        return model.prefill(cfg, params, inputs, caches)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch):
        return model.decode_step(
            cfg, params, batch["caches"], batch["tokens"],
            batch["cache_index"], enc_out=batch.get("enc_out"))
    return decode_step


def make_step(cfg: ModelConfig, kind: str, opt_cfg=None):
    if kind == "train":
        return make_train_step(cfg, opt_cfg or adamw.OptConfig())
    if kind == "prefill":
        return make_prefill_step(cfg)
    if kind == "decode":
        return make_decode_step(cfg)
    raise ValueError(kind)
