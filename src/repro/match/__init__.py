"""Unified match-engine subsystem (DESIGN.md Sec. 3).

The single entry point for all string-matching workloads:

* ``PackedCorpus`` -- fragments packed once into device-resident SWAR and
  one-hot forms, cached across queries (the paper's keep-data-next-to-
  compute discipline, Sec. 2-3); growable in place (``append_rows`` /
  ``reserve``): capacity-reserved row slots, device-side capacity
  doubling, zero host repacks of resident rows.
* ``MatchQuery`` -- frozen, hashable, declarative query IR: patterns as
  per-position accept-mask predicates (exact / IUPAC ambiguity / N
  wildcards / character classes), reduction spec, row subset, backend
  hints; content-digested for caching.
* ``Planner`` / ``Plan`` -- roofline-arithmetic kernel selection (swar /
  mxu / ref) + all tile/pad geometry for one query, predicate-aware.
* ``MatchEngine`` / ``CompiledMatch`` / ``MatchResult`` -- query compiler
  (``compile(query)`` lowers once: plan + packed pattern operands,
  LRU-cached by query content) over a sharded streaming executor with fused
  best / top-k / threshold reductions per row-chunk.
* ``CorpusIndex`` -- device-resident per-row q-gram signature index
  (filter-then-verify, DESIGN.md Sec. 3g): threshold queries prune rows
  that provably cannot reach their threshold with one cheap bitmap
  kernel pass, then verify the survivors through the exact path --
  zero false negatives by construction, kept incrementally current
  through ``append_rows`` / ``set_rows``.
* ``MatchService`` -- micro-batched multi-tenant front end: queues
  concurrent queries, coalesces compatible ones into fused batched
  launches (priced by ``Planner.plan_batch``), caches results (LRU,
  invalidated on corpus generation change), and ingests new corpus rows
  online (``ingest``: appends batched per tick, interleaved with query
  execution against the same resident corpus).
* ``PatternBank`` / ``HitTicket`` -- standing queries over a document
  stream (DESIGN.md Sec. 3j): thousands of frozen threshold patterns
  packed once into device-resident operands, scored against every
  ``MatchService.ingest`` batch in one roles-swapped fused launch before
  the batch splices in, with a pattern-side q-gram prefilter (zero false
  negatives), per-pattern TTLs/callbacks, and windowed corpus operation
  (tombstone eviction + periodic compaction).
* ``calibrate`` / ``FeedbackStore`` -- measured cost model (DESIGN.md
  Sec. 3i): ``autotune()`` microbenchmarks the kernels and fits
  per-kernel overhead curves, persisted per substrate
  (``load_cost_source()``); ``FeedbackStore`` is the online half, re-
  pricing (kernel, shape-bucket)s whose observed runtimes drift past the
  bound.  "Calibrate once, then serve":
  ``MatchEngine(frags, cost_source=load_cost_source())``.

``repro.kernels.ops.match_scores`` is the thin one-shot compat shim over
this package; long-lived consumers (dedup, serving-scale workloads) hold a
``MatchEngine`` so the corpus stays resident between queries; multi-tenant
traffic goes through a ``MatchService``.
"""

from repro.obs import (MetricsRegistry, Observability,  # noqa: F401
                       Tracer)

from .calibrate import (CalibrationTable, autotune, bench_provenance,
                        load_cost_source)
from .corpus import PackedCorpus
from .engine import CompiledMatch, MatchEngine, MatchResult
from .feedback import EwmaRatio, FeedbackStore, kernel_key
from .index import CorpusIndex, FilterOperands, build_query_filter
from .planner import BatchPlan, FilterContext, Plan, Planner
from .planner import BankPlan
from .query import MatchQuery, as_masks, as_query
from .service import (IngestTicket, MatchService, MatchTicket,
                      ServiceStats)
from .standing import HitTicket, PatternBank, StandingPattern

__all__ = ["PackedCorpus", "Planner", "Plan", "BatchPlan", "FilterContext",
           "MatchQuery", "as_query", "as_masks", "CompiledMatch",
           "MatchEngine", "MatchResult", "MatchService", "MatchTicket",
           "IngestTicket", "ServiceStats", "CorpusIndex", "FilterOperands",
           "build_query_filter", "CalibrationTable", "autotune",
           "bench_provenance", "load_cost_source", "EwmaRatio",
           "FeedbackStore", "kernel_key", "PatternBank", "StandingPattern",
           "HitTicket", "BankPlan", "Observability", "Tracer",
           "MetricsRegistry"]
