"""Logical-axis sharding rules -> NamedShardings (DP/FSDP/TP/EP + pod).

Every parameter/cache/activation dimension carries a *logical* axis name
(declared in ``models.spec.P``); the table below maps logical names onto
mesh axes.  Resolution enforces divisibility: a dimension that does not
divide evenly over its mapped mesh axes silently falls back to replication
(e.g. mamba2's 24 SSD heads on a 16-way model axis) -- the fallback is the
documented behaviour, not an error, so one rule table serves every arch.

Default layout (production mesh (data, model) or (pod, data, model)):
  batch   -> (pod, data)     activations/caches: pure DP
  embed   -> data            FSDP shard of the non-TP parameter dim
  vocab / ff / heads / kv_heads / heads_inner / experts -> model (TP / EP)
  layers  -> None            (scanned stacking dim)
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "vocab": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "heads_inner": ("model",),
    "experts": ("model",),
    "layers": (),
    "seq": (),          # sequence sharding is a hillclimb lever (see perf/)
    # Match-engine corpus rows (repro.match): embarrassingly parallel, the
    # TPU analogue of the paper's independent CRAM arrays (Sec. 3.4).
    "rows": ("data",),
}

# ZeRO-3/FSDP-only profile (§Perf lever): weights shard 256-way on their
# d_model dim and are all-gathered per layer (tens of MB), instead of
# row-parallel TP all-reducing half-GB activations.  Wins whenever
# weight-gather bytes << activation-reduce bytes (hybrid/recurrent archs).
FSDP_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data", "model"),   # pure DP: batch over every axis
    "embed": ("data", "model"),
    "vocab": ("model",),
    "ff": (),
    "heads": (),
    "kv_heads": (),
    "heads_inner": (),
    "experts": ("model",),
    "layers": (),
    "seq": (),
    "rows": ("data", "model"),   # no TP dim in a match query: rows over all
}

RULE_PROFILES = {"2d": LOGICAL_RULES, "fsdp": FSDP_RULES}


def resolve_axis(name: Optional[str], dim: int, mesh: Mesh,
                 rules: Optional[Dict[str, Tuple[str, ...]]] = None, *,
                 warn: bool = False):
    """Mesh axes for one dimension, with divisibility fallback.

    ``warn=True`` makes the fallback *audible*: when ``dim`` does not
    divide its mapped mesh axes the caller gets a ``UserWarning`` naming
    the axis, the dimension and the mesh sizes, instead of a silent
    replication (or partial sharding) whose only symptom is a perf
    cliff.  The default stays silent -- for model parameters the fallback
    is documented behaviour (e.g. mamba2's 24 SSD heads on a 16-way
    model axis) -- but capacity-style dims like match-corpus ``rows``
    opt in.
    """
    if name is None:
        return None
    rules = rules or LOGICAL_RULES
    want = [a for a in rules.get(name, ()) if a in mesh.axis_names]
    if not want:
        return None
    size = int(np.prod([mesh.shape[a] for a in want]))
    if size <= 1:
        return None
    if dim % size != 0:
        # Try dropping leading axes until it divides (partial sharding).
        for i in range(1, len(want)):
            sub = want[i:]
            s = int(np.prod([mesh.shape[a] for a in sub]))
            if dim % s == 0:
                if warn:
                    warnings.warn(
                        f"logical axis {name!r}: dim {dim} does not divide "
                        f"mesh axes {tuple(want)} (sizes "
                        f"{tuple(int(mesh.shape[a]) for a in want)}); "
                        f"partially sharding over {tuple(sub)} only",
                        UserWarning, stacklevel=2)
                return tuple(sub) if len(sub) > 1 else sub[0]
        if warn:
            warnings.warn(
                f"logical axis {name!r}: dim {dim} does not divide mesh "
                f"axes {tuple(want)} (sizes "
                f"{tuple(int(mesh.shape[a]) for a in want)}); falling "
                f"back to replication",
                UserWarning, stacklevel=2)
        return None
    return tuple(want) if len(want) > 1 else want[0]


# -- cyclic row layout (match stack) ------------------------------------------
# A row-sharded match corpus stores its device forms *physically permuted*:
# logical row r lives on shard s = r % S at slot j = r // S, i.e. physical
# index p = s * J + j for per-shard stride J.  Block-sharding the physical
# array over the mesh row axes is then a *cyclic* sharding of logical rows:
#   * contiguous logical appends round-robin across shards, so ingest is
#     balanced by construction (fewest-live-rows-first is exactly "next
#     row goes to shard n % S");
#   * capacity growth is a per-shard zero-extension (reshape (S, J, ...)
#     -> pad axis 1) -- a row's shard and slot never change, so growth
#     stays in place per shard;
#   * slots [j0:j1) across all shards are the contiguous logical rows
#     [j0*S : j1*S), so chunked streaming slices per-shard blocks without
#     any cross-device traffic.

def cyclic_physical_rows(rows, n_shards: int, stride: int):
    """Physical indices of logical row ids under the cyclic layout."""
    rows = np.asarray(rows)
    if n_shards == 1:
        return rows
    return (rows % n_shards) * stride + rows // n_shards


def cyclic_permute(a, n_shards: int):
    """Logical (R, ...) -> physical (R, ...): row j*S+s -> row s*J+j.

    Works on NumPy and JAX arrays (reshape/swapaxes only); R must be a
    multiple of ``n_shards``.
    """
    if n_shards == 1:
        return a
    R = a.shape[0]
    J = R // n_shards
    return a.reshape(J, n_shards, *a.shape[1:]).swapaxes(0, 1).reshape(
        R, *a.shape[1:])


def cyclic_unpermute(a, n_shards: int):
    """Physical (R, ...) -> logical (R, ...): inverse of cyclic_permute."""
    if n_shards == 1:
        return a
    R = a.shape[0]
    J = R // n_shards
    return a.reshape(n_shards, J, *a.shape[1:]).swapaxes(0, 1).reshape(
        R, *a.shape[1:])


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh, rules=None) -> PartitionSpec:
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        r = resolve_axis(name, dim, mesh, rules)
        flat = (r if isinstance(r, tuple) else (r,)) if r else ()
        if any(a in used for a in flat):
            r = None            # a mesh axis may appear once per spec
        else:
            used.update(flat)
        out.append(r)
    return PartitionSpec(*out)


def shardings_for(tree_axes: Any, tree_abstract: Any, mesh: Mesh,
                  rules=None) -> Any:
    """Pytree of NamedShardings matching (axes, abstract-shapes)."""
    def mk(axes, aval):
        return NamedSharding(mesh, spec_for(axes, aval.shape, mesh, rules))
    return jax.tree.map(
        mk, tree_axes, tree_abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_sharding(mesh: Mesh) -> NamedSharding:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return NamedSharding(mesh, PartitionSpec(axes if len(axes) > 1 else axes[0]))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_specs(batch_abstract: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """Shardings for an input batch dict: leading dim = batch, rest
    replicated; scalars replicated."""
    bs = batch_sharding(mesh)

    def mk(aval):
        if getattr(aval, "ndim", 0) == 0:
            return replicated(mesh)
        if aval.shape[0] % total_dp(mesh) == 0:
            spec = [bs.spec[0]] + [None] * (aval.ndim - 1)
            return NamedSharding(mesh, PartitionSpec(*spec))
        return replicated(mesh)

    return jax.tree.map(mk, batch_abstract)


def total_dp(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))
