"""Near-duplicate filtering on the match engine (paper technique as a
first-class data-pipeline feature; DESIGN.md Sec. 4).

Documents are fingerprinted as 2-bit character streams (each byte ->
4 crumbs) and stored one-per-row exactly like the paper's folded reference
(Fig. 3).  The store is a ``repro.match.MatchEngine`` over a capacity-
doubling ``PackedCorpus``: adding a document writes one packed row into the
device-resident corpus (the CRAM row-write analogue, no host repacking of
the resident part), and each candidate query runs the engine's fused
per-row-best reduction row-parallel against the whole store.  The corpus is
only repacked when capacity doubles -- amortized O(1) host packing per
document, the engine's keep-data-next-to-compute discipline doing
production data-plane work.
"""

from __future__ import annotations

import warnings
from typing import List, Optional

import numpy as np

from repro.match import MatchEngine, MatchQuery, PackedCorpus

_INITIAL_CAPACITY = 64


def fingerprint(doc: bytes, length: int = 128) -> np.ndarray:
    """First `length` 2-bit crumbs of the document (byte -> 4 crumbs)."""
    raw = np.frombuffer(doc[: (length + 3) // 4], np.uint8)
    crumbs = np.stack([(raw >> (2 * i)) & 3 for i in range(4)], 1).reshape(-1)
    out = np.zeros(length, np.uint8)
    out[:min(len(crumbs), length)] = crumbs[:length]
    return out


class CRAMDedup:
    """Row-parallel near-dup store on the match engine.

    The store is the 'reference' (one fingerprint per row, all rows matched
    in lock step); the candidate is the 'pattern'.  A pattern shorter than
    the fragment slides, so prefix-shifted duplicates are caught too.
    ``backend=None`` lets the planner pick the kernel per query size.
    """

    def __init__(self, fp_len: int = 128, pattern_len: int = 96,
                 threshold: float = 0.9, backend: Optional[str] = None,
                 method: Optional[str] = None):
        if method is not None:
            warnings.warn("CRAMDedup(method=...) is deprecated; pass "
                          "backend=...", DeprecationWarning, stacklevel=2)
        self.fp_len = fp_len
        self.pattern_len = pattern_len
        self.threshold = threshold
        self.backend = backend if backend is not None else method
        self._n = 0
        # Lifetime counters survive capacity doublings (each _grow replaces
        # the corpus, whose own counters restart at zero).
        self._prior_packs = 0
        self._prior_row_writes = 0
        self._engine = MatchEngine(PackedCorpus(
            np.zeros((_INITIAL_CAPACITY, fp_len), np.uint8)))

    def __len__(self) -> int:
        return self._n

    @property
    def engine(self) -> MatchEngine:
        return self._engine

    @property
    def capacity(self) -> int:
        return self._engine.corpus.n_rows

    @property
    def total_host_packs(self) -> int:
        """Full host packing events over the store's lifetime."""
        return self._prior_packs + self._engine.corpus.host_pack_count

    @property
    def total_row_writes(self) -> int:
        """Incremental packed-row writes over the store's lifetime."""
        return self._prior_row_writes + self._engine.corpus.row_update_count

    def _grow(self) -> None:
        """Double capacity; the one place the store repacks (amortized)."""
        old_corpus = self._engine.corpus
        self._prior_packs += old_corpus.host_pack_count
        self._prior_row_writes += old_corpus.row_update_count
        buf = np.zeros((max(self.capacity * 2, _INITIAL_CAPACITY),
                        self.fp_len), np.uint8)
        buf[:self._n] = old_corpus.fragments[:self._n]
        self._engine = MatchEngine(PackedCorpus(buf))

    def _similarity(self, doc: bytes) -> float:
        if self._n == 0:
            return 0.0
        pat = fingerprint(doc, self.fp_len)[: self.pattern_len]
        query = MatchQuery.exact(pat, reduction="best",
                                 backend=self.backend)
        res = self._engine.match(query)
        # Rows beyond _n are empty capacity; trim before reducing.
        return float(res.best_scores[:self._n].max()) / self.pattern_len

    def is_duplicate(self, doc: bytes) -> bool:
        return self._similarity(doc) >= self.threshold

    def add(self, doc: bytes) -> None:
        if self._n >= self.capacity:
            self._grow()
        self._engine.corpus.set_rows(self._n, fingerprint(doc, self.fp_len))
        self._n += 1

    def filter(self, docs: List[bytes]) -> List[bytes]:
        """Greedy near-dup filter: keep a doc iff not similar to any kept."""
        kept = []
        for d in docs:
            if not self.is_duplicate(d):
                kept.append(d)
                self.add(d)
        return kept
