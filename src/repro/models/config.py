"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` describes every family in the pool (dense / GQA / MoE /
SSM / hybrid / enc-dec / stub-frontend).  The layer stack is expressed as a
``block_pattern`` (e.g. ``("rglru", "rglru", "attn")``) repeated over the
depth; homogeneous runs are scanned (jax.lax.scan over stacked params) to
keep HLO size and compile time flat in depth.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional, Tuple

BlockKind = Literal["attn", "local_attn", "mlp", "moe", "ssd", "rglru"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    norm: str = "rms"                  # rms | layer
    act: str = "silu"                  # silu (SwiGLU) | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # Sequence-mixing pattern per layer; "attn" entries also get an "mlp".
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048           # for local_attn blocks
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                  # per-expert hidden
    capacity_factor: float = 1.25
    moe_group_size: int = 256
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # bf16 intra-chunk decay/score tensors (SSD): halves the dominant
    # activation traffic of the chunked scan (§Perf lever).
    ssd_bf16_intra: bool = False
    # --- hybrid (RG-LRU) ---
    rnn_width: int = 0
    rglru_c: float = 8.0
    # Block-diagonal RG-LRU gates (RecurrentGemma uses block-diagonal
    # projections); > 0 = number of blocks.  With n_blocks == TP width the
    # gates compute entirely within each model shard -- the §Perf lever
    # that removes the per-layer activation all-reduces.
    rglru_block_diag: int = 0
    # --- serving ---
    # int8 KV cache with per (batch, head, position) scales: halves decode
    # cache traffic (§Perf lever for the decode cells).
    kv_quant: bool = False
    # Pad KV heads up to tp_pad so the decode cache shards over the model
    # axis instead of replicating (16x cache-footprint reduction for
    # GQA kv=8 archs at decode_32k; §Perf capacity lever).
    pad_kv_heads: bool = False
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_audio_frames: int = 0
    # --- stub modality frontend ---
    input_mode: str = "tokens"         # tokens | embeddings
    # --- distribution-facing knobs ---
    tp_pad: int = 16                   # pad head counts to a multiple of this
    vocab_pad: int = 16                # pad vocab to a multiple of this
    sharding_profile: str = "2d"       # "2d" (FSDP+TP) | "fsdp" (ZeRO-only)
    param_dtype: str = "f32"           # "bf16" for serving deployments
    remat: bool = True
    microbatch: int = 1                # grad-accum microbatches in train_step
    # --- attention memory knobs ---
    attn_block_q: int = 512
    attn_block_kv: int = 1024

    # ------------------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        return _round_up(self.n_heads, self.tp_pad)

    @property
    def padded_kv_heads(self) -> int:
        """KV heads are replicated when fewer than tp_pad, unless
        ``pad_kv_heads`` forces padding so the cache shards (serving)."""
        if self.n_kv_heads >= self.tp_pad or self.pad_kv_heads:
            return _round_up(self.n_kv_heads, self.tp_pad)
        return self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad)

    @property
    def q_per_kv(self) -> int:
        return self.padded_heads // self.padded_kv_heads

    @property
    def d_inner(self) -> int:          # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssd_heads(self) -> int:
        return _round_up(self.d_inner // self.ssm_head_dim, self.tp_pad)

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """Full per-layer pattern of length n_layers."""
        reps = math.ceil(self.n_layers / len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (no full-attention layer)."""
        return all(b in ("ssd", "rglru", "local_attn")
                   for b in self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def n_params(self) -> int:
        """Approximate parameter count (validated by smoke tests)."""
        from . import model as _model  # lazy: avoid cycle
        import jax
        specs = _model.param_specs(self)
        return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "shape")))

    def n_active_params(self) -> int:
        """Active (per-token) parameters -- differs for MoE."""
        total = self.n_params()
        if self.n_experts:
            per_expert = 3 * self.d_model * self.moe_d_ff
            inactive = ((self.n_experts - self.top_k) * per_expert
                        * sum(1 for b in self.layer_pattern if b == "moe"))
            return total - inactive
        return total


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (shape) column: what gets lowered for the dry-run."""

    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch x shape) is a live dry-run cell (DESIGN.md skips)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention architecture: 500k-token decode state "
                       "has no sub-quadratic mechanism (recorded skip)")
    return True, ""
