"""Mesh-sharded match stack bench: 1M+ rows, near-linear shard scaling.

The regime from DESIGN.md Sec. 3h: a corpus too large (or too
query-loaded) for one device's scan budget shards its rows over the mesh
-- cyclic placement, shard-local kernels under shard_map, a host
survivor-union / top-k merge -- and throughput should scale with the
shard count.  This bench builds a >= 1M-row resident corpus and sweeps
1/2/4/8 row shards for both execution paths (full scan and q-gram
filter-then-verify).

**Timing model: critical path, not wall clock.**  This container runs
every forced host device on the same CPU core(s), so the wall time of an
S-shard shard_map dispatch cannot show the S-way hardware parallelism a
real mesh provides (it time-slices one core; the recorded
``shardmap_wall_s`` column shows exactly that).  What the bench measures
instead is the *critical path* of the sharded execution:

    T(S) = T_local(S) + T_merge(S)

where ``T_local`` is the measured runtime of one shard's work (an engine
holding exactly shard 0's rows, ``frags[0::S]`` under cyclic placement
-- all shards hold the same +-1 row count, so shard 0 is the critical
shard) and ``T_merge`` is the measured host-side cross-shard merge of
the real per-shard partial results.  On a real mesh the S shard-local
legs run concurrently on S devices, so T(S) is the end-to-end latency;
``speedup = T(1) / T(S)``.

Correctness gates before any timing is reported:

* the sharded (shard_map) engine's hits are asserted **bit-identical**
  to the single-shard engine's at every shard count, for both paths;
* the per-shard partial results used for merge timing are derived from
  (and asserted consistent with) the oracle hit set, so the critical-
  path decomposition measures a merge of *real* data;
* **zero false negatives** for the sharded filtered path: filtered hits
  == scan hits on the sharded engine, for the plain pattern, for an
  IUPAC wildcard pattern, and again after online growth
  (``append_rows`` with freshly planted needles);
* ``MatchService`` on the mesh: ingest placement balanced
  (max/min live-row ratio <= 1.1) with per-shard rows in the stats
  snapshot.

Emits ``BENCH_match_shard.json`` at the repo root and exits nonzero if
the record is malformed.  CI runs ``--smoke``: same pipeline, asserts
and schema on a reduced shape (no speedup floor -- scaling needs the
real row count), without overwriting the committed artifact.

``--processes N`` (default 2 on full runs) additionally runs the
N-process ``jax.distributed`` CPU demo (repro.launch.cluster): the same
8-shard mesh split over N controllers must produce bit-identical
threshold / filtered / top-k / best results with flat per-host pack
counters, and the gated row is committed into the artifact.  The
``multihost`` CI job runs ``--smoke --processes 2``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
from typing import Optional

# Forced host devices for the shard sweep -- must land before jax
# initializes its backend (harmless on real accelerators: the flag only
# affects the host platform).  When jax is already imported (driver runs
# where an earlier module pulled it), the run_bench device check governs.
_FORCE = "--xla_force_host_platform_device_count"
if "jax" not in sys.modules and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8").strip()

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_shard.json"

FULL = dict(R=1 << 20, F=64, P=32, planted=192, shards=(1, 2, 4, 8),
            repeats=3, grow=1024)
SMOKE = dict(R=1 << 12, F=64, P=32, planted=24, shards=(1, 2, 4),
             repeats=1, grow=64)

SPEEDUP_FLOOR = 3.0      # at max shards, both paths (full run only)
BALANCE_CEIL = 1.1       # max/min live rows per shard after ingest

REQUIRED_KEYS = ("shape", "device_kind", "backend", "calibration",
                 "n_processes", "n_hosts", "interpret", "smoke", "model",
                 "cpu_count", "shards", "scan", "filtered",
                 "false_negatives", "service")
REQUIRED_MP_KEYS = ("n_processes", "local_devices", "n_shards", "identical",
                    "merge_path", "collective_bytes", "pack_counts",
                    "demo_wall_s")
REQUIRED_RESULT_KEYS = ("shards", "local_s", "merge_s", "critical_path_s",
                        "shardmap_wall_s", "speedup", "identical")


def make_corpus(cfg: dict, rng):
    R, F, P = cfg["R"], cfg["F"], cfg["P"]
    frags = rng.integers(0, 4, (R, F), np.uint8)
    pat = rng.integers(0, 4, P, np.uint8)
    rows = rng.choice(R, cfg["planted"], replace=False)
    for r in rows:
        off = int(rng.integers(0, F - P + 1))
        frags[r, off:off + P] = pat
    return frags, pat


def _timed(fn, repeats: int) -> float:
    """Best-of-N: the minimum is the least-contended observation."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _shard_partial_hits(hits: np.ndarray, s: int, n_shards: int):
    """Shard s's partial hit rows, in shard-local (row // S) ids.

    Cyclic placement: shard s owns logical rows {r : r % S == s}, stored
    at local slot r // S -- so the real shard-local engine's hits for
    shard s are exactly the oracle hits restricted to its rows with the
    row column re-based.  (Bit-identity of the sharded engine is asserted
    separately; this derivation just avoids S redundant full runs.)
    """
    mine = hits[hits[:, 0] % n_shards == s].copy()
    mine[:, 0] //= n_shards
    return mine


def _merge_partial_hits(partials, n_shards: int) -> np.ndarray:
    """Cross-shard merge: local hit lists -> one global-row-ordered list.

    This is the serial tail of the sharded threshold query -- the only
    work that cannot ride the S-way parallelism -- and what T_merge
    times.  Global order (row asc, loc asc) matches the chunk-streamed
    single-shard scan exactly.
    """
    globs = []
    for s, part in enumerate(partials):
        g = part.copy()
        g[:, 0] = g[:, 0] * n_shards + s
        globs.append(g)
    cat = np.concatenate(globs, 0)
    order = np.lexsort((cat[:, 1], cat[:, 0]))
    return cat[order]


def bench_path(frags, query, scan_hits, cfg, *, label: str) -> list:
    """Sweep shard counts for one execution path (one query).

    ``scan_hits`` is the single-shard oracle hit set (also the merge-
    timing input); returns one result row per shard count.
    """
    from repro.launch.mesh import make_row_mesh
    from repro.match import MatchEngine

    repeats = cfg["repeats"]
    rows = []
    t1 = None
    for S in cfg["shards"]:
        # Critical-shard local engine: exactly shard 0's rows.
        local = MatchEngine(frags[0::S].copy())
        local.match(query)                      # warm (compile + pack)
        t_local = _timed(lambda: local.match(query), repeats)

        partials = [_shard_partial_hits(scan_hits, s, S) for s in range(S)]
        if S == 1:
            t_merge = 0.0
        else:
            merged = _merge_partial_hits(partials, S)
            np.testing.assert_array_equal(merged, scan_hits)
            t_merge = _timed(lambda: _merge_partial_hits(partials, S),
                             max(repeats, 3))

        # True shard_map engine: correctness gate + transparent wall time
        # (time-sliced on this host's core(s), so NOT the scaling metric).
        if S > 1:
            es = MatchEngine(frags, mesh=make_row_mesh(S))
            res = es.match(query)
            identical = bool(np.array_equal(res.hits, scan_hits))
            wall = _timed(lambda: es.match(query), 1)
            del es
        else:
            # S=1: `local` holds the whole corpus (frags[0::1]).
            identical = bool(np.array_equal(local.match(query).hits,
                                            scan_hits))
            wall = t_local
        if not identical:
            raise AssertionError(
                f"{label} S={S}: sharded hits diverged from single-shard")

        crit = t_local + t_merge
        if t1 is None:
            t1 = crit
        rows.append({
            "shards": S,
            "local_s": round(t_local, 4),
            "merge_s": round(t_merge, 5),
            "critical_path_s": round(crit, 4),
            "shardmap_wall_s": round(wall, 4),
            "speedup": round(t1 / crit, 2),
            "identical": identical,
        })
        del local
    return rows


def check_false_negatives(frags, pat, cfg, rng) -> dict:
    """Sharded filtered path vs. exhaustive scan: plain, wildcard, grown."""
    from repro.launch.mesh import make_row_mesh
    from repro.match import MatchEngine, MatchQuery

    P = cfg["P"]
    S = max(cfg["shards"])
    es = MatchEngine(frags, mesh=make_row_mesh(S))
    out = {}

    def gate(name, query):
        import dataclasses
        filt = es.match(dataclasses.replace(query, filter=True))
        scan = es.match(dataclasses.replace(query, filter=False))
        if not np.array_equal(filt.hits, scan.hits):
            raise AssertionError(f"false negatives in sharded filtered "
                                 f"path ({name})")
        out[name] = {"n_hits": int(scan.hits.shape[0]),
                     "strategy": filt.plan.strategy,
                     "survivor_frac": filt.survivor_frac}

    q_plain = MatchQuery.exact(pat, reduction="threshold", threshold=float(P))
    gate("plain", q_plain)

    pstr = "".join("ACGT"[c] for c in pat)
    gate("wildcard", MatchQuery.iupac("N" + pstr[1:], reduction="threshold",
                                      threshold=float(P)))

    # Online growth: append fresh rows with newly planted needles, then
    # re-check (survivor union must cover spliced + zero-extended shards).
    more = rng.integers(0, 4, (cfg["grow"], cfg["F"]), np.uint8)
    for r in range(0, cfg["grow"], 7):
        more[r, 3:3 + P] = pat
    es.corpus.append_rows(more)
    gate("after_growth", q_plain)
    return out


def bench_service(cfg) -> dict:
    """MatchService on a row mesh: balanced online ingest, per-shard stats."""
    from repro.launch.mesh import make_row_mesh
    from repro.match import MatchEngine, MatchService

    rng = np.random.default_rng(7)
    S = max(cfg["shards"])
    F = cfg["F"]
    eng = MatchEngine(rng.integers(0, 4, (256, F), np.uint8),
                      mesh=make_row_mesh(S))
    svc = MatchService(eng)
    n_ingested = 0
    for i in range(64):                    # ragged submissions
        n = 1 + (i * 13) % 5
        svc.ingest(rng.integers(0, 4, (n, F), np.uint8))
        n_ingested += n
        if i % 8 == 0:
            svc.submit(rng.integers(0, 4, 16, np.uint8), reduction="best")
        if i % 4 == 0:
            svc.tick()
    svc.flush()
    snap = svc.stats.snapshot()
    return {
        "n_shards": snap["n_shards"],
        "shard_rows": snap["shard_rows"],
        "balance": snap["shard_balance"],
        "n_ingested_rows": snap["n_ingested_rows"],
        "expected_ingested": n_ingested,
    }


def bench_multiprocess(n_processes: int) -> dict:
    """Multi-controller row (DESIGN.md Sec. 3k): the 2-process CPU
    ``jax.distributed`` bit-identity demo, gated before the row is
    committed -- a non-identical result raises instead of recording."""
    from repro.launch.cluster import run_cpu_demo

    t0 = time.perf_counter()
    summary = run_cpu_demo(n_processes=n_processes)
    wall = time.perf_counter() - t0
    if not summary["identical"]:
        raise AssertionError(
            f"multi-process run not bit-identical to single-process: "
            f"{summary['mismatches']}")
    m0 = summary["multiprocess"][0]
    return {
        "n_processes": summary["n_processes"],
        "local_devices": summary["local_devices"],
        "n_shards": summary["n_shards"],
        "identical": True,
        "merge_path": m0["merge_path"],
        "collective_bytes": m0["collective_bytes"],
        "n_collectives": m0["n_collectives"],
        "pack_counts": m0["pack_counts"],
        "single_pack_counts": summary["single"]["pack_counts"],
        "n_stages": len(m0["results"]),
        "demo_wall_s": round(wall, 1),
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not record["smoke"] and "multiprocess" not in record:
        raise ValueError("full-run artifact must carry the multi-process "
                         "row (run with --processes >= 2)")
    if "multiprocess" in record:
        mp = record["multiprocess"]
        for key in REQUIRED_MP_KEYS:
            if key not in mp:
                raise ValueError(f"multiprocess row missing key {key!r}")
        if not mp["identical"]:
            raise ValueError("multi-process run not bit-identical to "
                             "single-process")
        if mp["merge_path"] != "device":
            raise ValueError("multi-process run must merge device-side, "
                             f"got {mp['merge_path']!r}")
        if mp["pack_counts"] != mp["single_pack_counts"]:
            raise ValueError(
                "per-host pack counters moved vs single-process: "
                f"{mp['pack_counts']} != {mp['single_pack_counts']}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if record["model"] != "critical-path":
        raise ValueError("timing model must be declared as 'critical-path'")
    smoke = record["smoke"]
    if not smoke and record["shape"]["R"] < (1 << 20):
        raise ValueError(f"full run needs R >= 1M rows, got "
                         f"{record['shape']['R']}")
    if not smoke and max(record["shards"]) < 8:
        raise ValueError("full run must sweep up to 8 shards")
    for path in ("scan", "filtered"):
        rows = record[path]
        if not rows:
            raise ValueError(f"no results for path {path!r}")
        for row in rows:
            for key in REQUIRED_RESULT_KEYS:
                if key not in row:
                    raise ValueError(f"{path} row missing key {key!r}: "
                                     f"{row}")
            if not row["identical"]:
                raise ValueError(f"{path} S={row['shards']}: sharded run "
                                 "not bit-identical to single shard")
        if not smoke:
            top = rows[-1]
            if top["speedup"] < SPEEDUP_FLOOR:
                raise ValueError(
                    f"{path}: {top['shards']}-shard critical-path speedup "
                    f"{top['speedup']}x is below the {SPEEDUP_FLOOR}x "
                    "acceptance floor")
    for name, fn in record["false_negatives"].items():
        if fn["n_hits"] < 1:
            raise ValueError(f"false-negative gate {name!r} matched no "
                             "hits (needle not planted?)")
        if name != "wildcard" and fn["strategy"] != "filter":
            raise ValueError(f"false-negative gate {name!r} did not take "
                             f"the filtered path ({fn['strategy']!r})")
    svc = record["service"]
    if svc["balance"] > BALANCE_CEIL:
        raise ValueError(f"ingest placement unbalanced: max/min shard "
                         f"rows {svc['balance']} > {BALANCE_CEIL}")
    if len(svc["shard_rows"]) != svc["n_shards"]:
        raise ValueError("service snapshot missing per-shard rows")
    if sum(svc["shard_rows"]) != 256 + svc["n_ingested_rows"]:
        raise ValueError("per-shard rows do not sum to the live corpus")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool, n_processes: Optional[int] = None) -> dict:
    import jax

    from repro.match import MatchEngine, MatchQuery

    if n_processes is None:
        # The committed artifact always carries the multi-process row;
        # plain --smoke (the fast CI schema guard) skips it -- the
        # multihost CI job runs --smoke --processes 2 explicitly.
        n_processes = 0 if smoke else 2

    cfg = SMOKE if smoke else FULL
    if len(jax.devices()) < max(cfg["shards"]):
        raise RuntimeError(
            f"needs {max(cfg['shards'])} devices; run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={max(cfg['shards'])}")
    rng = np.random.default_rng(23)
    frags, pat = make_corpus(cfg, rng)
    P = cfg["P"]

    # Single-shard oracle hits for both paths (also the merge input).
    e1 = MatchEngine(frags)
    q_scan = MatchQuery.exact(pat, reduction="threshold", threshold=float(P),
                              filter=False)
    q_fil = MatchQuery.exact(pat, reduction="threshold", threshold=float(P),
                             filter=True)
    scan_hits = e1.match(q_scan).hits
    fil_res = e1.match(q_fil)
    if not np.array_equal(fil_res.hits, scan_hits):
        raise AssertionError("single-shard filtered hits != scan hits")
    interpret = bool(e1.interpret)
    del e1

    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {"R": cfg["R"], "F": cfg["F"], "P": P,
                  "planted_rows": cfg["planted"]},
        **bench_provenance(),
        "interpret": interpret,
        "smoke": smoke,
        "model": "critical-path",
        "cpu_count": os.cpu_count(),
        "shards": list(cfg["shards"]),
        "scan": bench_path(frags, q_scan, scan_hits, cfg, label="scan"),
        "filtered": bench_path(frags, q_fil, scan_hits, cfg,
                               label="filtered"),
        "false_negatives": check_false_negatives(frags, pat, cfg, rng),
        "service": bench_service(cfg),
    }
    if n_processes >= 2:
        record["multiprocess"] = bench_multiprocess(n_processes)
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with the reduced shape.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    if "multiprocess" in record:
        mp = record["multiprocess"]
        print(f"multiprocess: {mp['n_processes']}x{mp['local_devices']}dev "
              f"identical={mp['identical']} merge={mp['merge_path']}")
    out = []
    for path in ("scan", "filtered"):
        for row in record[path]:
            out.append((
                f"shard/{path}_S{row['shards']}",
                round(row["critical_path_s"] * 1e6, 1),
                f"local_us={row['local_s']*1e6:.1f} "
                f"merge_us={row['merge_s']*1e6:.1f} "
                f"speedup={row['speedup']}x identical={row['identical']}"))
    return out


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cols = " ".join(
        f"{p}@{r['shards']}sh:{r['speedup']}x"
        for p in ("scan", "filtered") for r in rec[p][-1:])
    return (f"{BENCH_JSON.name} R={rec['shape']['R']} model={rec['model']} "
            f"{cols} balance={rec['service']['balance']}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape, no speedup floor (CI schema guard)")
    ap.add_argument("--processes", type=int, default=None,
                    help="also run the N-process jax.distributed CPU "
                         "bit-identity demo and record a multi-process "
                         "row (default: 2 on full runs, off with --smoke)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke, n_processes=args.processes)
    except (ValueError, RuntimeError, AssertionError) as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for path in ("scan", "filtered"):
        for row in record[path]:
            print(f"{path:>9} S={row['shards']}  "
                  f"local={row['local_s']*1e3:9.1f}ms  "
                  f"merge={row['merge_s']*1e3:7.2f}ms  "
                  f"critical={row['critical_path_s']*1e3:9.1f}ms  "
                  f"wall={row['shardmap_wall_s']*1e3:9.1f}ms  "
                  f"speedup={row['speedup']:.2f}x")
    print(f"service: shards={record['service']['n_shards']} "
          f"rows={record['service']['shard_rows']} "
          f"balance={record['service']['balance']}")
    if "multiprocess" in record:
        mp = record["multiprocess"]
        print(f"multiprocess: {mp['n_processes']} procs x "
              f"{mp['local_devices']} devices, {mp['n_shards']} shards, "
              f"identical={mp['identical']} merge={mp['merge_path']} "
              f"collective_bytes={mp['collective_bytes']} "
              f"({mp['demo_wall_s']}s)")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
