"""stablelm-3b [dense]: full-head GQA (kv=32), LayerNorm.

[hf:stabilityai/stablelm-2-1_6b; unverified]  32L d_model=2560 32H (kv=32)
d_ff=6912 vocab=50304, head_dim=80, LayerNorm + GELU MLP.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50_304,
    rope_theta=1e4, act="gelu", norm="layer",
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    rope_theta=1e4, act="gelu", norm="layer",
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
