"""Model-stack tests: chunked attention / SSD / RG-LRU / MoE vs naive
references, per-arch smoke tests, and prefill+decode == full-forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import layers, model, rglru, ssm
from repro.models.config import SHAPES, shape_applicable


def naive_attention(q, k, v, causal=True, window=None, bidir=False):
    B, H, S, D = q.shape
    _, K, Skv, _ = k.shape
    G = H // K
    qg = q.reshape(B, K, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    qi = np.arange(S)[:, None]
    ki = np.arange(Skv)[None, :]
    if not bidir:
        mask = qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


class TestAttention:
    def _qkv(self, rng, B=2, H=4, K=2, S=64, D=16):
        q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, K, S, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, K, S, D)), jnp.float32)
        return q, k, v

    def test_chunked_equals_naive_causal(self, rng):
        q, k, v = self._qkv(rng)
        got = layers._online_softmax_scan(
            q, k, v, causal=True, window=None,
            q_offset=jnp.zeros((2,), jnp.int32), block_kv=16)
        want = naive_attention(q, k, v)
        np.testing.assert_allclose(got, want, atol=2e-3)

    def test_chunked_equals_naive_windowed(self, rng):
        q, k, v = self._qkv(rng)
        got = layers._online_softmax_scan(
            q, k, v, causal=True, window=24,
            q_offset=jnp.zeros((2,), jnp.int32), block_kv=16)
        want = naive_attention(q, k, v, window=24)
        np.testing.assert_allclose(got, want, atol=2e-3)

    def test_chunked_bidir(self, rng):
        q, k, v = self._qkv(rng)
        got = layers._online_softmax_scan(
            q, k, v, causal=False, window=None,
            q_offset=jnp.zeros((2,), jnp.int32), block_kv=16, bidir=True)
        want = naive_attention(q, k, v, bidir=True)
        np.testing.assert_allclose(got, want, atol=2e-3)

    def test_local_block_equals_naive_window(self, rng):
        q, k, v = self._qkv(rng, S=64)
        got = layers._local_block_attention(q, k, v, window=16)
        want = naive_attention(q, k, v, window=16)
        np.testing.assert_allclose(got, want, atol=2e-3)


class TestSSD:
    def _naive_ssd(self, xs, Bv, Cv, dt, A, D):
        """Sequential SSM recurrence: the ground truth for the chunked SSD."""
        B, S, H, P = xs.shape
        N = Bv.shape[-1]
        h = np.zeros((B, H, P, N))
        ys = np.zeros((B, S, H, P))
        for t in range(S):
            a = np.exp(dt[:, t] * A)                        # (B,H)
            dBx = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bv[:, t], xs[:, t])
            h = h * a[:, :, None, None] + dBx
            ys[:, t] = np.einsum("bhpn,bn->bhp", h, Cv[:, t]) \
                + D[None, :, None] * xs[:, t]
        return ys

    def test_chunked_ssd_equals_sequential(self, rng):
        cfg = get_config("mamba2-130m", smoke=True)
        B, S = 2, 64
        H, P, N = cfg.ssd_heads, cfg.ssm_head_dim, cfg.ssm_state
        xs = rng.normal(size=(B, S, H, P)).astype(np.float32)
        Bv = rng.normal(size=(B, S, N)).astype(np.float32)
        Cv = rng.normal(size=(B, S, N)).astype(np.float32)
        dt = np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.5
        A = -np.abs(rng.normal(size=(H,))).astype(np.float32)
        D = rng.normal(size=(H,)).astype(np.float32)
        want = self._naive_ssd(xs, Bv, Cv, dt, A, D)

        # Drive ssd_apply's chunked math directly via its internals:
        # reconstruct by monkey-running the full path with identity
        # projections is messy; instead validate through ssd_apply by
        # matching decode-vs-full below, and check the chunk math here via
        # a 1-chunk vs multi-chunk comparison.
        c_all = self._chunked(cfg, xs, Bv, Cv, dt, A, D, chunk=S)
        c_split = self._chunked(cfg, xs, Bv, Cv, dt, A, D, chunk=16)
        np.testing.assert_allclose(c_all, want, rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(c_split, want, rtol=2e-2, atol=2e-2)

    def _chunked(self, cfg, xs, Bv, Cv, dt, A, D, chunk):
        """Invoke the same chunked math as ssm.ssd_apply (extracted)."""
        B, S, H, P = xs.shape
        N = Bv.shape[-1]
        log_a = dt * A
        c = chunk
        nc = S // c
        xc = xs.reshape(B, nc, c, H, P)
        Bc = Bv.reshape(B, nc, c, N)
        Cc = Cv.reshape(B, nc, c, N)
        dtc = dt.reshape(B, nc, c, H)
        La = np.cumsum(log_a.reshape(B, nc, c, H), axis=2)
        G = np.einsum("bnim,bnjm->bnij", Cc, Bc)
        decay = np.exp(La[:, :, :, None, :] - La[:, :, None, :, :])
        ii = np.arange(c)
        causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
        M = np.where(causal, G[..., None] * decay * dtc[:, :, None, :, :], 0)
        y_intra = np.einsum("bnijh,bnjhp->bnihp", M, xc)
        tail = np.exp(La[:, :, -1:, :] - La)
        cs = np.einsum("bnch,bncm,bnchp->bnhpm", tail * dtc, Bc, xc)
        a_chunk = np.exp(La[:, :, -1, :])
        h = np.zeros((B, H, P, N))
        y_inter = np.zeros((B, nc, c, H, P))
        for n in range(nc):
            y_inter[:, n] = np.einsum("bcm,bch,bhpm->bchp",
                                      Cc[:, n], np.exp(La[:, n]), h)
            h = h * a_chunk[:, n][:, :, None, None] + cs[:, n]
        y = y_intra + y_inter + D[None, None, None, :, None] * xc
        return y.reshape(B, S, H, P)

    def test_decode_matches_full(self, rng):
        """ssd_apply full over S tokens == S decode steps (same params)."""
        cfg = get_config("mamba2-130m", smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(1))
        S, B = 16, 2
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        full_logits, _, _ = model.forward(cfg, params, {"tokens": tokens})
        caches = model.init_cache(cfg, B, S)
        logits = None
        for t in range(S):
            logits, caches = model.decode_step(
                cfg, params, caches, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            logits, full_logits[:, -1], rtol=3e-2, atol=3e-2)


class TestRGLRU:
    def test_scan_equals_sequential(self, rng):
        cfg = get_config("recurrentgemma-9b", smoke=True)
        r = cfg.rnn_width
        p = {k: jnp.asarray(v) for k, v in {
            "w_a": rng.normal(size=(r, r)).astype(np.float32) * 0.1,
            "b_a": rng.normal(size=(r,)).astype(np.float32),
            "w_i": rng.normal(size=(r, r)).astype(np.float32) * 0.1,
            "b_i": rng.normal(size=(r,)).astype(np.float32),
            "lam": np.abs(rng.normal(size=(r,))).astype(np.float32),
        }.items()}
        x = jnp.asarray(rng.normal(size=(2, 24, r)), jnp.float32)
        hh, h_last = rglru._rglru_core(cfg, p, x, None, cfg.rglru_c, "full")
        # sequential reference
        rg = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
        ig = jax.nn.sigmoid(x @ p["w_i"] + p["b_i"])
        log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * rg
        a = jnp.exp(log_a)
        gated = jnp.sqrt(1 - jnp.exp(2 * log_a)) * ig * x
        h = jnp.zeros((2, r))
        for t in range(24):
            h = a[:, t] * h + gated[:, t]
        np.testing.assert_allclose(h, h_last, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(h, hh[:, -1], rtol=2e-3, atol=2e-3)

    def test_decode_matches_full(self, rng):
        cfg = get_config("recurrentgemma-9b", smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(2))
        S, B = 16, 2
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        full_logits, _, _ = model.forward(cfg, params, {"tokens": tokens})
        caches = model.init_cache(cfg, B, S)
        logits = None
        for t in range(S):
            logits, caches = model.decode_step(
                cfg, params, caches, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            logits, full_logits[:, -1], rtol=3e-2, atol=3e-2)


class TestMoE:
    def test_moe_against_bruteforce(self, rng):
        cfg = get_config("olmoe-1b-7b", smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(3))
        p = jax.tree.map(lambda x: x, params)  # grab one layer's moe params
        moe_p = jax.tree.map(lambda x: x[0], params["blocks"]["units"])["0"]["moe"]
        B, S, d = 1, 32, cfg.d_model
        x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.bfloat16)
        out, aux = layers.moe_apply(cfg, moe_p, x)
        assert out.shape == (B, S, d)
        assert float(aux) > 0
        # brute force: same routing decisions, no capacity drop expected at
        # this size -> outputs must match the dispatch-einsum path.
        gates = jax.nn.softmax(
            (x.reshape(S, d) @ moe_p["router"].astype(x.dtype)).astype(jnp.float32), -1)
        probs, idx = jax.lax.top_k(gates, cfg.top_k)
        probs = probs / probs.sum(-1, keepdims=True)
        want = np.zeros((S, d), np.float32)
        for t in range(S):
            for s in range(cfg.top_k):
                e = int(idx[t, s])
                h = jax.nn.silu(x.reshape(S, d)[t] @ moe_p["wg"][e].astype(x.dtype))
                u = x.reshape(S, d)[t] @ moe_p["wu"][e].astype(x.dtype)
                y = (h * u) @ moe_p["wd"][e].astype(x.dtype)
                want[t] += float(probs[t, s]) * np.asarray(y, np.float32)
        np.testing.assert_allclose(
            np.asarray(out).reshape(S, d), want, rtol=5e-2, atol=5e-2)


class TestArchSmoke:
    """Assigned-arch reduced-config smoke tests: one train step shape + no
    NaNs (assignment deliverable f)."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_forward_and_loss(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 32
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
        if cfg.is_encdec:
            batch["frames"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)),
                jnp.bfloat16)
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        elif cfg.input_mode == "embeddings":
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
        else:
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        logits, _, _ = model.forward(cfg, params, batch)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())
        loss = model.loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss))

    @pytest.mark.parametrize("arch", ARCHS)
    def test_grads_finite(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 16
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
        if cfg.is_encdec:
            batch["frames"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)),
                jnp.bfloat16)
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        elif cfg.input_mode == "embeddings":
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
        else:
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        grads = jax.grad(lambda p: model.loss_fn(cfg, p, batch))(params)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat)


class TestPrefillDecode:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen1.5-32b",
                                      "stablelm-3b", "olmoe-1b-7b"])
    def test_prefill_plus_decode_equals_full(self, arch, rng):
        """prefill(t<T) then decode steps reproduces the full forward."""
        cfg = get_config(arch, smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(4))
        B, S, S_pre = 2, 16, 12
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        full_logits, _, _ = model.forward(cfg, params, {"tokens": tokens})
        caches = model.init_cache(cfg, B, S)
        last, caches = model.prefill(cfg, params, {"tokens": tokens[:, :S_pre],
                                                   "caches": None} | {}, caches)
        np.testing.assert_allclose(last, full_logits[:, S_pre - 1],
                                   rtol=3e-2, atol=3e-2)
        logits = last
        for t in range(S_pre, S):
            logits, caches = model.decode_step(
                cfg, params, caches, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(logits, full_logits[:, -1],
                                   rtol=3e-2, atol=3e-2)

    def test_whisper_encdec_decode(self, rng):
        cfg = get_config("whisper-tiny", smoke=True)
        params = model.init_params(cfg, jax.random.PRNGKey(5))
        B, S = 2, 8
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)), jnp.bfloat16)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        full_logits, _, _ = model.forward(
            cfg, params, {"frames": frames, "tokens": tokens})
        enc_out = model.encode(cfg, params, frames)
        caches = model.init_cache(cfg, B, S)
        # fill cross caches via prefill of the first token
        last, caches = model.prefill(
            cfg, params, {"enc_out": enc_out, "tokens": tokens[:, :1]}, caches)
        np.testing.assert_allclose(last, full_logits[:, 0], rtol=4e-2, atol=4e-2)
        logits = last
        for t in range(1, S):
            logits, caches = model.decode_step(
                cfg, params, caches, tokens[:, t:t + 1], jnp.int32(t),
                enc_out=enc_out)
        np.testing.assert_allclose(logits, full_logits[:, -1],
                                   rtol=4e-2, atol=4e-2)


class TestShapeApplicability:
    def test_long500k_runs_only_for_subquadratic(self):
        live = [a for a in ARCHS
                if shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
        assert sorted(live) == ["mamba2-130m", "recurrentgemma-9b"]

    def test_total_cells(self):
        """40 assigned cells = 32 live + 8 recorded skips."""
        live = skips = 0
        for a in ARCHS:
            for s in SHAPES.values():
                ok, _ = shape_applicable(get_config(a), s)
                live += ok
                skips += not ok
        assert live == 32 and skips == 8
