"""Near-duplicate filtering with the CRAM-PM matcher (paper technique as a
first-class data-pipeline feature; DESIGN.md Sec. 4).

Documents are fingerprinted as 2-bit character streams (each byte ->
4 crumbs), stored one-per-row exactly like the paper's folded reference
(Fig. 3), and each incoming document's fingerprint is matched row-parallel
against the store with the bit-parallel kernel; max similarity above
threshold -> duplicate.  This is the paper's string-matching engine doing
production data-plane work.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.kernels import ops


def fingerprint(doc: bytes, length: int = 128) -> np.ndarray:
    """First `length` 2-bit crumbs of the document (byte -> 4 crumbs)."""
    raw = np.frombuffer(doc[: (length + 3) // 4], np.uint8)
    crumbs = np.stack([(raw >> (2 * i)) & 3 for i in range(4)], 1).reshape(-1)
    out = np.zeros(length, np.uint8)
    out[:min(len(crumbs), length)] = crumbs[:length]
    return out


class CRAMDedup:
    """Row-parallel near-dup store.

    The store is the 'reference' (one fingerprint per row, all rows matched
    in lock step); the candidate is the 'pattern'.  A pattern shorter than
    the fragment slides, so prefix-shifted duplicates are caught too.
    """

    def __init__(self, fp_len: int = 128, pattern_len: int = 96,
                 threshold: float = 0.9, method: str = "swar"):
        self.fp_len = fp_len
        self.pattern_len = pattern_len
        self.threshold = threshold
        self.method = method
        self._rows: List[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._rows)

    def _similarity(self, doc: bytes) -> float:
        if not self._rows:
            return 0.0
        store = np.stack(self._rows)
        pat = fingerprint(doc, self.fp_len)[: self.pattern_len]
        scores = np.asarray(ops.match_scores(store, pat, method=self.method))
        return float(scores.max()) / self.pattern_len

    def is_duplicate(self, doc: bytes) -> bool:
        return self._similarity(doc) >= self.threshold

    def add(self, doc: bytes) -> None:
        self._rows.append(fingerprint(doc, self.fp_len))

    def filter(self, docs: List[bytes]) -> List[bytes]:
        """Greedy near-dup filter: keep a doc iff not similar to any kept."""
        kept = []
        for d in docs:
            if not self.is_duplicate(d):
                kept.append(d)
                self.add(d)
        return kept
