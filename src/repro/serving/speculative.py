"""Speculative decoding with the CRAM-PM n-gram proposer.

Draft-free speculation (prompt-lookup class): the bit-parallel matcher
proposes k continuation tokens from the generation history; the target
model verifies all k in ONE batched forward (scoring positions t..t+k), and
the longest agreeing prefix is accepted.  Greedy-sampling equivalence is
exact: accepted tokens are precisely what step-by-step decoding would have
produced, so speedup (accepted tokens per model call) is free.

This is the paper's engine (match a short pattern against a long resident
reference) accelerating the serving plane -- the reference is the token
history, the pattern is the current suffix.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig

from .ngram_cache import NgramSpeculator


@dataclasses.dataclass
class SpecStats:
    model_calls: int = 0
    tokens_out: int = 0
    proposed: int = 0
    accepted: int = 0

    @property
    def tokens_per_call(self) -> float:
        return self.tokens_out / max(self.model_calls, 1)

    @property
    def acceptance(self) -> float:
        return self.accepted / max(self.proposed, 1)


class SpeculativeDecoder:
    """Greedy speculative decoding for a single stream.

    Verification uses the prefill path over the (k+1)-token window --
    one model call scores every proposed position plus the bonus token.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int,
                 k: int = 4, min_confidence: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.k = k
        self.min_confidence = min_confidence
        self.spec = NgramSpeculator(suffix_tokens=4)
        self._verify = jax.jit(self._verify_fn)
        self._decode = jax.jit(
            lambda p, c, t, i: model.decode_step(cfg, p, c, t, i))

    def _verify_fn(self, params, caches, window, start):
        """window (1, k+1) tokens at positions start..start+k -> greedy
        next-token at every position + updated caches."""
        logits, new_caches, _ = model.forward(
            self.cfg, params, {"tokens": window}, mode="full",
            caches=caches, cache_index=start)
        return jnp.argmax(logits, -1), new_caches

    def generate(self, prompt: np.ndarray, max_new: int
                 ) -> Tuple[np.ndarray, SpecStats]:
        stats = SpecStats()
        caches = model.init_cache(self.cfg, 1, self.max_seq)
        toks = list(int(t) for t in prompt)
        self.spec.feed(toks)
        # Prefill the prompt.
        logits, caches = model.prefill(
            self.cfg, self.params, {"tokens": jnp.asarray([toks])}, caches)
        stats.model_calls += 1
        cur = int(jnp.argmax(logits[0]))
        out: List[int] = [cur]
        pos = len(toks)
        while len(out) < max_new and pos + self.k + 1 < self.max_seq:
            prop, conf = self.spec.propose(toks + out, k=self.k)
            if conf >= self.min_confidence and len(prop) == self.k:
                window = np.array([[cur] + [int(t) for t in prop]], np.int32)
                greedy, caches = self._verify(self.params, caches,
                                              jnp.asarray(window),
                                              jnp.int32(pos))
                greedy = np.asarray(greedy[0])
                stats.model_calls += 1
                stats.proposed += self.k
                # position i's greedy output is the target token after
                # window[:i+1]; accept while proposal agrees.
                n_acc = 0
                for i in range(self.k):
                    if int(prop[i]) == int(greedy[i]):
                        n_acc += 1
                    else:
                        break
                stats.accepted += n_acc
                accepted = [int(t) for t in prop[:n_acc]]
                bonus = int(greedy[n_acc])       # model's own next token
                out.extend(accepted + [bonus])
                self.spec.feed(accepted + [bonus])
                pos += n_acc + 1
                cur = bonus
                # Cache holds K/V for all k+1 window positions, but only
                # n_acc+1 are valid; decoding continues at pos (overwrites).
            else:
                logits, caches = self._decode(
                    self.params, caches, jnp.asarray([[cur]]), jnp.int32(pos))
                stats.model_calls += 1
                cur = int(jnp.argmax(logits[0]))
                out.append(cur)
                self.spec.feed([cur])
                pos += 1
            stats.tokens_out = len(out)
        return np.asarray(out[:max_new]), stats
