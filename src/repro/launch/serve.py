"""Serving launcher: batched requests through the Engine.

``python -m repro.launch.serve --arch llama3.2-1b --smoke`` boots a
randomly initialized reduced model, runs a batch of synthetic requests
through the continuous-batching engine, and reports decode throughput +
n-gram speculator acceptance (the paper's matcher in the serving plane).

``--workload match`` serves synthetic string-match traffic instead: many
small shared-mode queries through a ``MatchService`` over one resident
corpus (micro-batched multi-tenant execution, DESIGN.md Sec. 3d), mixed
with online ingestion (``--ingest-every``: the corpus grows in place under
load, Sec. 3f), and reports coalescing + cache + ingest stats alongside
QPS.

``--workload stream`` is the inverted regime (DESIGN.md Sec. 3j): an
open-loop document-arrival generator drives ``MatchService.ingest``
against a standing ``PatternBank`` -- mostly benign docs, a few with
planted bank hits -- over a sliding-window corpus, and reports per-tick
bank-launch counts, hit latency, and prefilter survivor fractions.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model
from repro.obs import Observability
from repro.serving.engine import Engine, Request
from repro.serving.ngram_cache import NgramSpeculator, verify


def _build_obs(args) -> Observability:
    """Observability for the match/stream workloads.

    Spans turn on exactly when a ``--trace`` destination exists -- the
    disabled tracer is a no-op singleton, so an untraced run pays
    nothing -- while the metrics registry is always on (it only
    observes; DESIGN.md Sec. 3l).
    """
    return Observability(spans=bool(args.trace),
                         profiler=bool(args.jax_profiler))


def _export_trace(obs: Observability, path: str) -> None:
    """Write the collected span tree: Chrome/Perfetto JSON by default,
    JSON-lines when the path ends in ``.jsonl``."""
    if path.endswith(".jsonl"):
        obs.tracer.write_jsonl(path)
        n = obs.tracer.n_spans
        print(f"trace: wrote {n} spans to {path} (JSON-lines)")
    else:
        n = obs.tracer.write_chrome(path)
        print(f"trace: wrote {n} spans to {path} "
              f"(load in Perfetto / chrome://tracing)")


def _print_metrics(svc, tick_label: str) -> None:
    """One greppable per-interval metrics line (``--metrics-every``)."""
    s = svc.stats
    m = svc.obs.metrics
    print(f"metrics,{tick_label},"
          f"completed={s.n_completed},"
          f"p50_ms={s.latency_hist.quantile(0.50) * 1e3:.2f},"
          f"p95_ms={s.latency_hist.quantile(0.95) * 1e3:.2f},"
          f"p99_ms={s.latency_hist.quantile(0.99) * 1e3:.2f},"
          f"launches_last_tick={s.launches_last_tick},"
          f"queue_depth={int(m.gauge('service.queue_depth').value)},"
          f"plan_mispredict_rate={m.mispredict_rate():.3f}")


def run_match_service(args) -> None:
    """Synthetic multi-tenant match traffic through one MatchService.

    Requests are declarative ``MatchQuery`` objects; ``--predicate
    wildcard`` turns a few positions of every pattern into ``N`` wildcards
    (accept-everything masks), exercising the accept-set kernel path under
    the same coalescing machinery.  ``--ingest-every K`` mixes online
    ingestion into the stream: every Kth request also appends a fresh
    corpus row through ``service.ingest`` (batched per tick, in-place
    ``append_rows`` -- the corpus grows under load without ever repacking
    its resident rows or rebuilding the engine).  ``--selective K`` makes
    every Kth request a planted-substring threshold lookup, the workload
    the q-gram filter index serves (DESIGN.md Sec. 3g); filter routing
    stats print alongside QPS.
    """
    from repro.match import MatchEngine, MatchQuery, MatchService

    rng = np.random.default_rng(0)
    frags = rng.integers(0, 4, (args.corpus_rows, args.fragment_chars),
                         np.uint8)
    obs = _build_obs(args)
    eng = MatchEngine(frags, obs=obs)
    svc = MatchService(eng)
    P = args.pattern_chars
    pats = rng.integers(0, 4, (args.requests, P), np.uint8)
    if args.predicate == "wildcard":
        masks = (np.uint8(1) << pats).astype(np.uint8)
        n_wild = max(1, P // 8)
        for q in range(args.requests):
            masks[q, rng.integers(0, P, n_wild)] = 0b1111
        queries = [MatchQuery.from_masks(m) for m in masks]
    else:
        queries = [MatchQuery.exact(p) for p in pats]
    if args.selective:
        # Every Kth request is a selective needle-in-haystack lookup: an
        # exact threshold query for a substring planted in the resident
        # corpus -- the workload the q-gram filter index exists for
        # (DESIGN.md Sec. 3g).  The planner routes each through
        # filter-then-verify or full scan on its own cost model; the
        # filter stats below report what actually happened.
        for i in range(0, args.requests, args.selective):
            row = int(rng.integers(0, args.corpus_rows))
            off = int(rng.integers(0, args.fragment_chars - P + 1))
            queries[i] = MatchQuery.exact(frags[row, off:off + P],
                                          reduction="threshold",
                                          threshold=P)
    # Warm the forms so the ingest counters below isolate growth behavior.
    eng.match(queries[0])
    rows_before = eng.corpus.n_rows
    t0 = time.perf_counter()
    tickets, ingests = [], []
    for i, q in enumerate(queries):
        if args.ingest_every and i % args.ingest_every == 0:
            ingests.append(svc.ingest(
                rng.integers(0, 4, args.fragment_chars, np.uint8)))
        tickets.append(svc.submit(q))
        if args.tick_every and (i + 1) % args.tick_every == 0:
            svc.tick()                 # mixed ingest+query ticks under load
            if (args.metrics_every
                    and svc.stats.n_ticks % args.metrics_every == 0):
                _print_metrics(svc, f"tick={svc.stats.n_ticks}")
    svc.flush()
    dt = time.perf_counter() - t0
    assert all(t.done for t in tickets) and all(t.done for t in ingests)
    stats = svc.stats.snapshot()
    print(f"served {len(tickets)} {args.predicate} match queries in "
          f"{dt:.2f}s ({len(tickets)/dt:.1f} qps)")
    print(f"launches={stats['n_launches']} "
          f"coalesced={stats['n_coalesced_launches']} "
          f"(fused {stats['n_coalesced_queries']} queries) "
          f"cache_hits={stats['n_cache_hits']} "
          f"(hit_rate={stats['cache_hit_rate']:.2f}) "
          f"avg_latency={stats['avg_latency_s']*1e3:.1f}ms "
          f"latency_p50={stats['latency_p50_s']*1e3:.1f}ms "
          f"p95={stats['latency_p95_s']*1e3:.1f}ms "
          f"p99={stats['latency_p99_s']*1e3:.1f}ms "
          f"ticks={stats['n_ticks']} "
          f"launches/tick={stats['avg_launches_per_tick']}")
    if stats["timings"]:
        print("stage seconds (last tick): " + " ".join(
            f"{k}={v:.4f}" for k, v in stats["timings"].items()))
    print(f"plan-vs-actual: mispredict_rate="
          f"{stats['plan_mispredict_rate']:.3f} over "
          f"{len(stats['plan_actual'] or {})} (kernel, shape) buckets")
    if args.selective:
        print(f"filtered_launches={stats['n_filtered_launches']} "
              f"(filter_hit_rate={stats['filter_hit_rate']:.2f}) "
              f"avg_survivor_frac={stats['avg_survivor_frac']:.4f} "
              f"index={eng.index.stats() if eng.index else None}")
    if ingests:
        grew = eng.corpus.n_rows - rows_before
        # Resident repacks = host packs beyond the lazy first one per form
        # (a coalesced launch may legitimately first-pack the *other* form
        # when the batched roofline picks the other kernel).
        repacks = (max(0, eng.corpus.swar_pack_count - 1)
                   + max(0, eng.corpus.onehot_pack_count - 1))
        assert repacks == 0, "resident rows must never repack during ingest"
        print(f"ingested {stats['n_ingested_rows']} rows in "
              f"{stats['n_ingest_batches']} batched appends "
              f"({rows_before} -> {eng.corpus.n_rows} rows, capacity "
              f"{eng.corpus.capacity}, resident repacks: {repacks})")
        assert grew == stats["n_ingested_rows"]
    if args.trace:
        _export_trace(obs, args.trace)


def run_stream(args) -> None:
    """Open-loop document stream against a standing pattern bank.

    Each tick, ``--docs-per-tick`` synthetic documents arrive via
    ``service.ingest``; every ``--plant-every``-th document carries a
    planted substring of a registered standing pattern, so the expected
    hit stream is known.  The service scans each tick's fused batch
    against the whole bank in **one** roles-swapped launch before
    appending (asserted below), evicts past ``--window-rows``, and the
    report covers exactly what a standing-query deployment is judged on:
    bank launches per tick, planted-hit detection + latency percentiles,
    and prefilter survivor fractions.
    """
    from repro.match import MatchEngine, MatchService, PackedCorpus, \
        PatternBank

    rng = np.random.default_rng(0)
    F, P = args.fragment_chars, args.pattern_chars
    corpus = PackedCorpus(rng.integers(0, 4, (args.corpus_rows, F),
                                       np.uint8))
    obs = _build_obs(args)
    eng = MatchEngine(corpus, obs=obs)
    bank = PatternBank(F, P, capacity=max(8, args.bank_patterns),
                       filter={"auto": None, "on": True,
                               "off": False}[args.bank_filter])
    pats = rng.integers(0, 4, (args.bank_patterns, P), np.uint8)
    pids = [bank.register(p, threshold=P) for p in pats]
    svc = MatchService(eng, bank=bank, window_rows=args.window_rows or None)

    per_tick_launches, survivor_fracs, latencies = [], [], []
    n_planted = n_detected = 0
    t0 = time.perf_counter()
    for tick in range(args.ticks):
        docs = rng.integers(0, 4, (args.docs_per_tick, F), np.uint8)
        planted_docs = set()
        if args.plant_every:
            for d in range(0, args.docs_per_tick, args.plant_every):
                j = int(rng.integers(0, args.bank_patterns))
                off = int(rng.integers(0, F - P + 1))
                docs[d, off:off + P] = pats[j]
                planted_docs.add(d)
                n_planted += 1
        t_arrive = time.perf_counter()
        ticket = svc.ingest(docs)
        before = svc.stats.n_bank_launches
        svc.tick()
        t_done = time.perf_counter()
        per_tick_launches.append(svc.stats.n_bank_launches - before)
        bt = ticket.bank_ticket
        hit_docs = set(int(d) for d in bt.hits[:, 0])
        n_detected += len(planted_docs & hit_docs)
        latencies.extend((t_done - t_arrive,) * len(planted_docs & hit_docs))
        if bt.survivor_frac is not None:
            survivor_fracs.append(bt.survivor_frac)
    dt = time.perf_counter() - t0

    assert all(n == 1 for n in per_tick_launches), \
        "every ingest tick must cost exactly one fused bank launch"
    assert n_detected == n_planted, \
        f"planted hits missed: {n_detected}/{n_planted}"
    total_docs = args.ticks * args.docs_per_tick
    lat = np.array(sorted(latencies)) if latencies else np.zeros(1)
    print(f"streamed {total_docs} docs over {args.ticks} ticks against "
          f"{bank.n_live} standing patterns in {dt:.2f}s "
          f"({total_docs / dt:.1f} docs/s)")
    print(f"bank launches/tick={np.mean(per_tick_launches):.0f} "
          f"(total {svc.stats.n_bank_launches}, prefilter "
          f"{svc.stats.n_bank_prefilter_launches}) "
          f"planted hits detected {n_detected}/{n_planted} "
          f"hit latency p50={np.percentile(lat, 50) * 1e3:.1f}ms "
          f"p95={np.percentile(lat, 95) * 1e3:.1f}ms")
    surv = (f"mean={np.mean(survivor_fracs):.4f} "
            f"last={survivor_fracs[-1]:.4f}" if survivor_fracs
            else "(scan strategy: no prefilter launches)")
    print(f"prefilter survivor fractions {surv}")
    if args.window_rows:
        print(f"window: corpus {corpus.n_live} live / {corpus.n_rows} "
              f"physical rows (evicted {svc.stats.n_evicted_rows}, "
              f"compactions {corpus.n_compactions})")
        assert corpus.n_live <= args.window_rows
    if args.trace:
        _export_trace(obs, args.trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "match", "stream"),
                    default="lm")
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--corpus-rows", type=int, default=64,
                    help="match workload: resident corpus rows")
    ap.add_argument("--fragment-chars", type=int, default=256,
                    help="match workload: fragment length")
    ap.add_argument("--pattern-chars", type=int, default=32,
                    help="match workload: query pattern length")
    ap.add_argument("--predicate", choices=("exact", "wildcard"),
                    default="exact",
                    help="match workload: exact queries or N-wildcard "
                         "accept-mask queries")
    ap.add_argument("--selective", type=int, default=0,
                    help="match workload: make every Kth request a "
                         "selective exact-threshold lookup of a planted "
                         "substring (0 disables); eligible for the q-gram "
                         "filter index")
    ap.add_argument("--ingest-every", type=int, default=4,
                    help="match workload: ingest one fresh corpus row "
                         "every K requests (0 disables ingestion)")
    ap.add_argument("--tick-every", type=int, default=8,
                    help="match workload: drive a service tick every K "
                         "submissions (0: one big flush at the end)")
    ap.add_argument("--bank-patterns", type=int, default=64,
                    help="stream workload: standing patterns registered "
                         "in the bank")
    ap.add_argument("--ticks", type=int, default=8,
                    help="stream workload: arrival ticks to run")
    ap.add_argument("--docs-per-tick", type=int, default=16,
                    help="stream workload: documents arriving per tick")
    ap.add_argument("--plant-every", type=int, default=4,
                    help="stream workload: every Kth arriving doc carries "
                         "a planted bank hit (0 disables)")
    ap.add_argument("--window-rows", type=int, default=256,
                    help="stream workload: sliding-window corpus bound "
                         "(0: append-only)")
    ap.add_argument("--bank-filter", choices=("auto", "on", "off"),
                    default="auto",
                    help="stream workload: pattern-side q-gram prefilter "
                         "routing (auto: planner prices it)")
    ap.add_argument("--trace", type=str, default="",
                    help="match/stream workloads: write the span tree "
                         "here on exit -- Chrome/Perfetto trace-event "
                         "JSON, or JSON-lines if the path ends in "
                         ".jsonl (enables span collection)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="match workload: print one greppable metrics "
                         "line every N service ticks (0 disables)")
    ap.add_argument("--jax-profiler", action="store_true",
                    help="annotate spans into the jax profiler timeline "
                         "(jax.profiler.TraceAnnotation) as well")
    args = ap.parse_args()

    if args.workload == "match":
        run_match_service(args)
        return
    if args.workload == "stream":
        run_stream(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    eng = Engine(cfg, params, max_seq=args.max_seq, n_slots=args.slots)
    t0 = time.perf_counter()
    eng.run(list(reqs))
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")

    # n-gram speculation demo on the generated streams
    spec = NgramSpeculator()
    acc, tries = 0, 0
    for r in reqs:
        spec.feed(r.out)
    for r in reqs:
        if len(r.out) > 8:
            prop, conf = spec.propose(r.out[:4], k=4)
            acc += verify(prop, np.asarray(r.out[4:8]))
            tries += 4
    if tries:
        print(f"ngram speculator acceptance: {acc}/{tries}")


if __name__ == "__main__":
    main()
