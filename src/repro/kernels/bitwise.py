"""Bulk bitwise ops -- Pallas TPU kernel (Fig. 11 gate-level analogue + RC4).

One kernel, op selected statically; operands stream HBM->VMEM tile-wise and
the result is produced in-place in VMEM -- the TPU rendition of "computation
happens where the data sits" (no intermediate ever returns to HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_TILE = 256
OPS = ("NOT", "OR", "NAND", "XOR", "AND", "NOR")


def _bitwise_kernel(a_ref, b_ref, out_ref, *, op: str):
    a = a_ref[...]
    b = b_ref[...]
    if op == "NOT":
        r = ~a
    elif op == "OR":
        r = a | b
    elif op == "AND":
        r = a & b
    elif op == "NAND":
        r = ~(a & b)
    elif op == "NOR":
        r = ~(a | b)
    elif op == "XOR":
        r = a ^ b
    else:
        raise ValueError(op)
    out_ref[...] = r


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def bitwise(op: str, a: jnp.ndarray, b: jnp.ndarray | None = None,
            *, interpret: bool = False) -> jnp.ndarray:
    """(N, W) uint32 elementwise bulk op; N % N_TILE == 0."""
    if op not in OPS:
        raise ValueError(op)
    if b is None:
        b = a  # unary NOT ignores b
    N, W = a.shape
    if N % N_TILE:
        raise ValueError(f"rows must be padded to a multiple of {N_TILE}")
    kernel = functools.partial(_bitwise_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=(N // N_TILE,),
        in_specs=[pl.BlockSpec((N_TILE, W), lambda i: (i, 0)),
                  pl.BlockSpec((N_TILE, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((N_TILE, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W), jnp.uint32),
        interpret=interpret,
    )(a, b)
