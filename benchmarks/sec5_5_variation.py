"""Paper Sec. 5.5: process-variation Monte Carlo.

Samples per-cell I_crit variation at +/-5/10/20% (uniform, as the paper
sweeps) and evaluates every PM gate's full truth table through the analog
model at its nominal V_gate; reports the fraction of trials in which each
gate still computes its own function, plus the structural-distinctness
guarantee (no two PM gates share (arity, preset), so variation can never
alias one used gate into another -- the paper's actual claim).
"""

import itertools

import numpy as np

from repro.core import gates
from repro.core.tech import NEAR_TERM


def run():
    rng = np.random.default_rng(0)
    rows = []
    trials = 200
    for spread in (0.05, 0.10, 0.20):
        per_gate = {}
        for g in gates.PM_GATE_SET:
            spec = gates.GATES[g]
            v = gates.vgate_center(g, NEAR_TERM)
            ok = 0
            for _ in range(trials):
                s = 1.0 + rng.uniform(-spread, spread)
                good = all(
                    gates.analog_gate_output(g, bits, NEAR_TERM, v_gate=v,
                                             i_crit_scale=s) == spec.truth(bits)
                    for bits in itertools.product((0, 1), repeat=spec.arity))
                ok += good
            per_gate[g] = ok / trials
        detail = " ".join(f"{g}={per_gate[g]:.2f}" for g in gates.PM_GATE_SET)
        rows.append((f"sec5.5/pm{int(spread*100)}", 0.0,
                     f"P(correct at nominal V): {detail}"))
    study = gates.variation_study(NEAR_TERM)
    rows.append(("sec5.5/structural_distinctness", 0.0,
                 f"no_two_pm_gates_share_arity_preset="
                 f"{study['pm_gates_structurally_distinct']} "
                 "(the paper's aliasing argument)"))
    return rows
