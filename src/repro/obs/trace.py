"""Structured nested spans for the match stack (DESIGN.md Sec. 3l).

A ``Tracer`` records a tree of timed spans around every stage of a
request's life -- service enqueue/coalesce, planner decision, corpus
pack/splice/compact, filter, per-chunk launch, cross-shard merge and
host pull.  Zero dependencies (stdlib only): the match stack can thread
a tracer everywhere without importing anything heavy, and the disabled
path is a true no-op.

Design constraints, in order:

* **Disabled means free.**  ``Tracer(enabled=False).span(name)`` returns
  one module-level singleton no-op context manager -- no ``Span``
  object, no dict, no list append; the hot per-chunk loop pays two
  method calls and nothing else.  Tests assert zero allocations on this
  path (``tests/test_obs.py``).  Attribute dicts are therefore passed
  as an optional positional ``attrs`` mapping, never ``**kwargs`` (a
  kwargs dict would be materialized even when disabled); hot callers
  guard dict construction with ``tracer.enabled``.
* **Times are honest.**  Every span carries a monotonic start/end
  (``time.perf_counter``) for durations; a wall-clock start for
  correlation with external logs is derived at export time from the
  tracer's paired ``perf_counter``/``time.time`` epochs (no per-span
  wall-clock read on the hot path).  JAX dispatch is
  asynchronous: a ``launch`` span measures dispatch, the blocking
  device->host transfer lands in the enclosing ``pull`` span -- the
  trace shows where the *host* actually waited, which is what serving
  latency is made of.
* **Exportable two ways.**  ``write_jsonl`` emits one JSON object per
  span (machine-diffable); ``chrome_trace`` / ``write_chrome`` emit the
  Chrome trace-event format (``{"traceEvents": [...]}`` with complete
  "X" events in microseconds), loadable directly in Perfetto / Chrome
  ``about:tracing`` for timeline viewing.

Optional ``jax.profiler`` hook: ``Tracer(profiler=True)`` additionally
enters a ``jax.profiler.TraceAnnotation`` per span, so spans line up
with device activity inside a captured XLA profile.  The import is
lazy; the module itself never touches jax.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

# Stage names the per-request timing breakdown aggregates over
# (MatchResult.timings / ServiceStats.snapshot()["timings"]).  The span
# taxonomy is larger (service.*, splice, compact, bank scans); these are
# the stages every request's critical path decomposes into.
STAGES: Tuple[str, ...] = ("plan", "pack", "filter", "launch", "merge",
                           "pull")

_ATTR_TYPES = (str, int, float, bool, type(None))
_np_generic = None   # cached numpy scalar base; resolved on first use


def _coerce(value: Any) -> Any:
    """Typed attributes only: pass through JSON scalars, stringify rest."""
    if isinstance(value, _ATTR_TYPES):
        return value
    global _np_generic
    if _np_generic is None:
        try:
            import numpy as _np  # localized: obs itself stays stdlib-only
            _np_generic = _np.generic
        except Exception:
            _np_generic = ()
    if _np_generic and isinstance(value, _np_generic):
        return value.item()
    return str(value)


class _NoopSpan:
    """Shared do-nothing span: the disabled tracer's entire cost."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, key: str, value: Any) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed stage: monotonic times, typed attrs, children.

    The hot path is deliberately lean (the overhead gate in
    ``BENCH_match_obs.json`` depends on it): one ``perf_counter`` call
    per boundary, no wall-clock read (derived from the tracer's paired
    epochs at export), no attrs dict unless the caller passed or set
    one, and attribute *coercion* deferred to export -- ``set`` coerces
    eagerly since mid-span values may be mutated later by the caller,
    constructor attrs are coerced when serialized.
    """

    __slots__ = ("tracer", "name", "span_id", "parent_id", "t0", "t1",
                 "attrs", "children", "_prof")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.t1: Optional[float] = None
        self.attrs: Optional[Dict[str, Any]] = attrs
        self.children: List["Span"] = []
        self._prof = None

    # -- context protocol ------------------------------------------------------
    def __enter__(self) -> "Span":
        tr = self.tracer
        tr._n_spans += 1
        self.span_id = tr._n_spans
        stack = tr._stack
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        if tr._annotation is not None:
            self._prof = tr._annotation(self.name)
            self._prof.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = time.perf_counter()
        if self._prof is not None:
            self._prof.__exit__(*exc)
            self._prof = None
        tr = self.tracer
        stack = tr._stack
        # Tolerate a corrupted stack (an exception unwinding through
        # nested spans) instead of mis-attributing children.
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        if stack:
            stack[-1].children.append(self)
        elif len(tr.roots) < tr.max_spans:
            tr.roots.append(self)
        else:
            tr.n_dropped += 1
        return False

    # -- attributes ------------------------------------------------------------
    def set(self, key: str, value: Any) -> None:
        """Attach one typed attribute mid-span."""
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = _coerce(value)

    @property
    def wall0(self) -> float:
        """Wall-clock start, derived from the tracer's paired epochs."""
        return self.tracer.wall_epoch + (self.t0 - self.tracer.t_epoch)

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def walk(self) -> Iterator["Span"]:
        """This span and every descendant, depth-first, start order."""
        yield self
        for ch in self.children:
            yield from ch.walk()

    def stage_seconds(self, stages: Sequence[str] = STAGES
                      ) -> Dict[str, float]:
        """Disjoint per-stage self-times under this span.

        A stage span's time is its duration minus the time of stage
        spans nested inside it (a ``pull`` inside ``filter`` counts as
        pull, not twice), so the stage values sum to at most this span's
        duration and read as a true breakdown.
        """
        out = {s: 0.0 for s in stages}
        known = set(stages)

        def visit(span: "Span") -> float:
            child_stage = 0.0
            for ch in span.children:
                child_stage += visit(ch)
            if span.name in known:
                out[span.name] += max(0.0, span.duration_s - child_stage)
                return span.duration_s
            return child_stage
        for ch in self.children:
            visit(ch)
        if self.name in known:
            out[self.name] += max(0.0,
                                  self.duration_s - sum(out.values()))
        return out

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
                f"{len(self.children)} children)")


class Tracer:
    """Span recorder with a bounded root store and two export formats.

    Single-threaded by design (the whole match stack is); ``enabled``
    may be flipped at runtime, in-flight spans finish normally.
    ``max_spans`` bounds retained *root* spans (a serve run's requests);
    overflow increments ``n_dropped`` instead of growing without bound.
    """

    def __init__(self, *, enabled: bool = False, profiler: bool = False,
                 max_spans: int = 100_000):
        self.enabled = bool(enabled)
        self.max_spans = int(max_spans)
        self.roots: List[Span] = []
        self.n_dropped = 0
        self._stack: List[Span] = []
        self._n_spans = 0
        # perf_counter epoch for trace-event timestamps; wall epoch for
        # human correlation (recorded in trace metadata).
        self.t_epoch = time.perf_counter()
        self.wall_epoch = time.time()
        self._annotation = None
        if profiler:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:
                self._annotation = None

    @property
    def n_spans(self) -> int:
        """Spans started since construction or the last ``clear()``
        (dropped roots included)."""
        return self._n_spans

    def span(self, name: str, attrs: Optional[Dict[str, Any]] = None):
        """Context manager for one stage; free no-op when disabled."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, attrs)

    def current(self) -> Optional[Span]:
        """Innermost open span (None outside any span or when disabled)."""
        return self._stack[-1] if self._stack else None

    def clear(self) -> None:
        self.roots = []
        self._stack = []
        self.n_dropped = 0
        self._n_spans = 0

    def iter_spans(self) -> Iterator[Span]:
        for root in self.roots:
            yield from root.walk()

    # -- export ----------------------------------------------------------------
    @staticmethod
    def _attrs_out(span: Span) -> Dict[str, Any]:
        """Coerce constructor attrs at export (kept raw on the hot path)."""
        if not span.attrs:
            return {}
        return {k: _coerce(v) for k, v in span.attrs.items()}

    def _span_record(self, span: Span) -> Dict[str, Any]:
        return {
            "span_id": span.span_id,
            "parent_id": span.parent_id,
            "name": span.name,
            "wall0": span.wall0,
            "t0_s": span.t0 - self.t_epoch,
            "dur_s": span.duration_s,
            "attrs": self._attrs_out(span),
        }

    def to_jsonl(self) -> str:
        """One JSON object per span (depth-first, start order)."""
        return "\n".join(json.dumps(self._span_record(s))
                         for s in self.iter_spans())

    def write_jsonl(self, path) -> int:
        n = 0
        with open(path, "w") as fh:
            for s in self.iter_spans():
                fh.write(json.dumps(self._span_record(s)) + "\n")
                n += 1
        return n

    def chrome_trace(self, *, pid: int = 0) -> Dict[str, Any]:
        """Chrome/Perfetto trace-event JSON (complete "X" events, us).

        All spans ride one pid/tid (the stack is single-threaded);
        Perfetto nests same-track events by time containment, which
        matches the span tree exactly.
        """
        events = []
        for s in self.iter_spans():
            events.append({
                "name": s.name,
                "cat": "match",
                "ph": "X",
                "ts": (s.t0 - self.t_epoch) * 1e6,
                "dur": s.duration_s * 1e6,
                "pid": pid,
                "tid": 0,
                "args": self._attrs_out(s),
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "wall_epoch": self.wall_epoch,
                "n_spans": self._n_spans,
                "n_dropped_roots": self.n_dropped,
            },
        }

    def write_chrome(self, path, *, pid: int = 0) -> int:
        trace = self.chrome_trace(pid=pid)
        with open(path, "w") as fh:
            json.dump(trace, fh)
        return len(trace["traceEvents"])
