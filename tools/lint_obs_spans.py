#!/usr/bin/env python
"""AST lint: every pallas-dispatching engine path runs under a span.

The observability contract (DESIGN.md Sec. 3l) is that no kernel launch
escapes the trace: any code path in the match runtime that can reach a
``pl.pallas_call`` dispatch must execute inside a tracer span, so a
``--trace`` run accounts for every launch.  This lint enforces that
statically, with no imports and no JAX:

1. **Kernel discovery.**  Parse every module under ``src/repro/kernels/``
   and compute, to a fixpoint, the set of functions that *transitively*
   contain a ``pallas_call`` (directly, or by calling -- by bare name --
   another kernel-package function that does).

2. **Dispatch sites.**  Parse the match runtime modules under
   ``src/repro/match/`` (excluding ``calibrate.py``, whose whole job is
   timing *raw* kernels for the cost model -- wrapping those would
   corrupt the calibration) and find every call whose callee resolves to
   a dispatching kernel function: ``alias.func(...)`` where ``alias``
   imports a kernel module, or a bare name imported from one.

3. **Coverage.**  A dispatch site is covered if it sits lexically inside
   a ``with`` statement over a ``*.span(...)`` context, or -- to a
   fixpoint -- if it sits inside a function every one of whose call
   sites (found across the same runtime modules) is covered.  This lets
   helpers like ``_chunk_scores`` stay span-free as long as each caller
   wraps them.

Exit status 1 with ``file:line`` diagnostics on any uncovered dispatch.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

REPO = Path(__file__).resolve().parent.parent
# calibrate.py times raw kernel dispatches on purpose (autotune must
# measure the kernel, not the kernel plus tracing overhead).
EXCLUDE = {"calibrate.py"}


def _parse(path: Path) -> ast.AST:
    return ast.parse(path.read_text(), filename=str(path))


# -- step 1: which kernel functions transitively reach pallas_call? ----------

def _contains_pallas_call(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "pallas_call":
                return True
            if isinstance(f, ast.Name) and f.id == "pallas_call":
                return True
    return False


def _called_names(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)
    return out


def dispatching_kernel_functions(kernels_dir: Path) -> Set[str]:
    """Bare names of kernel-package functions that reach pallas_call."""
    fns: Dict[str, ast.AST] = {}
    for path in sorted(kernels_dir.glob("*.py")):
        tree = _parse(path)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fns.setdefault(node.name, node)
    dispatching = {n for n, fn in fns.items() if _contains_pallas_call(fn)}
    changed = True
    while changed:
        changed = False
        for name, fn in fns.items():
            if name in dispatching:
                continue
            if _called_names(fn) & dispatching:
                dispatching.add(name)
                changed = True
    return dispatching


# -- step 2 + 3: dispatch sites and span coverage in the runtime -------------

class _Site:
    __slots__ = ("path", "line", "callee", "func_stack", "in_span")

    def __init__(self, path: str, line: int, callee: str,
                 func_stack: Tuple[str, ...], in_span: bool):
        self.path = path
        self.line = line
        self.callee = callee
        self.func_stack = func_stack     # enclosing defs, outermost first
        self.in_span = in_span


def _is_span_with(node: ast.With) -> bool:
    for item in node.items:
        expr = item.context_expr
        if (isinstance(expr, ast.Call)
                and isinstance(expr.func, ast.Attribute)
                and expr.func.attr == "span"):
            return True
    return False


class _Visitor(ast.NodeVisitor):
    """Collect kernel-dispatch sites + every call site of local defs."""

    def __init__(self, path: str, kernel_aliases: Set[str],
                 kernel_names: Set[str], dispatching: Set[str]):
        self.path = path
        self.kernel_aliases = kernel_aliases    # `_fq`, `_swar`, ...
        self.kernel_names = kernel_names        # bare imported names
        self.dispatching = dispatching
        self.sites: List[_Site] = []
        # bare callee name -> list of (func_stack, in_span) call sites
        self.calls: Dict[str, List[Tuple[Tuple[str, ...], bool]]] = {}
        self._funcs: List[str] = []
        self._spans = 0

    def visit_With(self, node: ast.With) -> None:
        if _is_span_with(node):
            self._spans += 1
            self.generic_visit(node)
            self._spans -= 1
        else:
            self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._funcs.append(node.name)
        # Span state does not flow into a nested def: the def's *body*
        # runs when called, not where the `with` is open.
        spans, self._spans = self._spans, 0
        self.generic_visit(node)
        self._spans = spans
        self._funcs.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _callee(self, call: ast.Call) -> Optional[str]:
        f = call.func
        if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                and f.value.id in self.kernel_aliases):
            return f.attr
        if isinstance(f, ast.Name) and f.id in self.kernel_names:
            return f.id
        return None

    def visit_Call(self, node: ast.Call) -> None:
        callee = self._callee(node)
        if callee is not None and callee in self.dispatching:
            self.sites.append(_Site(self.path, node.lineno, callee,
                                    tuple(self._funcs), self._spans > 0))
        f = node.func
        bare = (f.id if isinstance(f, ast.Name)
                else f.attr if isinstance(f, ast.Attribute) else None)
        if bare is not None:
            self.calls.setdefault(bare, []).append(
                (tuple(self._funcs), self._spans > 0))
        self.generic_visit(node)


def _kernel_imports(tree: ast.AST) -> Tuple[Set[str], Set[str]]:
    aliases: Set[str] = set()
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            if "kernels" in mod.split("."):
                for a in node.names:
                    asname = a.asname or a.name
                    # `from repro.kernels import match_swar as _swar`
                    # imports a *module* as an alias; `from
                    # repro.kernels.match_swar import match_swar`
                    # imports a function by name.  Treat both: alias if
                    # the module path ends at the kernels package,
                    # bare name otherwise.
                    if mod.rstrip(".").endswith("kernels"):
                        aliases.add(asname)
                    else:
                        names.add(asname)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if "kernels" in a.name.split("."):
                    aliases.add((a.asname or a.name).split(".")[0])
    return aliases, names


def main(root: Optional[Path] = None) -> int:
    root = Path(root) if root is not None else REPO
    kernels_dir = root / "src" / "repro" / "kernels"
    match_dir = root / "src" / "repro" / "match"
    dispatching = dispatching_kernel_functions(kernels_dir)
    if not dispatching:
        print("lint_obs_spans: no pallas_call found under "
              f"{kernels_dir} -- wrong tree?", file=sys.stderr)
        return 1

    all_sites: List[_Site] = []
    # bare function name -> call sites across all runtime modules
    all_calls: Dict[str, List[Tuple[Tuple[str, ...], bool]]] = {}
    for path in sorted(match_dir.glob("*.py")):
        if path.name in EXCLUDE:
            continue
        tree = _parse(path)
        aliases, names = _kernel_imports(tree)
        v = _Visitor(str(path.relative_to(root)), aliases, names,
                     dispatching)
        v.visit(tree)
        all_sites.extend(v.sites)
        for name, sites in v.calls.items():
            all_calls.setdefault(name, []).extend(sites)

    # Fixpoint: a function is covered if every one of its call sites is
    # lexically in a span or inside a covered function.
    covered_funcs: Set[str] = set()

    def _site_ok(stack: Tuple[str, ...], in_span: bool) -> bool:
        return in_span or any(f in covered_funcs for f in stack)

    changed = True
    while changed:
        changed = False
        for name, sites in all_calls.items():
            if name in covered_funcs:
                continue
            if sites and all(_site_ok(st, sp) for st, sp in sites):
                covered_funcs.add(name)
                changed = True

    violations = [s for s in all_sites
                  if not _site_ok(s.func_stack, s.in_span)]
    if violations:
        for s in violations:
            where = ".".join(s.func_stack) or "<module>"
            print(f"{s.path}:{s.line}: pallas dispatch `{s.callee}` in "
                  f"`{where}` is not under a tracer span (and not every "
                  f"call site of `{where}` is)", file=sys.stderr)
        print(f"lint_obs_spans: {len(violations)} uncovered dispatch "
              f"site(s) of {len(all_sites)}", file=sys.stderr)
        return 1
    print(f"lint_obs_spans: OK -- {len(all_sites)} pallas dispatch sites "
          f"across {match_dir.relative_to(root)} all run under spans "
          f"({len(dispatching)} dispatching kernel fns)")
    return 0


if __name__ == "__main__":
    sys.exit(main(Path(sys.argv[1]) if len(sys.argv) > 1 else None))
