"""Device-resident q-gram filter index (DESIGN.md Sec. 3g).

The paper's premise is that at-scale matching is bound by touching every
byte of the resident database; the companion in-storage accelerator
literature (Jun et al.'s sparse pattern processor; Mutlu et al.'s
minimize-data-touched discipline) prunes with a cheap filter stage before
exact matching.  This module is that stage for the TPU engine:

* ``CorpusIndex`` maintains, per corpus row, a **B-bit q-gram occurrence
  signature**: every q-gram (q consecutive 2-bit characters) of the row is
  hashed to one of B bits and OR'd in.  Signatures are packed as uint32
  words and kept device-resident alongside the corpus's SWAR/one-hot forms
  -- same lazy-pack-once protocol, same incremental row splices
  (``append_rows`` / ``set_rows`` index only the touched rows; pack
  counters stay flat), same generation discipline (the index never stores
  content of its own; it derives from the corpus host buffer it observes).
* ``build_query_filter`` lowers a query to the signature of the q-grams it
  *requires*.  Only q-grams whose q positions are all exact (one-hot
  accept masks) participate -- a q-gram spanning a wildcard/ambiguity
  position is dropped, which can only lose pruning power, never
  correctness.  **Zero false negatives by construction** (the q-gram
  lemma): an alignment scoring >= t has at most e = floor(P - t)
  mismatches; each mismatch destroys at most q required q-grams; each
  signature bit absent from the row witnesses >= 1 destroyed q-gram.  So
  ``popcount(qsig & ~rowsig) > e*q`` proves the row has no qualifying
  alignment.  Hash collisions only ever *add* candidates.
* **Selectivity feedback**: the index tracks measured row-signature
  density and an EWMA of (measured / predicted) survivor fractions from
  executed filtered queries, which calibrates the planner's two-stage
  cost model (``Planner.plan`` with a ``FilterContext``).

The filter stage itself is ``repro.kernels.filter_qgram``; the engine
gathers survivors and verifies them through the existing exact path
(the ``rows=`` subset machinery), bit-identical to a full scan.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed import sharding as _sharding
from repro.kernels import filter_qgram as _fq
from repro.match.feedback import EwmaRatio

from . import merge as _merge

# Host signature packing proceeds in bounded row chunks: pack_bit_rows
# materializes an (n, n_bits) occupancy matrix, which at 1M rows x 256
# bits would be a 1 GiB temporary.  64K-row chunks cap it at ~64 MiB
# with no change in output.
_BUILD_CHUNK_ROWS = 1 << 16

# Fibonacci-multiplicative hash constant (Knuth); the top log2(B) bits of
# the wrapped product spread consecutive q-gram values well.
_HASH_MUL = np.uint32(2654435761)

DEFAULT_Q = 4
DEFAULT_BITS = 256
# One-hot accept mask -> character code (0 for non-one-hot entries; callers
# select with the one-hot test first).
_ONEHOT_CODE = np.zeros(256, np.uint8)
for _c in range(4):
    _ONEHOT_CODE[1 << _c] = _c


def qgram_values(codes: np.ndarray, q: int) -> np.ndarray:
    """(..., n) uint8 codes -> (..., n-q+1) uint32 base-4 q-gram values."""
    codes = np.asarray(codes, np.uint8)
    n = codes.shape[-1]
    if n < q:
        return np.zeros(codes.shape[:-1] + (0,), np.uint32)
    vals = np.zeros(codes.shape[:-1] + (n - q + 1,), np.uint32)
    for j in range(q):
        vals |= codes[..., j:n - q + 1 + j].astype(np.uint32) << \
            np.uint32(2 * j)
    return vals


def hash_bits(vals: np.ndarray, n_bits: int) -> np.ndarray:
    """q-gram values -> signature bit indices in [0, n_bits)."""
    shift = np.uint32(32 - int(n_bits).bit_length() + 1)
    return ((np.asarray(vals, np.uint32) * _HASH_MUL) >> shift).astype(
        np.int64)


def pack_bit_rows(bit_idx_rows: Sequence[np.ndarray], n_bits: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row bit indices -> ((n, Wb) uint32 words, (n,) distinct counts).

    Bit ``b`` of a signature lives at bit ``b % 32`` of word ``b // 32``.
    ``bit_idx_rows`` is a (n, G) array or a ragged sequence of 1-D index
    arrays; duplicates are free (OR is idempotent).  One vectorized
    scatter packs all rows at once -- the first index build on a large
    corpus is O(total q-grams) numpy work, not an O(rows) Python loop --
    and the distinct-bit counts fall out of the packed words.
    """
    n = len(bit_idx_rows)
    wb = n_bits // 32
    if n == 0:
        return np.zeros((0, wb), np.uint32), np.zeros(0, np.int32)
    if isinstance(bit_idx_rows, np.ndarray) and bit_idx_rows.ndim == 2:
        row_ids = np.repeat(np.arange(n), bit_idx_rows.shape[1])
        flat_bits = bit_idx_rows.reshape(-1)
    else:
        lens = np.fromiter((len(b) for b in bit_idx_rows), np.int64, n)
        row_ids = np.repeat(np.arange(n), lens)
        flat_bits = (np.concatenate([np.asarray(b, np.int64)
                                     for b in bit_idx_rows])
                     if lens.sum() else np.zeros(0, np.int64))
    # Boolean occupancy matrix + lane-shift pack (the pack_codes_u32
    # idiom): one fancy assignment and one vectorized reduction, no
    # unbuffered ufunc.at scatter.  Duplicate bits are free.
    occupancy = np.zeros((n, n_bits), np.uint32)
    occupancy[row_ids, flat_bits] = 1
    lanes = occupancy.reshape(n, wb, 32)
    shifts = np.arange(32, dtype=np.uint32)
    words = (lanes << shifts).sum(-1, dtype=np.uint64).astype(np.uint32)
    counts = occupancy.sum(1).astype(np.int32)
    return words, counts


def row_signatures(rows: np.ndarray, q: int, n_bits: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(n, F) uint8 code rows -> packed signatures + per-row bit counts."""
    rows = np.asarray(rows, np.uint8)
    bits = hash_bits(qgram_values(rows, q), n_bits)
    return pack_bit_rows(bits, n_bits)


@dataclasses.dataclass(frozen=True)
class FilterOperands:
    """Per-query filter-stage operands, row-count independent.

    Derived from (query content, index q, index B) only, so -- like the
    packed pattern operands -- they survive every corpus generation and
    every growth step unchanged.
    """

    qsig_words: np.ndarray        # (Q, Wb) uint32 required-bit signatures
    slacks: Tuple[int, ...]       # per-query e*q (negative: unsatisfiable)
    n_bits: Tuple[int, ...]       # per-query distinct required bits


def build_query_filter(masks2d: np.ndarray,
                       thresholds: Sequence[float], q: int,
                       n_bits: int) -> FilterOperands:
    """Lower query accept-masks + thresholds to filter operands.

    ``masks2d`` is (Q, P) uint8 accept masks; a pattern position is
    *exact* iff its mask is one-hot.  Q-grams spanning any non-exact
    position are dropped (conservative).  ``slack = floor(P - t) * q``:
    the mismatch budget times the per-mismatch q-gram damage bound.
    """
    masks2d = np.asarray(masks2d, np.uint8)
    Q, P = masks2d.shape
    onehot = (masks2d & (masks2d - 1)) == 0          # mask 0 never occurs
    codes = _ONEHOT_CODE[masks2d]
    sig_rows = []
    for i in range(Q):
        if P < q:
            sig_rows.append(np.zeros(0, np.int64))
            continue
        vals = qgram_values(codes[i], q)
        usable = np.ones(P - q + 1, bool)
        for j in range(q):
            usable &= onehot[i, j:P - q + 1 + j]
        sig_rows.append(hash_bits(vals[usable], n_bits))
    words, counts = pack_bit_rows(sig_rows, n_bits)
    slacks = tuple(
        (math.floor(P - float(t)) * q) if float(t) <= P else -1
        for t in thresholds)
    return FilterOperands(qsig_words=words, slacks=slacks,
                          n_bits=tuple(int(c) for c in counts))


def binom_cdf(k: int, n: int, p: float) -> float:
    """P(Binomial(n, p) <= k), direct log-space sum (no scipy dep)."""
    if k < 0:
        return 0.0
    if k >= n or p <= 0.0:
        return 1.0
    if p >= 1.0:
        return 0.0
    lg = math.lgamma
    total = 0.0
    for a in range(k + 1):
        total += math.exp(lg(n + 1) - lg(a + 1) - lg(n - a + 1)
                          + a * math.log(p) + (n - a) * math.log1p(-p))
    return min(1.0, total)


def expected_density(n_chars: int, q: int, n_bits: int) -> float:
    """Analytic prior for hashed q-gram signature occupancy.

    A length-``n_chars`` row throws ``n_chars - q + 1`` q-grams into
    ``n_bits`` bins; the expected fraction of bits set is the classic
    occupancy formula.  Shared between the corpus index (before the
    first pack measures the real density) and the pattern bank (which
    models the *arriving documents'* density without ever packing
    them).
    """
    g = int(n_chars) - int(q) + 1
    return 1.0 - (1.0 - 1.0 / int(n_bits)) ** max(g, 0)


def pass_probability(n_query_bits: int, slack: int, density: float) -> float:
    """Probability one random row admits one query under the filter.

    Required bits are modeled as independently present at ``density``;
    the query passes iff at most ``slack`` of its ``n_query_bits``
    required bits are absent.  Negative slack is the unsatisfiable
    sentinel (prunes everything); ``n_query_bits == 0`` or
    ``slack >= n_query_bits`` passes everything.
    """
    if slack < 0:
        return 0.0
    return binom_cdf(int(slack), int(n_query_bits), 1.0 - float(density))


class CorpusIndex:
    """Per-row q-gram signatures, device-resident and grown in place.

    Attaches to a ``PackedCorpus`` as an observer: every row splice
    (``append_rows`` / ``set_rows``) re-derives signatures for exactly the
    touched rows and splices them into the cached device form
    (``.at[].set``), capacity growth zero-extends on device, and
    ``invalidate`` drops the form -- the same residency protocol as the
    SWAR/one-hot forms, with its own ``sig_pack_count`` asserting the
    at-most-one-host-pack invariant.
    """

    def __init__(self, corpus, *, q: int = DEFAULT_Q,
                 n_bits: int = DEFAULT_BITS):
        q = int(q)
        n_bits = int(n_bits)
        if q < 1 or q > 16:
            raise ValueError(f"q must be in [1, 16], got {q}")
        if n_bits < 32 or n_bits & (n_bits - 1):
            raise ValueError(
                f"n_bits must be a power of two >= 32, got {n_bits}")
        if corpus.fragment_chars < q:
            raise ValueError(
                f"fragment_chars={corpus.fragment_chars} shorter than "
                f"q={q}: no q-grams to index")
        self.corpus = corpus
        self.q = q
        self.n_bits = n_bits
        self.sig_words = n_bits // 32
        self._sigs: Optional[jnp.ndarray] = None     # (S_pad, Wb) uint32
        self._row_bits = np.zeros(corpus.capacity, np.int32)
        # Multi-controller: per-row distinct-bit counts live on device
        # ((S_pad, 1) int32, same cyclic layout as the signatures) --
        # each host only ever computes counts for the rows it packs, and
        # density() must be identical on every process, so the mean
        # reduces device-side.
        self._bits_dev: Optional[jnp.ndarray] = None
        self._dsum_fn = None
        self._dcache: Optional[tuple] = None
        self.sig_pack_count = 0
        self.row_update_count = 0
        # Selectivity feedback: EWMA of measured/predicted survivor-
        # fraction ratios from executed filtered queries (the planner's
        # calibration term), plus plain counters for stats surfaces.
        # The shared EwmaRatio idiom (repro.match.feedback) with the
        # historically tight one-decade clamp -- see record_selectivity.
        self._selectivity = EwmaRatio(decay=0.3, clamp=(0.1, 10.0))
        self.n_filter_runs = 0
        self.last_survivor_frac: Optional[float] = None
        corpus.attach_index(self)

    # -- geometry --------------------------------------------------------------
    @property
    def _rows_padded(self) -> int:
        """Device-form row count: per-shard capacity padded to the filter
        row tile.

        The signature form mirrors the corpus's cyclic row layout (same
        shard for every logical row) but pads each shard's slot count to
        ``FILTER_ROW_TILE`` independently -- its stride ``Jf`` is
        therefore generally larger than the corpus forms' ``J``.
        """
        tile = _fq.FILTER_ROW_TILE
        s = self.corpus.n_shards
        j = self.corpus.capacity_padded // s
        return s * (-(-j // tile) * tile)

    @property
    def shard_stride(self) -> int:
        """Per-shard physical stride of the signature form."""
        return self._rows_padded // self.corpus.n_shards

    # -- residency -------------------------------------------------------------
    def signatures(self) -> jnp.ndarray:
        """(S_pad, Wb) uint32 device-resident row signatures.

        First call packs the live rows on the host (one event; reserved
        and padding rows are all-zero); later calls reuse the cached
        array, which row splices keep up to date incrementally.
        """
        if self._sigs is None:
            tr = self.corpus.obs.tracer
            with tr.span("pack",
                         {"form": "qgram_sigs", "rows": self._rows_padded}
                         if tr.enabled else None):
                if self.corpus._multiprocess:
                    self._build_sigs_per_host()
                else:
                    n = self.corpus.n_rows
                    s = self.corpus.n_shards
                    stride = self.shard_stride
                    words = np.zeros((self._rows_padded, self.sig_words),
                                     np.uint32)
                    # Chunked pack (bounded occupancy temporary) straight
                    # into the cyclic physical layout the corpus forms use.
                    for b0 in range(0, n, _BUILD_CHUNK_ROWS):
                        b1 = min(b0 + _BUILD_CHUNK_ROWS, n)
                        live, counts = row_signatures(
                            self.corpus.fragments[b0:b1], self.q,
                            self.n_bits)
                        words[_sharding.cyclic_physical_rows(
                            np.arange(b0, b1), s, stride)] = live
                        self._row_bits[b0:b1] = counts
                    self._sigs = self.corpus._place(words)
            self.sig_pack_count += 1
            self.corpus.obs.metrics.counter("corpus.packs").inc()
        return self._sigs

    def _build_sigs_per_host(self) -> None:
        """First signature pack, multi-controller: per-host shard blocks.

        Signature block ``s`` holds rows ``s::S`` (slot ``j`` <-> logical
        ``s + j*S``), so each process hashes only the rows its devices
        own -- bit-identical to permuting a global pack, at 1/P of the
        host work.  Per-row bit counts ride along as a device form
        (``_bits_dev``) because no host holds all of them.
        """
        S = self.corpus.n_shards
        Jf = self.shard_stride
        n = self.corpus.n_rows
        blocks: dict = {}

        def pack(s):
            blk = blocks.get(s)
            if blk is None:
                words = np.zeros((Jf, self.sig_words), np.uint32)
                counts = np.zeros((Jf, 1), np.int32)
                frag_s = self.corpus._frags[s::S]
                live_s = max(0, (n - s + S - 1) // S)
                for b0 in range(0, live_s, _BUILD_CHUNK_ROWS):
                    b1 = min(b0 + _BUILD_CHUNK_ROWS, live_s)
                    w, c = row_signatures(frag_s[b0:b1], self.q,
                                          self.n_bits)
                    words[b0:b1] = w
                    counts[b0:b1, 0] = c
                blocks[s] = blk = (words, counts)
            return blk
        ns = self.corpus._row_sharding()
        self._sigs = jax.make_array_from_callback(
            (S * Jf, self.sig_words), ns,
            lambda idx: pack((idx[0].start or 0) // Jf)[0])
        self._bits_dev = jax.make_array_from_callback(
            (S * Jf, 1), ns,
            lambda idx: pack((idx[0].start or 0) // Jf)[1])
        self._dcache = None

    # -- corpus observer hooks -------------------------------------------------
    def _on_rows_written(self, start: int, rows: np.ndarray) -> None:
        """Touched-rows-only splice, mirroring ``PackedCorpus._splice_device``."""
        n = rows.shape[0]
        if self._sigs is not None:
            words, counts = row_signatures(rows, self.q, self.n_bits)
            s = self.corpus.n_shards
            if s == 1:
                self._sigs = self._sigs.at[start:start + n, :].set(
                    jnp.asarray(words))
            elif self.corpus._multiprocess:
                phys = _sharding.cyclic_physical_rows(
                    np.arange(start, start + n), s, self.shard_stride)
                self._sigs = _merge.scatter_rows(self._sigs, phys, words)
                if self._bits_dev is not None:
                    self._bits_dev = _merge.scatter_rows(
                        self._bits_dev, phys,
                        counts[:, None].astype(np.int32))
                self._dcache = None
            else:
                phys = jnp.asarray(_sharding.cyclic_physical_rows(
                    np.arange(start, start + n), s, self.shard_stride))
                self._sigs = self._sigs.at[phys, :].set(jnp.asarray(words))
            self._row_bits[start:start + n] = counts
            self.row_update_count += n

    def _on_capacity(self) -> None:
        """Capacity growth: zero-extend on device, extend host counters."""
        cap = self.corpus.capacity
        if cap > self._row_bits.shape[0]:
            self._row_bits = np.concatenate(
                [self._row_bits,
                 np.zeros(cap - self._row_bits.shape[0], np.int32)])
        if self._sigs is not None:
            pad = self._rows_padded
            if self._sigs.shape[0] < pad:
                # Per-shard zero-extension through the corpus's layout
                # helper: rows keep their shard and slot, placement is
                # re-applied.
                self._sigs = self.corpus._grow_form_rows(self._sigs, pad)
                if self._bits_dev is not None:
                    self._bits_dev = self.corpus._grow_form_rows(
                        self._bits_dev, pad)
                    self._dcache = None

    def _on_invalidate(self) -> None:
        self._sigs = None
        self._bits_dev = None
        self._dsum_fn = None
        self._dcache = None

    # -- selectivity model -----------------------------------------------------
    def density(self) -> float:
        """Mean fraction of signature bits set per live row.

        Measured once the index is built; before that, the analytic prior
        for hashed q-gram occupancy (F - q + 1 throws into B bins) -- so
        the planner can price the filter before paying the first pack.
        """
        n = self.corpus.n_rows
        if self._sigs is not None and n:
            if self._bits_dev is not None:
                return self._density_device(n)
            return float(self._row_bits[:n].mean()) / self.n_bits
        return expected_density(self.corpus.fragment_chars, self.q,
                                self.n_bits)

    def _density_device(self, n: int) -> float:
        """Live-row mean bit count from the device counts, replicated.

        The masked integer sum reduces on device (XLA inserts the
        cross-shard psum) and every process receives the same scalar, so
        planner decisions stay in lock step; ``float(total) / n``
        reproduces ``np.mean`` (exact integer sum, one float64 divide)
        bit for bit.  Cached per (generation, n): density is read on
        every plan, the corpus mutates far less often.
        """
        key = (self.corpus.generation, n)
        if self._dcache is not None and self._dcache[0] == key:
            return self._dcache[1]
        if self._dsum_fn is None:
            Jf, S = self.shard_stride, self.corpus.n_shards
            ns = NamedSharding(self.corpus._mesh, PartitionSpec())

            def total(c, n_):
                p = jnp.arange(c.shape[0])
                logical = (p % Jf) * S + p // Jf
                return jnp.sum(jnp.where(logical < n_, c[:, 0], 0))
            self._dsum_fn = jax.jit(total, out_shardings=ns)
        tot = int(np.asarray(self._dsum_fn(self._bits_dev, np.int32(n))))
        val = float(tot) / n / self.n_bits
        self._dcache = (key, val)
        return val

    def estimate_survivor_frac(self, n_query_bits: Sequence[int],
                               slacks: Sequence[int], *,
                               calibrated: bool = True) -> float:
        """Estimated fraction of rows surviving the (union) filter.

        Per query: P(#absent required bits <= slack) with bits modeled as
        independently present at the measured density; union-bounded over
        queries.  ``calibrated=True`` (the planner's spelling) scales by
        the measured-selectivity EWMA; ``calibrated=False`` is the raw
        model prediction -- the quantity measurements are recorded
        against, so the calibration converges to measured/model instead
        of chasing its own output.
        """
        d = self.density()
        total = 0.0
        for bq, slack in zip(n_query_bits, slacks):
            if slack < 0:
                continue                 # unsatisfiable: prunes every row
            total += pass_probability(bq, slack, d)
        if calibrated and self._calibration is not None:
            total *= self._calibration
        return float(min(1.0, total))

    @property
    def _calibration(self) -> Optional[float]:
        """Measured-selectivity EWMA value (None until the first run)."""
        return self._selectivity.value

    def record_selectivity(self, predicted: float, measured: float) -> None:
        """Fold one filtered run's outcome into the calibration EWMA.

        ``predicted`` must be the **uncalibrated** model estimate
        (``estimate_survivor_frac(..., calibrated=False)``): folding in
        ratios against already-calibrated predictions would converge the
        calibrated estimate only to the geometric mean of model and
        truth, never to the truth itself.

        The per-update ratio clamp is deliberately tight (one decade):
        only filtered runs ever record, so a single wild outlier that
        saturated the estimate could flip every future eligible query to
        "scan" and never be contradicted -- an absorbing state.  Walking
        the calibration a long way therefore requires *consistent*
        evidence across runs, each of which still took the filter path.
        """
        self._selectivity.update(measured / max(predicted, 1e-9))
        self.n_filter_runs += 1
        self.last_survivor_frac = measured

    def stats(self) -> dict:
        return {
            "q": self.q,
            "n_bits": self.n_bits,
            "sig_pack_count": self.sig_pack_count,
            "row_update_count": self.row_update_count,
            "density": round(self.density(), 4),
            "n_filter_runs": self.n_filter_runs,
            "last_survivor_frac": self.last_survivor_frac,
            "calibration": (None if self._calibration is None
                            else round(self._calibration, 4)),
        }
