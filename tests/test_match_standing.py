"""Standing-query pattern bank tests (DESIGN.md Sec. 3j).

The load-bearing invariants:

* **bank residency** -- device operands pack lazily at most once
  (``plane_pack_count`` / ``sig_pack_count`` <= 1) across registration,
  unregistration, capacity growth and scans; ``register``/``unregister``
  splice only the touched slots and live patterns stay dense over
  ``[0, n_live)``;
* **one fused launch per batch** -- a ``scan`` (and a
  ``MatchService.ingest`` batch) costs exactly one ``match_swar_masks``
  dispatch regardless of bank size, and its hits are **bit-identical**
  to compiling each standing pattern as an ad-hoc threshold query over
  the same documents;
* the **pattern-side prefilter** has zero false negatives (q-gram lemma,
  roles swapped), including wildcard/IUPAC patterns whose spanned
  q-grams drop out of the signature;
* **windowed corpus operation** -- tombstoned rows vanish from every
  reduction exactly as if the corpus had been rebuilt from the live
  window, and compaction preserves results with flat pack counters;
* the **service integration** satellites: empty-ingest no-op, TTL
  expiry, hit delivery on tickets/callbacks, bank stats in the snapshot.
"""

import numpy as np
import pytest

from repro.kernels.filter_qgram import (FILTER_ROW_TILE, bank_prefilter,
                                        bank_prefilter_ref)
from repro.match import (MatchEngine, MatchQuery, MatchService,
                         PackedCorpus, PatternBank, Planner, as_masks)
from repro.match.index import build_query_filter, row_signatures

F, P = 96, 16


def make_docs(n=24, f=F, seed=0):
    rng = np.random.default_rng(seed)
    return rng, rng.integers(0, 4, (n, f), np.uint8)


def make_bank(n_patterns=6, docs=None, planted=(), seed=1, **kw):
    """Bank of random exact patterns; ``planted`` (doc, off) pairs copy
    pattern i into docs so the expected hit stream is non-empty."""
    rng = np.random.default_rng(seed)
    kw.setdefault("capacity", max(4, n_patterns))
    bank = PatternBank(F, P, **kw)
    pids = []
    for i in range(n_patterns):
        pat = rng.integers(0, 4, P, np.uint8)
        if docs is not None and i < len(planted):
            d, off = planted[i]
            docs[d, off:off + P] = pat
        pids.append(bank.register(pat, threshold=P))
    return bank, pids


def adhoc_hits(docs, bank, pid):
    """Reference: compile the standing pattern ad-hoc over the docs."""
    eng = MatchEngine(PackedCorpus(docs))
    return eng.match(bank.pattern(pid).query).hits


# -- registration / validation ------------------------------------------------

def test_register_spellings_canonicalize():
    bank = PatternBank(F, P, capacity=4)
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 4, P, np.uint8)
    a = bank.register(codes, threshold=P)
    b = bank.register("".join("ACGT"[c] for c in codes), threshold=P)
    q = MatchQuery.exact(codes, reduction="threshold", threshold=float(P))
    c = bank.register(q, threshold=P)
    assert (bank.pattern(a).query == bank.pattern(b).query
            == bank.pattern(c).query)


def test_register_validates():
    bank = PatternBank(F, P, capacity=4)
    with pytest.raises(ValueError):
        bank.register(np.zeros(P + 1, np.uint8), threshold=P)  # wrong len
    with pytest.raises(ValueError):
        bank.register(np.full(P, 7, np.uint8), threshold=P)  # bad codes
    with pytest.raises(ValueError):
        bank.register(np.zeros((2, P), np.uint8), threshold=P)  # 2-D
    with pytest.raises(ValueError):
        PatternBank(F, F + 1)           # pattern longer than fragment
    with pytest.raises(ValueError):
        bank.unregister(999)


def test_as_masks_rejects_2d_query():
    q = MatchQuery.exact(np.zeros((2, P), np.uint8), mode="batched")
    with pytest.raises(ValueError):
        as_masks(q)


# -- residency protocol -------------------------------------------------------

def test_pack_counters_flat_across_lifecycle():
    rng, docs = make_docs()
    # One planted pattern keeps the prefilter from pruning the whole bank,
    # so the verify operand actually packs (once).
    bank, pids = make_bank(4, docs=docs, planted=[(0, 8)], filter=True)
    for _ in range(3):
        bank.scan(docs)
    extra = bank.register(rng.integers(0, 4, P, np.uint8), threshold=P)
    bank.scan(docs)
    bank.unregister(pids[1])
    bank.scan(docs)
    # Growth past capacity: reserve doubles, no repack.
    for _ in range(bank.capacity):
        bank.register(rng.integers(0, 4, P, np.uint8), threshold=P)
    bank.scan(docs)
    assert bank.plane_pack_count == 1
    assert bank.sig_pack_count == 1
    assert bank.slot_update_count > 0        # splices, not packs
    assert bank.capacity > 4                 # growth happened in place


def test_unregister_swap_keeps_slots_dense():
    _, docs = make_docs()
    bank, pids = make_bank(5)
    bank.scan(docs)                          # pack before mutating
    bank.unregister(pids[1])                 # middle: last slot swaps in
    bank.unregister(pids[4])                 # the swapped-in one again
    assert bank.n_live == 3
    live = set(int(x) for x in bank.live_ids())
    assert live == {pids[0], pids[2], pids[3]}
    # Device forms stay correct after the swaps: hits match ad-hoc.
    t = bank.scan(docs)
    for pid in live:
        mine = t.hits[t.hits[:, 2] == pid][:, [0, 1, 3]]
        assert np.array_equal(adhoc_hits(docs, bank, pid), mine)


def test_lazy_pack_defers_until_first_scan():
    bank, _ = make_bank(3)
    assert bank.plane_pack_count == 0 and bank.sig_pack_count == 0
    assert bank.slot_update_count == 0       # nothing resident to splice


# -- one fused launch + bit-identity ------------------------------------------

def test_scan_is_one_launch_any_bank_size():
    _, docs = make_docs()
    for n in (1, 7, 40):
        bank, _ = make_bank(n, capacity=64)
        before = bank.n_bank_launches
        bank.scan(docs)
        assert bank.n_bank_launches - before == 1


def test_hits_bit_identical_to_adhoc_compiles():
    _, docs = make_docs(seed=3)
    bank, pids = make_bank(
        6, docs=docs, planted=[(2, 5), (9, 40), (9, 77)], seed=4)
    t = bank.scan(docs)
    assert t.hits.shape[0] >= 3
    for pid in pids:
        mine = t.hits[t.hits[:, 2] == pid][:, [0, 1, 3]]
        assert np.array_equal(adhoc_hits(docs, bank, pid), mine)


def test_hits_bit_identical_with_wildcards_and_thresholds():
    _, docs = make_docs(seed=5)
    bank = PatternBank(F, P, capacity=8)
    docs[4, 10:10 + P] = 2
    pids = [
        bank.register("GG" + "N" * (P - 4) + "GG", threshold=P - 2),
        bank.register("RYRYRYRYRYRYRYRY", threshold=P - 6),
        bank.register(docs[0, 3:3 + P].copy(), threshold=P - 1),
    ]
    t = bank.scan(docs)
    assert t.hits.shape[0] > 0
    for pid in pids:
        mine = t.hits[t.hits[:, 2] == pid][:, [0, 1, 3]]
        assert np.array_equal(adhoc_hits(docs, bank, pid), mine)


def test_scan_anchors_corpus_rows():
    _, docs = make_docs(seed=3)
    bank, _ = make_bank(4, docs=docs, planted=[(2, 5)], seed=4)
    t = bank.scan(docs, base_row=100)
    assert (t.corpus_rows == 100 + t.hits[:, 0]).all()
    assert bank.scan(docs).corpus_rows is None


def test_empty_batch_and_empty_bank_launch_nothing():
    _, docs = make_docs()
    bank, _ = make_bank(3)
    t = bank.scan(np.zeros((0, F), np.uint8))
    assert t.hits.shape == (0, 4) and bank.n_bank_launches == 0
    empty = PatternBank(F, P)
    t = empty.scan(docs)
    assert t.hits.shape == (0, 4) and empty.n_bank_launches == 0
    assert empty.n_scans == 0


# -- pattern-side prefilter ---------------------------------------------------

def test_bank_prefilter_kernel_matches_oracle():
    rng = np.random.default_rng(7)
    Q, Wb, D = 2 * FILTER_ROW_TILE, 8, 16
    psigs = rng.integers(0, 1 << 32, (Q, Wb), np.uint64).astype(np.uint32)
    dsigs = rng.integers(0, 1 << 32, (D, Wb), np.uint64).astype(np.uint32)
    slacks = rng.integers(-2, 260, (Q, 1)).astype(np.int32)
    got = np.asarray(bank_prefilter(psigs, dsigs, slacks,
                                    interpret=True))[:, 0]
    assert np.array_equal(got, bank_prefilter_ref(psigs, dsigs, slacks))


def test_bank_prefilter_validates():
    z = np.zeros((FILTER_ROW_TILE, 8), np.uint32)
    s = np.zeros((FILTER_ROW_TILE, 1), np.int32)
    with pytest.raises(ValueError):
        bank_prefilter(z[:-1], z[:4], s[:-1], interpret=True)
    with pytest.raises(ValueError):
        bank_prefilter(z, z[:4, :-1], s, interpret=True)
    with pytest.raises(ValueError):
        bank_prefilter(z, z[:4], s[:-1], interpret=True)


_REALIZE = {"A": 0, "C": 1, "G": 2, "T": 3, "N": 0, "R": 0, "Y": 1}


@pytest.mark.parametrize("kind", ["exact", "wildcard", "iupac"])
def test_prefilter_zero_false_negatives(kind):
    """Forced-filter hits == forced-scan hits on every pattern flavor."""
    rng = np.random.default_rng(11)
    docs = rng.integers(0, 4, (32, F), np.uint8)
    specs = []
    for i in range(8):
        s = "".join("ACGT"[c] for c in rng.integers(0, 4, P, np.uint8))
        if kind == "wildcard":
            s = "NNNN" + s[4:]
        elif kind == "iupac":
            s = "RYRY" + s[4:]
        specs.append(s)
        if i < 4:
            # Plant a realization consistent with the ambiguity codes
            # (R -> A, Y -> C) so real hits exist for the filter to keep.
            real = np.array([_REALIZE[ch] for ch in s], np.uint8)
            docs[i, 3 + 11 * i:3 + 11 * i + P] = real
    tickets = {}
    for mode in (True, False):
        bank = PatternBank(F, P, capacity=8, filter=mode)
        for s in specs:
            bank.register(s, threshold=P - 2)
        tickets[mode] = bank.scan(docs)
        assert bank.n_prefilter_launches == (1 if mode else 0)
    # Same registration order -> same pattern ids, and survivors keep
    # ascending slot order, so the hit arrays must be exactly equal.
    assert tickets[False].hits.shape[0] >= 4    # planted hits fired
    assert np.array_equal(tickets[True].hits, tickets[False].hits)
    assert tickets[True].n_verified <= tickets[False].n_verified


def test_prefilter_prunes_and_calibrates():
    _, docs = make_docs(n=16, seed=13)
    bank, pids = make_bank(12, docs=docs, planted=[(0, 8)], seed=14,
                           filter=True)
    t = bank.scan(docs)
    assert t.plan.strategy == "filter"
    assert t.survivor_frac is not None and t.survivor_frac < 1.0
    assert bank.last_survivor_frac == t.survivor_frac
    assert bank.stats()["calibration"] is not None
    # The planted pattern survived and fired.
    assert pids[0] in set(int(x) for x in t.hits[:, 2])


def test_unsatisfiable_threshold_never_fires():
    _, docs = make_docs()
    bank = PatternBank(F, P, capacity=4, filter=True)
    pid = bank.register(docs[0, :P].copy(), threshold=P + 5)
    t = bank.scan(docs)
    assert t.hits.shape[0] == 0
    assert bank.pattern(pid).slack < 0


def test_plan_bank_pricing():
    pl = Planner()
    scan = pl.plan_bank(n_docs=8, fragment_chars=F, pattern_chars=P,
                        n_patterns=4, sig_words=8, survivor_frac=0.9,
                        prunable=False)
    assert scan.strategy == "scan" and scan.est_filter_seconds == 0.0
    forced = pl.plan_bank(n_docs=8, fragment_chars=F, pattern_chars=P,
                          n_patterns=4, sig_words=8, survivor_frac=0.9,
                          prunable=True, force=True)
    assert forced.strategy == "filter"
    off = pl.plan_bank(n_docs=8, fragment_chars=F, pattern_chars=P,
                       n_patterns=4, sig_words=8, survivor_frac=0.01,
                       prunable=True, force=False)
    assert off.strategy == "scan"
    # Selective big bank: the two-stage path must eventually win.
    big = pl.plan_bank(n_docs=64, fragment_chars=F, pattern_chars=P,
                       n_patterns=4096, sig_words=8, survivor_frac=0.001,
                       prunable=True)
    assert big.strategy == "filter"
    assert big.est_seconds < big.est_scan_seconds
    with pytest.raises(ValueError):
        pl.plan_bank(n_docs=0, fragment_chars=F, pattern_chars=P,
                     n_patterns=1, sig_words=8, survivor_frac=1.0)


# -- TTL ----------------------------------------------------------------------

def test_ttl_expiry():
    clock = [0.0]
    _, docs = make_docs(seed=3)
    bank = PatternBank(F, P, capacity=4, clock=lambda: clock[0])
    planted = docs[2, 5:5 + P].copy()
    a = bank.register(planted, threshold=P, ttl_s=10.0)
    b = bank.register(planted, threshold=P)           # immortal twin
    clock[0] = 5.0
    t = bank.scan(docs)
    assert {a, b} <= set(int(x) for x in t.hits[:, 2])
    clock[0] = 10.0
    assert bank.expire() == [a]
    t = bank.scan(docs)
    hit_ids = set(int(x) for x in t.hits[:, 2])
    assert b in hit_ids and a not in hit_ids
    assert bank.n_expired == 1 and bank.n_live == 1


# -- windowed corpus (tombstones + compaction) --------------------------------

def window_pair(n=40, window=24, seed=21):
    """(windowed corpus engine, from-scratch engine over the live rows)."""
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (n, F), np.uint8)
    corpus = PackedCorpus(frags)
    corpus.tombstone(np.arange(n - window))
    fresh = PackedCorpus(frags[n - window:])
    return rng, corpus, MatchEngine(corpus), MatchEngine(fresh), n - window


@pytest.mark.parametrize("reduction", ["threshold", "topk", "full", "best"])
def test_tombstones_match_fresh_window(reduction):
    rng, corpus, eng, fresh_eng, shift = window_pair()
    pat = np.array(corpus.fragments[corpus.live_row_ids()[3]][7:7 + P])
    kw = (dict(threshold=P - 4) if reduction == "threshold"
          else dict(k=5) if reduction == "topk" else {})
    res = eng.match(pat, reduction=reduction, **kw)
    ref = fresh_eng.match(pat, reduction=reduction, **kw)
    if reduction == "threshold":
        moved = ref.hits.copy()
        if moved.size:
            moved[:, 0] += shift
        assert np.array_equal(res.hits, moved)
    elif reduction == "topk":
        assert np.array_equal(res.topk_rows, ref.topk_rows + shift)
        assert np.array_equal(res.topk_scores, ref.topk_scores)
    elif reduction == "full":
        live = res.scores[shift:]
        assert np.array_equal(live, ref.scores)
        assert (res.scores[:shift] == -1).all()      # dead-row sentinel
    else:
        assert np.array_equal(res.best_scores[shift:], ref.best_scores)
        assert (res.best_scores[:shift] == -1).all()


def test_tombstone_validates_and_counts():
    _, corpus, *_ = window_pair()
    n = corpus.n_rows
    assert corpus.n_live == 24 and corpus.n_dead == n - 24
    assert corpus.tombstone(np.array([0])) == 0       # already dead: no-op
    gen = corpus.generation
    assert corpus.tombstone(np.zeros(0, np.int64)) == 0
    assert corpus.generation == gen                   # no-op: no bump
    with pytest.raises(ValueError):
        corpus.tombstone(np.array([n]))


def test_compaction_preserves_results_with_flat_packs():
    rng, corpus, eng, fresh_eng, shift = window_pair()
    # Copy: compact() rewrites the fragment buffer this view aliases.
    pat = np.array(corpus.fragments[corpus.live_row_ids()[0]][11:11 + P])
    eng.match(pat)                                    # pack the forms
    packs = corpus.swar_pack_count
    freed = corpus.compact()
    assert freed == shift and corpus.n_dead == 0
    assert corpus.n_rows == corpus.n_live == 24
    assert corpus.swar_pack_count == packs            # splice, not repack
    res = eng.match(pat, reduction="threshold", threshold=P - 4)
    ref = fresh_eng.match(pat, reduction="threshold", threshold=P - 4)
    assert np.array_equal(res.hits, ref.hits)         # rows now align


def test_compiled_rows_subset_stale_after_compact():
    _, corpus, eng, _, _ = window_pair()
    q = MatchQuery.exact(np.array(corpus.fragments[30][:P]),
                         rows=np.arange(30, 40))
    cm = eng.compile(q)
    cm.run()
    corpus.compact()                                  # n_rows shrinks to 24
    with pytest.raises(IndexError):
        cm.run()


def test_filtered_query_skips_tombstoned_rows():
    rng = np.random.default_rng(23)
    frags = rng.integers(0, 4, (48, F), np.uint8)
    pat = frags[5, 9:9 + P].copy()
    frags[40, 9:9 + P] = pat                          # live twin
    eng = MatchEngine(PackedCorpus(frags))
    eng.corpus.tombstone(np.array([5]))
    res = eng.match(MatchQuery.exact(pat, reduction="threshold",
                                     threshold=P, filter=True))
    rows = set(int(r) for r in res.hits[:, 0])
    assert 40 in rows and 5 not in rows


# -- service integration ------------------------------------------------------

def make_service(seed=31, window=None, bank_kw=None, **kw):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (24, F), np.uint8)
    eng = MatchEngine(PackedCorpus(frags, capacity=256))
    bank = PatternBank(F, P, capacity=8, **(bank_kw or {}))
    svc = MatchService(eng, bank=bank, window_rows=window, **kw)
    return rng, eng, bank, svc


def test_empty_ingest_is_noop():
    rng, eng, bank, svc = make_service()
    gen = eng.corpus.generation
    # Seed the result cache, then prove the empty ingest doesn't drop it.
    q = MatchQuery.exact(rng.integers(0, 4, P, np.uint8))
    svc.submit(q).wait()
    t = svc.ingest(np.zeros((0, F), np.uint8))
    assert t.done and t.n == 0 and t.start == eng.corpus.n_rows
    svc.tick()
    assert eng.corpus.generation == gen
    assert svc.stats.n_ingest_batches == 0
    assert svc.stats.n_bank_launches == 0
    tk = svc.submit(q)
    svc.tick()
    assert tk.cached                                   # cache survived


def test_ingest_scans_bank_once_before_splice():
    rng, eng, bank, svc = make_service()
    docs = rng.integers(0, 4, (10, F), np.uint8)
    got = []
    pid = bank.register(
        docs[4, 20:20 + P].copy(), threshold=P,
        on_hit=lambda p, h: got.append((p, eng.corpus.n_rows)))
    base = eng.corpus.n_rows
    t1 = svc.ingest(docs[:6])
    t2 = svc.ingest(docs[6:])
    before = svc.stats.n_bank_launches
    svc.tick()
    # One fused launch covered both same-tick submissions...
    assert svc.stats.n_bank_launches - before == 1
    # ...and fired before the rows spliced in.
    assert got and got[0][1] == base
    bt = t1.bank_ticket
    assert bt is t2.bank_ticket and bt.base_row == base
    assert (bt.corpus_rows == base + bt.hits[:, 0]).all()
    assert {(4, 20)} <= {(int(h[0]), int(h[1])) for h in bt.hits}
    assert svc.stats.n_bank_hits == bt.hits.shape[0]
    snap = svc.stats.snapshot()
    assert snap["n_bank_launches"] == 1
    assert snap["bank"]["hits_by_pattern"][pid] >= 1


def test_service_ttl_expires_before_scan():
    clock = [0.0]
    rng, eng, bank, svc = make_service(
        bank_kw=dict(clock=lambda: clock[0]))
    docs = rng.integers(0, 4, (4, F), np.uint8)
    pid = bank.register(docs[0, 3:3 + P].copy(), threshold=P, ttl_s=1.0)
    clock[0] = 2.0
    t = svc.ingest(docs)
    svc.tick()
    assert bank.n_live == 0 and bank.n_expired == 1
    assert t.bank_ticket.hits.shape[0] == 0


def test_sliding_window_eviction_end_to_end():
    rng, eng, bank, svc = make_service(window=30, compact_dead_frac=0.3)
    for _ in range(5):
        svc.ingest(rng.integers(0, 4, (8, F), np.uint8))
        svc.tick()
    corpus = eng.corpus
    assert corpus.n_live == 30
    assert svc.stats.n_evicted_rows == 24 + 5 * 8 - 30
    assert svc.stats.n_compactions == corpus.n_compactions > 0
    # The window holds exactly the newest 30 rows, query-visible.
    planted = np.array(corpus.fragments[corpus.live_row_ids()[-1]])
    res = svc.match(MatchQuery.exact(planted[:P], reduction="threshold",
                                     threshold=P))
    assert res.hits.shape[0] >= 1
