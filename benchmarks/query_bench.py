"""Compiled-query bench: compile-once reuse vs. per-call lowering, and
exact vs. wildcard predicate throughput (DESIGN.md Sec. 3e).

Two comparisons on one resident corpus:

* **compiled vs. uncompiled warm path.**  The uncompiled loop is what
  every caller paid before the query IR existed -- per call: build the
  query, plan (kernel + geometry), pack the pattern operands, then run.
  The compiled loop lowers once (``MatchEngine.compile``) and calls
  ``CompiledMatch.run()``, which streams the resident corpus with zero
  per-call host work.  Results are asserted bit-identical before timing.
* **exact vs. wildcard.**  The same pattern with N-wildcard positions as
  an accept-mask predicate, through the bit-plane SWAR kernel -- the cost
  of opening the approximate-matching scenario family on the VPU path
  (the MXU path prices wildcards at zero; see the planner).

Both paths run the SWAR kernel (``backend="swar"``): on this CPU container
the Pallas kernels execute via the interpreter, where MXU bf16 matmuls are
emulated and their timings are meaningless (see ``kernel_bench``); holding
the kernel fixed makes the comparison measure exactly the query layer.

Emits ``BENCH_match_query.json`` at the repo root and exits nonzero if the
record is malformed.  CI runs ``--smoke`` as a schema guard: same pipeline
and validation on a reduced shape, without overwriting the committed
full-run artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_query.json"

FULL = dict(R=48, F=256, P=32, iters=8, repeats=5)
SMOKE = dict(R=16, F=128, P=16, iters=2, repeats=1)
BACKEND = "swar"
N_WILDCARDS = 4

REQUIRED_KEYS = ("shape", "kernel_backend", "device_kind", "backend",
                 "calibration", "n_processes", "n_hosts", "interpret",
                 "smoke", "results")
REQUIRED_RESULT_KEYS = ("predicate", "uncompiled_us", "compiled_us",
                        "speedup", "identical", "oracle_ok")


def _mk_query(masks, exact_codes):
    from repro.match import MatchQuery

    if exact_codes is not None:
        return MatchQuery.exact(exact_codes, reduction="best",
                                backend=BACKEND)
    return MatchQuery.from_masks(masks, reduction="best", backend=BACKEND)


def bench_predicate(eng, predicate: str, P: int, rng, iters: int,
                    repeats: int) -> dict:
    from repro.core.matcher import sliding_scores_masks

    codes = rng.integers(0, 4, P, np.uint8)
    masks = (np.uint8(1) << codes).astype(np.uint8)
    exact_codes = codes if predicate == "exact" else None
    if predicate == "wildcard":
        masks[rng.integers(0, P, N_WILDCARDS)] = 0b1111

    # Warm the jit cache at the exact shapes to be timed.
    warm = eng.compile(_mk_query(masks, exact_codes), cached=False)
    warm.run()

    t_unc = t_cmp = float("inf")
    # Best-of-N per path: this container's CPU timings are noisy; the
    # minimum is the least-contended observation of the same work.
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            # Per-call lowering: query build + plan + pack + run.
            res_unc = eng.compile(_mk_query(masks, exact_codes),
                                  cached=False).run()
        t_unc = min(t_unc, (time.perf_counter() - t0) / iters)

        cm = eng.compile(_mk_query(masks, exact_codes), cached=False)
        t0 = time.perf_counter()
        for _ in range(iters):
            res_cmp = cm.run()
        t_cmp = min(t_cmp, (time.perf_counter() - t0) / iters)

    identical = (np.array_equal(res_unc.best_scores, res_cmp.best_scores)
                 and np.array_equal(res_unc.best_locs, res_cmp.best_locs))
    oracle = sliding_scores_masks(eng.corpus.fragments, masks)
    oracle_ok = bool(
        np.array_equal(res_cmp.best_scores, oracle.max(1))
        and np.array_equal(res_cmp.best_locs, oracle.argmax(1)))
    return {
        "predicate": predicate,
        "uncompiled_us": round(t_unc * 1e6, 1),
        "compiled_us": round(t_cmp * 1e6, 1),
        "speedup": round(t_unc / t_cmp, 3),
        "identical": bool(identical),
        "oracle_ok": oracle_ok,
        "plan_backend": res_cmp.plan.backend,
        "plan_predicate": res_cmp.plan.predicate,
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if not record["results"]:
        raise ValueError("BENCH record has no results")
    preds = set()
    for row in record["results"]:
        for key in REQUIRED_RESULT_KEYS:
            if key not in row:
                raise ValueError(f"result row missing key {key!r}: {row}")
        if not row["identical"]:
            raise ValueError(f"{row['predicate']}: compiled results "
                             "diverged from per-call lowering")
        if not row["oracle_ok"]:
            raise ValueError(f"{row['predicate']}: results diverged from "
                             "the NumPy accept-mask oracle")
        if row["uncompiled_us"] <= 0 or row["compiled_us"] <= 0:
            raise ValueError(f"{row['predicate']}: non-positive timing")
        preds.add(row["predicate"])
    if preds != {"exact", "wildcard"}:
        raise ValueError(f"expected exact+wildcard rows, got {preds}")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.match import MatchEngine

    cfg = SMOKE if smoke else FULL
    R, F, P = cfg["R"], cfg["F"], cfg["P"]
    rng = np.random.default_rng(11)
    eng = MatchEngine(rng.integers(0, 4, (R, F), np.uint8))
    results = [bench_predicate(eng, pred, P, rng, cfg["iters"],
                               cfg["repeats"])
               for pred in ("exact", "wildcard")]
    by_pred = {r["predicate"]: r for r in results}
    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {"R": R, "F": F, "P": P},
        "kernel_backend": BACKEND,
        **bench_provenance(eng.planner.cost_source),
        "interpret": eng.interpret,
        "smoke": smoke,
        "results": results,
        "wildcard_over_exact_compiled": round(
            by_pred["wildcard"]["compiled_us"]
            / by_pred["exact"]["compiled_us"], 3),
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with reduced shapes.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    return [
        (f"query/compiled_{row['predicate']}", row["compiled_us"],
         f"uncompiled_us={row['uncompiled_us']} "
         f"speedup={row['speedup']}x identical={row['identical']} "
         f"oracle_ok={row['oracle_ok']}")
        for row in record["results"]
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cases = " ".join(f"{r['predicate']}:compiled_us={r['compiled_us']}:"
                     f"speedup={r['speedup']}x" for r in rec["results"])
    return (f"{BENCH_JSON.name} wildcard_over_exact="
            f"{rec['wildcard_over_exact_compiled']} {cases}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shape (CI schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for row in record["results"]:
        print(f"{row['predicate']:>9}  uncompiled={row['uncompiled_us']:>9.1f}us"
              f"  compiled={row['compiled_us']:>9.1f}us"
              f"  speedup={row['speedup']:.3f}x"
              f"  identical={row['identical']} oracle_ok={row['oracle_ok']}")
    print(f"wildcard/exact compiled cost: "
          f"{record['wildcard_over_exact_compiled']}x")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
