"""Speculative decoding tests: exact greedy equivalence + speedup counting.

The CRAM-PM n-gram proposer + batched verification must produce *exactly*
the greedy sequence (speculation only changes how many model calls it
takes), and repetitive streams must verify with fewer calls than tokens.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import model
from repro.serving.engine import generate_greedy
from repro.serving.speculative import SpeculativeDecoder

CFG = get_config("llama3.2-1b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


class TestSpeculativeDecoding:
    def test_exact_greedy_equivalence(self, params):
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, CFG.vocab, 8, dtype=np.int32)
        ref = generate_greedy(CFG, params, prompt[None], max_new=20,
                              max_seq=96)[0]
        dec = SpeculativeDecoder(CFG, params, max_seq=96, k=3)
        out, stats = dec.generate(prompt, max_new=20)
        np.testing.assert_array_equal(out, ref)

    def test_fewer_calls_on_repetitive_stream(self, params):
        """Greedy generation converges to a loop; once the history repeats,
        n-gram proposals verify and calls/token drops below 1."""
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, CFG.vocab, 8, dtype=np.int32)
        dec = SpeculativeDecoder(CFG, params, max_seq=160, k=3)
        out, stats = dec.generate(prompt, max_new=48)
        assert stats.tokens_out == 48
        assert stats.tokens_per_call > 1.0, (
            f"calls={stats.model_calls} tokens={stats.tokens_out} "
            f"acceptance={stats.acceptance:.2f}")

    def test_chunked_continuation_attention(self, params):
        """The verify path (forward at cache offset) must equal token-by-
        token decoding for the same window."""
        import jax.numpy as jnp
        rng = np.random.default_rng(2)
        S_pre, W = 10, 4
        tokens = jnp.asarray(rng.integers(0, CFG.vocab, (1, S_pre + W)))
        full, _, _ = model.forward(CFG, params, {"tokens": tokens})
        caches = model.init_cache(CFG, 1, 64)
        _, caches = model.prefill(
            CFG, params, {"tokens": tokens[:, :S_pre]}, caches)
        logits, _, _ = model.forward(
            CFG, params, {"tokens": tokens[:, S_pre:]}, mode="full",
            caches=caches, cache_index=S_pre)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, S_pre:]),
                                   rtol=3e-2, atol=3e-2)
