"""Training loop: metrics, step watchdog (straggler mitigation),
preemption-safe checkpointing, auto-resume.

Straggler policy (DESIGN.md Sec. 5): step wall-times feed a rolling median;
a step exceeding ``watchdog_factor x median`` raises a StragglerEvent which
the loop handles by (a) recording it, (b) forcing a non-blocking checkpoint
so a drop-and-reshard restart loses no work.  On a real cluster the event
hooks the coordinator's reconfiguration path; the policy and its trigger
are exercised by tests/test_runtime.py with an injected delay.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import steps


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median_time: float


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    step_times: List[float]
    straggler_events: List[StragglerEvent]
    final_step: int


def train(cfg: ModelConfig, opt_cfg: adamw.OptConfig, data, n_steps: int,
          *, ckpt: Optional[CheckpointManager] = None,
          ckpt_every: int = 50, log_every: int = 10,
          watchdog_factor: float = 5.0,
          rng_seed: int = 0,
          step_hook: Optional[Callable[[int], None]] = None,
          log: Callable[[str], None] = print) -> TrainResult:
    """Single-process training driver (examples + integration tests).

    Auto-resumes from the newest checkpoint in ``ckpt`` if one exists.
    ``step_hook`` is a test seam (e.g. to inject a straggler delay).
    """
    params = model.init_params(cfg, jax.random.PRNGKey(rng_seed))
    opt_state = adamw.init(params)
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        (params, opt_state), start_step = ckpt.restore((params, opt_state))
        log(f"[resume] restored checkpoint at step {start_step}")

    train_step = jax.jit(steps.make_train_step(cfg, opt_cfg),
                         donate_argnums=(0, 1))

    losses: List[float] = []
    times: List[float] = []
    events: List[StragglerEvent] = []
    step = start_step
    for step in range(start_step, n_steps):
        batch = {k: jax.numpy.asarray(v)
                 for k, v in data.batch_at(step).items()}
        t0 = time.perf_counter()
        if step_hook is not None:
            step_hook(step)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        times.append(dt)
        if len(times) >= 5:
            med = float(np.median(times[-50:]))
            if dt > watchdog_factor * med:
                ev = StragglerEvent(step, dt, med)
                events.append(ev)
                log(f"[watchdog] step {step} took {dt:.3f}s "
                    f"(median {med:.3f}s) -- snapshotting for reshard")
                if ckpt is not None:
                    ckpt.save(step + 1, (params, opt_state))
        if log_every and step % log_every == 0:
            log(f"step {step:5d}  loss {loss:.4f}  {dt*1e3:7.1f} ms"
                f"  lr {float(metrics['lr']):.2e}")
        if ckpt is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt is not None:
        ckpt.save(n_steps, (params, opt_state), blocking=True)
    return TrainResult(losses, times, events, step + 1)
