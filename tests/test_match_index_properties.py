"""Randomized no-false-negative property for the q-gram filter
(hypothesis-driven; DESIGN.md Sec. 3g).

Split out behind ``importorskip`` so a missing ``hypothesis`` install
skips only this module (repo convention, see
``test_kernels_properties.py``).

Property: for ANY corpus, ANY accept-mask pattern (random wildcard mix),
ANY threshold, filtered threshold execution is bit-identical to the full
scan -- the filter may only remove rows that provably cannot hit.  The
conservativeness argument (q-gram lemma + per-mismatch damage bound +
absent-bit witness) has to survive adversarial inputs: patterns shorter
than q, unsatisfiable thresholds, thresholds of zero, periodic patterns
whose q-grams all collide, corpora containing the pattern many times.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.match import MatchEngine, MatchQuery  # noqa: E402


def random_masks(rng, p, wild_frac):
    codes = rng.integers(0, 4, p, np.uint8)
    masks = (np.uint8(1) << codes).astype(np.uint8)
    wild = rng.random(p) < wild_frac
    masks[wild] = rng.integers(1, 16, int(wild.sum()), np.uint8)
    return masks


class TestFilterProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 24), st.integers(8, 48), st.data())
    def test_property_filtered_equals_full_scan(self, r, f, data):
        p = data.draw(st.integers(1, f))
        thr = data.draw(st.integers(0, p + 1))
        wild = data.draw(st.sampled_from([0.0, 0.2, 0.6]))
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (r, f), np.uint8)
        masks = random_masks(rng, p, wild)
        if data.draw(st.booleans()):
            # Plant a window every mask position accepts (lowest accepted
            # code per position), so true positives exist -- the filtered
            # path is then exercised on real hits, not just empty sets.
            lowest = np.array([0, 0, 1, 0, 2, 0, 1, 0,
                               3, 0, 1, 0, 2, 0, 1, 0], np.uint8)
            row, off = rng.integers(0, r), rng.integers(0, f - p + 1)
            frags[row, off:off + p] = lowest[masks]
        eng = MatchEngine(frags)
        fil = eng.match(MatchQuery.from_masks(
            masks, reduction="threshold", threshold=float(thr),
            filter=True, backend="ref"))
        scan = eng.match(MatchQuery.from_masks(
            masks, reduction="threshold", threshold=float(thr),
            filter=False, backend="ref"))
        np.testing.assert_array_equal(fil.hits, scan.hits)
        if fil.survivor_frac is not None and fil.hits.size:
            assert set(fil.hits[:, 0]) <= set(fil.survivor_rows.tolist())

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_planted_needle_always_found(self, seed):
        """A row containing the pattern always survives an exact-threshold
        filter and produces its hit (direct no-false-negative witness)."""
        rng = np.random.default_rng(seed)
        r, f = int(rng.integers(4, 32)), int(rng.integers(24, 64))
        p = int(rng.integers(4, min(f, 20)))
        frags = rng.integers(0, 4, (r, f), np.uint8)
        pat = rng.integers(0, 4, p, np.uint8)
        row, off = int(rng.integers(0, r)), int(rng.integers(0, f - p + 1))
        frags[row, off:off + p] = pat
        eng = MatchEngine(frags)
        res = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=float(p), filter=True,
            backend="ref"))
        assert ((res.hits[:, 0] == row) & (res.hits[:, 1] == off)).any()
        assert row in set(res.survivor_rows.tolist())

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_filtered_equals_scan_after_growth(self, seed):
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (8, 40), np.uint8)
        pat = rng.integers(0, 4, 10, np.uint8)
        eng = MatchEngine(frags)
        q_fil = MatchQuery.exact(pat, reduction="threshold", threshold=9.0,
                                 filter=True, backend="ref")
        q_scan = MatchQuery.exact(pat, reduction="threshold", threshold=9.0,
                                  filter=False, backend="ref")
        cm = eng.compile(q_fil)
        cm.run()
        new = rng.integers(0, 4, (3, 40), np.uint8)
        new[1, 11:21] = pat
        eng.corpus.append_rows(new)
        np.testing.assert_array_equal(cm.run().hits,
                                      eng.match(q_scan).hits)
