"""LM model stack: config, param specs, layers, and assembly."""

from . import config, layers, model, rglru, spec, ssm
from .config import SHAPES, InputShape, ModelConfig, shape_applicable

__all__ = ["config", "layers", "model", "rglru", "spec", "ssm",
           "SHAPES", "InputShape", "ModelConfig", "shape_applicable"]
