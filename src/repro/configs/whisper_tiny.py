"""whisper-tiny [audio]: encoder-decoder, conv frontend STUB.

[arXiv:2212.04356; unverified]  4L enc + 4L dec, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865 (padded to 51872 for TP), head_dim=64, sinusoidal
positions (rope disabled), GELU, LayerNorm, QKV bias.  ``input_specs``
supplies precomputed mel-frame embeddings (1500 frames).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51_865,
    n_enc_layers=4, n_audio_frames=1500,
    rope_theta=0.0, act="gelu", norm="layer", qkv_bias=True,
    tie_embeddings=True,
    tp_pad=1,
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    n_enc_layers=2, n_audio_frames=32,
    rope_theta=0.0, act="gelu", norm="layer", qkv_bias=True,
    tie_embeddings=True,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
