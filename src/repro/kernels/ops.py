"""Thin compat wrappers over the match engine + bulk-bitwise kernels.

``match_scores`` is a one-shot shim over ``repro.match`` kept for callers
that match once against a throwaway fragment set (tests, examples).  All
host-side packing, padding and kernel selection lives in the engine layer
(``repro.match``: PackedCorpus / Planner / MatchEngine); long-lived
consumers hold a ``MatchEngine`` so the corpus stays device-resident
across queries instead of being repacked per call.

``popcount`` and ``bitwise`` remain direct kernel wrappers (their operands
are query data, not a resident corpus).  ``interpret`` defaults to True off
TPU (kernel bodies execute via the Pallas interpreter, which is how this
CPU container validates them); on TPU it compiles to Mosaic.
"""

from __future__ import annotations

import warnings

from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import bitwise as _bitwise
from . import popcount as _popcount


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: np.ndarray, mult: int) -> np.ndarray:
    r = (-x.shape[0]) % mult
    if r:
        x = np.concatenate([x, np.zeros((r,) + x.shape[1:], x.dtype)], 0)
    return x


def match_scores(fragments: np.ndarray, patterns,
                 method: Optional[Literal["swar", "mxu", "ref"]] = None,
                 interpret: bool | None = None, *,
                 backend: Optional[str] = None) -> np.ndarray:
    """Similarity scores for all alignments (Algorithm 1 fast path).

    fragments: (R, F) uint8 codes.  patterns: (P,) shared, (R, P) per-row,
    or (Q, P) batched (-> (R, L, Q)) uint8 codes -- or a
    ``repro.match.MatchQuery`` (whose reduction is forced to "full"),
    which is how wildcard / IUPAC predicates reach this shim.  Returns
    (R, L) int32 or (R, L, Q) int32, L = F - P + 1.

    ``backend=None`` lets the planner pick the kernel from the workload
    shape; pass an explicit name to override (``method=`` is the
    deprecated spelling).  One-shot path: packs the fragments for this
    call only -- hold a ``repro.match.MatchEngine`` to amortize packing
    across queries.
    """
    from repro.match import MatchEngine

    if method is not None:
        warnings.warn("ops.match_scores(method=...) is deprecated; pass "
                      "backend=... or compile a MatchQuery",
                      DeprecationWarning, stacklevel=2)
        if backend is None:
            backend = method

    eng = MatchEngine(np.asarray(fragments, np.uint8), interpret=interpret)
    # The streaming executor materializes on host; hand that array back
    # rather than re-uploading (every caller consumes it as numpy).
    kw = {} if backend is None else {"backend": backend}
    return eng.scores(patterns if hasattr(patterns, "masks_b")
                      else np.asarray(patterns, np.uint8), **kw)


def popcount(words: np.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """(N, W) uint32 -> (N,) int32."""
    if interpret is None:
        interpret = default_interpret()
    words = np.asarray(words, np.uint32)
    N = words.shape[0]
    padded = _pad_rows(words, _popcount.N_TILE)
    out = _popcount.popcount(jnp.asarray(padded), interpret=interpret)
    return out[:N, 0]


def bitwise(op: str, a: np.ndarray, b: np.ndarray | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """Bulk bitwise op over (N, W) uint32 operands."""
    if interpret is None:
        interpret = default_interpret()
    a = np.asarray(a, np.uint32)
    N = a.shape[0]
    ap = _pad_rows(a, _bitwise.N_TILE)
    bp = ap if b is None else _pad_rows(np.asarray(b, np.uint32), _bitwise.N_TILE)
    out = _bitwise.bitwise(op, jnp.asarray(ap), jnp.asarray(bp),
                           interpret=interpret)
    return out[:N]
