"""Kernel micro-bench (beyond paper): the TPU-adapted matching engine.

On this CPU container the Pallas kernels execute via the interpreter (not
meaningful to time), so we wall-clock the jnp packed SWAR mirror (identical
math, XLA-compiled for CPU) and derive the TPU v5e roofline projection for
both kernels from their exact op/byte counts.  The projection is compared
against the CRAM-PM substrate's match rate from the paper cost model --
the adaptation target the hillclimb in EXPERIMENTS §Perf works against.

The end-to-end engine bench (cold pack + first query vs. warm repeated
queries on the resident corpus) runs the real ``repro.match`` stack and
emits ``BENCH_match_engine.json`` at the repo root so later PRs have a
perf trajectory; it also asserts the steady-state no-repacking invariant.
"""

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import encoding
from repro.core.tech import NEAR_TERM, TPU_V5E
from repro.kernels import ref as kref

R, F, P = 512, 1024, 100

# Engine end-to-end shape: sized so interpret-mode Pallas stays sub-second
# per query while still exercising chunked streaming (2 chunks).
ER, EF, EP, EQUERIES = 64, 512, 96, 5
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_engine.json"

REQUIRED_KEYS = ("shape", "device_kind", "backend", "calibration",
                 "n_processes", "n_hosts", "interpret", "cold_s",
                 "warm_s_per_query", "warm_rows_per_s", "cold_over_warm",
                 "host_pack_count", "auto_backend", "planner_est_s")


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if record["host_pack_count"] != 1:
        raise ValueError("corpus repacked on warm query "
                         f"({record['host_pack_count']} packs)")
    if record["cold_s"] <= 0 or record["warm_s_per_query"] <= 0:
        raise ValueError("non-positive timing in BENCH record")
    json.loads(json.dumps(record))      # round-trips as JSON


def _setup():
    rng = np.random.default_rng(0)
    frags = rng.integers(0, 4, (R, F), np.uint8)
    pat = rng.integers(0, 4, P, np.uint8)
    L = F - P + 1
    wp = -(-P // 16)
    rw = encoding.pack_codes_u32(frags)
    need = (L - 1) // 16 + wp + 1
    rw = np.concatenate([rw, np.zeros((R, need - rw.shape[1]), np.uint32)], 1)
    pw = encoding.pack_codes_u32(np.broadcast_to(pat, (R, P)))
    mask_codes = np.zeros(wp * 16, np.uint32)
    mask_codes[:P] = 1
    mask = encoding.pack_codes_u32(mask_codes[None, :])[0]
    return rw, pw, mask, L


def bench_engine(smoke: bool = False):
    """Cold-pack vs. warm repeated-query path through the real engine."""
    from repro.match import MatchEngine
    from repro.match.calibrate import bench_provenance

    rng = np.random.default_rng(42)
    frags = rng.integers(0, 4, (ER, EF), np.uint8)
    pats = [rng.integers(0, 4, EP, np.uint8) for _ in range(EQUERIES)]

    eng = MatchEngine(frags)
    chunk = ER // 2                       # force streaming (2 chunks)
    t0 = time.perf_counter()
    res = eng.match(pats[0], backend="swar", reduction="best",
                    chunk_rows=chunk)
    cold_s = time.perf_counter() - t0
    assert eng.corpus.host_pack_count == 1

    t0 = time.perf_counter()
    for p in pats[1:]:
        res = eng.match(p, backend="swar", reduction="best",
                        chunk_rows=chunk)
    warm_s = (time.perf_counter() - t0) / (EQUERIES - 1)
    # Steady state: the corpus was packed exactly once, ever.
    assert eng.corpus.host_pack_count == 1, "corpus repacked on warm query"

    plan = eng.plan(pats[0])
    record = {
        "shape": {"R": ER, "F": EF, "P": EP, "chunk_rows": chunk,
                  "n_chunks": res.n_chunks},
        **bench_provenance(eng.planner.cost_source),
        "cold_s": round(cold_s, 6),
        "warm_s_per_query": round(warm_s, 6),
        "warm_rows_per_s": round(ER / warm_s, 1),
        "cold_over_warm": round(cold_s / warm_s, 2),
        "host_pack_count": eng.corpus.host_pack_count,
        "auto_backend": plan.backend,
        "planner_est_s": plan.est_seconds,
        "interpret": eng.interpret,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run():
    import jax
    rw, pw, mask, L = _setup()
    f = jax.jit(lambda a, b: kref.match_scores_swar_ref(
        a, b, mask, n_locs=L, pattern_chars=P))
    out = f(rw, pw)
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        f(rw, pw).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    rows_per_s = R / dt

    # TPU roofline projection of the SWAR kernel: per (row, loc): ~Wp words
    # x ~12 integer ops; ref tile read once per pattern block.
    wp = pw.shape[1]
    ops = R * L * wp * 12
    bytes_hbm = rw.nbytes + out.nbytes + pw.nbytes
    t_compute = ops / (TPU_V5E.peak_bf16_flops / 2)      # int ops ~ half rate
    t_mem = bytes_hbm / TPU_V5E.hbm_bw
    t_tpu = max(t_compute, t_mem)
    tpu_rows_per_s = R / t_tpu

    # MXU one-hot correlation projection: per (row, loc-tile, k-chunk) one
    # (256 x 128) @ (128 x Q) matmul; Q=128 patterns amortize the ref read.
    Q = 128
    n_chunks = -(-P // 32)
    mxu_flops = R * L * (n_chunks * 128) * 2 * Q         # 2*K*out per dot
    mxu_bytes = (R * (F + P) * 4 * 2                     # one-hot ref bf16
                 + n_chunks * 128 * Q * 2 + R * L * Q * 4)
    t_c = mxu_flops / TPU_V5E.peak_bf16_flops
    t_m = mxu_bytes / TPU_V5E.hbm_bw
    mxu_rows_per_s = R * Q / max(t_c, t_m)               # row-pattern pairs/s

    # CRAM-PM substrate: one array, OracularOpt: rows/s = n_rows/pass_time.
    d = cm.Design(tech=NEAR_TERM, opt=True, n_arrays=1)
    pc = cm.pass_cost(d)
    cram_rows_per_s = d.n_rows / pc.latency_s

    er = bench_engine()
    return [
        ("engine/cold_pack_query", round(er["cold_s"] * 1e6, 1),
         f"R={ER} F={EF} P={EP} chunks={er['shape']['n_chunks']}"
         f" backend=swar (pack + first query)"),
        ("engine/warm_query", round(er["warm_s_per_query"] * 1e6, 1),
         f"rows_per_s={er['warm_rows_per_s']:.4g}"
         f" cold/warm={er['cold_over_warm']}x"
         f" host_packs={er['host_pack_count']} (resident corpus)"),
        ("kernel/swar_cpu", round(dt / R * 1e6, 3),
         f"rows_per_s={rows_per_s:.4g} (CPU jnp mirror, R={R} F={F} P={P})"),
        ("kernel/swar_tpu_projection", 0.0,
         f"rows_per_s={tpu_rows_per_s:.4g}"
         f" intensity={ops/bytes_hbm:.1f}op/B"
         f" bound={'compute' if t_compute > t_mem else 'memory'}"),
        ("kernel/mxu_tpu_projection", 0.0,
         f"row_pattern_pairs_per_s={mxu_rows_per_s:.4g} (Q={Q} batched)"
         f" bound={'compute' if t_c > t_m else 'memory'}"),
        ("kernel/crampm_substrate", 0.0,
         f"rows_per_s={cram_rows_per_s:.4g} (near-term OracularOpt array)"),
        ("kernel/tpu_vs_crampm", 0.0,
         f"swar={tpu_rows_per_s/cram_rows_per_s:.3g}x"
         f" mxu={mxu_rows_per_s/cram_rows_per_s:.3g}x"
         " per chip vs per array"),
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    return (f"{BENCH_JSON.name} warm_rows_per_s={rec['warm_rows_per_s']} "
            f"cold_over_warm={rec['cold_over_warm']}x "
            f"backend={rec['auto_backend']} "
            f"host_packs={rec['host_pack_count']}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="validate the record without rewriting the "
                         "committed artifact (CI schema guard)")
    args = ap.parse_args()
    try:
        record = bench_engine(smoke=args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    print(f"cold={record['cold_s']*1e3:.1f}ms "
          f"warm={record['warm_s_per_query']*1e3:.1f}ms/query "
          f"cold/warm={record['cold_over_warm']}x "
          f"auto_backend={record['auto_backend']} "
          f"calibration={record['calibration']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
