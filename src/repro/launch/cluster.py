"""Multi-host cluster bootstrap (1000+ node path).

On a real TPU/TRN fleet every host runs the same entry point; this module
derives (coordinator, process_id, process_count) from the scheduler
environment (TPU metadata, SLURM, or explicit REPRO_* variables), calls
``jax.distributed.initialize``, and returns the host's role.  The rest of
the stack is already multi-host-clean:

* ``make_production_mesh`` builds from ``jax.devices()`` (global after
  initialize);
* ``data.pipeline.host_shard`` slices the deterministic batch stream by
  (process_id, process_count) -- restarts replay identically on any host
  count;
* ``checkpoint.CheckpointManager`` restores onto any mesh (elastic), so a
  job rescheduled from 2 pods to 1 resumes from the same step;
* the straggler watchdog (runtime/loop.py) triggers the snapshot +
  drop-and-reshard path on slow hosts.

Typical driver::

    from repro.launch import cluster
    info = cluster.initialize()           # no-op on a single host
    mesh = make_production_mesh(multi_pod=info.process_count > 1)
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HostInfo:
    coordinator: Optional[str]
    process_id: int
    process_count: int

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def detect_environment(env=None) -> HostInfo:
    """Resolve the host's role from the environment (no side effects).

    Priority: explicit REPRO_* vars > SLURM > single host.
    """
    env = env if env is not None else os.environ
    if "REPRO_COORDINATOR" in env:
        return HostInfo(
            coordinator=env["REPRO_COORDINATOR"],
            process_id=int(env.get("REPRO_PROCESS_ID", "0")),
            process_count=int(env.get("REPRO_NUM_PROCESSES", "1")),
        )
    if "SLURM_JOB_NUM_NODES" in env and int(env["SLURM_JOB_NUM_NODES"]) > 1:
        nodelist = env.get("SLURM_STEP_NODELIST", env.get("SLURM_NODELIST", ""))
        first = _first_slurm_node(nodelist)
        port = env.get("REPRO_PORT", "8476")
        return HostInfo(
            coordinator=f"{first}:{port}" if first else None,
            process_id=int(env.get("SLURM_PROCID", "0")),
            process_count=int(env["SLURM_JOB_NUM_NODES"]),
        )
    return HostInfo(coordinator=None, process_id=0, process_count=1)


def _first_slurm_node(nodelist: str) -> Optional[str]:
    """First hostname of a SLURM nodelist ('a[001-004],b02' -> 'a001')."""
    if not nodelist:
        return None
    head = nodelist.split(",")[0]
    if "[" not in head:
        return head
    prefix, rng = head.split("[", 1)
    rng = rng.rstrip("]")
    first = rng.split(",")[0].split("-")[0]
    return prefix + first


def initialize(info: Optional[HostInfo] = None) -> HostInfo:
    """Call jax.distributed.initialize when running multi-host; no-op on a
    single host (this container)."""
    info = info or detect_environment()
    if info.process_count > 1 and info.coordinator:
        import jax
        jax.distributed.initialize(
            coordinator_address=info.coordinator,
            num_processes=info.process_count,
            process_id=info.process_id,
        )
    return info
