"""Activation-sharding context: logical constraints inside model code.

Model code calls ``constrain(x, ("batch", None, "vocab"))`` at layout-
critical points (residual stream, logits).  When a mesh has been installed
via ``activation_sharding(mesh)`` (the dry-run / production launchers do
this around tracing), the logical axes resolve through the same rule table
as parameters and become ``with_sharding_constraint``s -- pinning XLA's
propagation so it never gathers the batch.  Without an installed mesh
(unit tests, single-device smoke runs) it is a no-op.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from . import sharding

_MESH: contextvars.ContextVar[Optional[tuple]] = contextvars.ContextVar(
    "activation_mesh", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules=None):
    token = _MESH.set((mesh, rules))
    try:
        yield
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    cur = _MESH.get()
    return cur[0] if cur else None


def constrain(x, axes: Tuple[Optional[str], ...]):
    cur = _MESH.get()
    if cur is None:
        return x
    mesh, rules = cur
    if getattr(x, "ndim", None) != len(axes):
        return x
    spec = sharding.spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
