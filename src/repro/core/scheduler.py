"""Pattern-to-row scheduling: Naive vs Oracular (paper Sec. 5).

* **Naive** -- one pattern at a time is broadcast to *every* row of *every*
  array; the whole substrate performs one pattern's alignment per pass.
* **Oracular** -- a scheduler between the pattern pool and the substrate
  routes each pattern only to rows whose reference fragment is a plausible
  home (the paper implements this with "hash-based filtering", citing
  GRIM-filter).  We implement a real, runnable k-mer seed index (not an
  oracle stub): a pattern is a candidate for a row iff the row's fragment
  contains at least one of the pattern's k-mers.

The schedule quality determines the number of *passes* (lock-step array
executions) needed to process a pattern pool; the cost model turns passes
into time/energy.  For problem sizes that fit in this container the index is
built exactly; for paper-scale problems (3G-char reference) the expected
candidate count is computed analytically from k-mer statistics -- both paths
are exposed and cross-validated in tests.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np


def kmer_codes(codes: np.ndarray, k: int) -> np.ndarray:
    """All k-mers of a code string as packed integers (2 bits/char)."""
    codes = np.asarray(codes, np.uint64)
    if len(codes) < k:
        return np.zeros((0,), np.uint64)
    weights = (np.uint64(4) ** np.arange(k, dtype=np.uint64))
    windows = np.lib.stride_tricks.sliding_window_view(codes, k)
    return (windows * weights).sum(-1).astype(np.uint64)


class KmerIndex:
    """fragment-row inverted index over k-mers (the 'hash-based filter')."""

    def __init__(self, fragments: np.ndarray, k: int = 8):
        self.k = k
        self.n_rows = fragments.shape[0]
        self.index: Dict[int, List[int]] = defaultdict(list)
        for r in range(self.n_rows):
            for km in np.unique(kmer_codes(fragments[r], k)):
                self.index[int(km)].append(r)

    def candidate_rows(self, pattern: np.ndarray) -> np.ndarray:
        rows: set[int] = set()
        for km in np.unique(kmer_codes(pattern, self.k)):
            rows.update(self.index.get(int(km), ()))
        return np.fromiter(rows, np.int64) if rows else np.zeros(0, np.int64)


@dataclasses.dataclass
class Schedule:
    """Result of scheduling a pattern pool onto the substrate.

    ``passes[p]`` maps row -> pattern index for pass p (rows not present are
    idle but still burn compute, as the array is lock-step).
    """

    n_rows: int
    passes: List[Dict[int, int]]
    candidate_counts: np.ndarray

    @property
    def n_passes(self) -> int:
        return len(self.passes)

    @property
    def replication(self) -> float:
        """Average rows evaluated per pattern."""
        total = sum(len(p) for p in self.passes)
        n_pat = len(self.candidate_counts)
        return total / max(n_pat, 1)


def schedule_naive(n_rows: int, n_patterns: int) -> Schedule:
    passes = [{r: p for r in range(n_rows)} for p in range(n_patterns)]
    return Schedule(n_rows, passes, np.full(n_patterns, n_rows))


def schedule_oracular(fragments: np.ndarray, patterns: np.ndarray,
                      k: int = 8) -> Schedule:
    """Greedy list scheduling of (pattern, candidate-row) pairs into passes.

    Each pass may use a row at most once; the number of passes is therefore
    max over rows of the per-row queue depth (load balancing is implicit in
    how fragments partition the reference).
    """
    index = KmerIndex(fragments, k)
    n_rows = fragments.shape[0]
    row_queues: List[List[int]] = [[] for _ in range(n_rows)]
    counts = np.zeros(len(patterns), np.int64)
    for p, pat in enumerate(patterns):
        cand = index.candidate_rows(pat)
        counts[p] = len(cand)
        for r in cand:
            row_queues[r].append(p)
    n_passes = max((len(q) for q in row_queues), default=0)
    passes: List[Dict[int, int]] = []
    for i in range(n_passes):
        assignment = {r: q[i] for r, q in enumerate(row_queues) if i < len(q)}
        passes.append(assignment)
    return Schedule(n_rows, passes, counts)


# Fixed per-pattern seed sampling budget: practical seed-and-extend filters
# (GRIM-filter class, the paper's [30]) sample a bounded number of seeds per
# pattern rather than all P-k+1, so the candidate-row count -- and hence the
# Oracular pass count -- is roughly *independent of pattern length*.  This
# is what makes the paper's Fig. 7 throughput stay close to baseline while
# compute-per-alignment grows.  86 = the P=100, k=15 seed count.
SEED_BUDGET = 86


def expected_candidates(ref_len: int, pattern_len: int, k: int,
                        packing_overhead: float = 1.25,
                        seed_budget: int = SEED_BUDGET) -> float:
    """Analytic expected candidate-row count per pattern (paper scale).

    Each sampled k-mer matches ~ref_len / 4^k random reference locations;
    distinct locations land in distinct rows at the paper's fragment sizes.
    ``packing_overhead`` covers dedup slack and imperfect pass packing
    (calibrated once; see costmodel).  A floor of 1 row per pattern applies
    (Oracular never drops patterns, Sec. 5).
    """
    n_kmers = min(max(pattern_len - k + 1, 1), seed_budget)
    hits = n_kmers * ref_len / float(4 ** k)
    return max(hits * packing_overhead, 1.0)


def oracular_passes_analytic(n_patterns: int, total_rows: int, ref_len: int,
                             pattern_len: int, k: int | None = None,
                             packing_overhead: float = 1.25) -> float:
    """Expected number of substrate passes for an Oracular schedule."""
    if k is None:
        k = 15
    cand = expected_candidates(ref_len, pattern_len, k, packing_overhead)
    return max(n_patterns * cand / total_rows, 1.0)
