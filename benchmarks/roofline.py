"""Roofline tables from the dry-run records (assignment deliverable g).

Loads ``experiments/dryrun/*.jsonl`` (last record wins per cell), computes
the three terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the
roofline fraction:

    mfu_bound = (MODEL_FLOPS / n_dev / peak) / max(compute, memory, collective)

i.e. what fraction of the step-time *bound* is useful model compute -- the
score §Perf hillclimbs.

When a calibration table for the current substrate exists
(``repro.match.calibrate``), the report also prints one greppable
``CALIB_DELTA`` line per kernel: the static roofline price vs. the
measured (curve) price at a reference shape, i.e. how far the datasheet
model is from reality here.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

PEAK = 197e12

REPO = pathlib.Path(__file__).resolve().parent.parent


def load(path: str | pathlib.Path = None) -> Dict[tuple, dict]:
    path = pathlib.Path(path) if path else REPO / "experiments/dryrun/full.jsonl"
    cells: Dict[tuple, dict] = {}
    if not path.exists():
        return cells
    for line in path.open():
        r = json.loads(line)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def enrich(r: dict) -> dict:
    if r.get("status") != "ok":
        return r
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    bound = max(terms.values())
    model_term = r["model_flops_global"] / r["n_devices"] / PEAK
    r = dict(r)
    r["bound_s"] = bound
    r["mfu_bound"] = model_term / bound if bound else None
    r["compute_fraction"] = terms["compute"] / bound if bound else None
    return r


def table(mesh: str = "16x16", path=None) -> List[dict]:
    cells = load(path)
    out = []
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        out.append(enrich(r))
    return out


def markdown(mesh: str = "16x16", path=None) -> str:
    rows = table(mesh, path)
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| model/HLO flops | MFU@bound |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                         f"skip | -- | -- |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['mfu_bound']:.4f} |")
    return "\n".join(lines)


# Reference shape per kernel for the static-vs-measured delta (the
# largest point of the autotune grid: least intercept-dominated).
_DELTA_SHAPES = {
    "swar": dict(R=4096, F=128, P=16),
    "swar_masks": dict(R=2048, F=512, P=64),
    "mxu": dict(R=512, F=256, P=64, Q=128),
    "ref": dict(R=1024, F=256, P=32),
    "filter": dict(R=16384, sig_words=8),
}


def calibration_delta() -> List[dict]:
    """Per-kernel static-vs-measured price delta, [] when no table fits.

    Prices the same analytic estimate through both cost sources; the
    ratio is the measured overhead the static model cannot see (in
    interpret mode it is orders of magnitude).
    """
    from repro.core.tech import TPU_V5E, StaticCostSource
    from repro.match import calibrate
    from repro.match.planner import (analytic_filter_seconds,
                                     analytic_mxu_seconds,
                                     analytic_ref_seconds,
                                     analytic_swar_seconds)

    source = calibrate.load_cost_source()
    if source is None:
        return []
    static = StaticCostSource()
    out = []
    for kernel, shape in _DELTA_SHAPES.items():
        if kernel not in source.curves:
            continue
        if kernel == "filter":
            analytic = analytic_filter_seconds(
                TPU_V5E, shape["R"], shape["sig_words"], 1)
        else:
            L = shape["F"] - shape["P"] + 1
            if kernel == "mxu":
                analytic = analytic_mxu_seconds(
                    TPU_V5E, shape["R"], L, shape["P"], shape["Q"])
            elif kernel == "ref":
                analytic = analytic_ref_seconds(
                    TPU_V5E, shape["R"], L, shape["P"], 1)
            else:
                pred = "accept" if kernel == "swar_masks" else "exact"
                analytic = analytic_swar_seconds(
                    TPU_V5E, shape["R"], L, shape["P"], 1, pred)
        s = static.price(kernel, analytic, 1)
        m = source.price(kernel, analytic, 1)
        curve = source.curves[kernel]
        out.append({"kernel": kernel, "shape": shape,
                    "static_s": s, "measured_s": m,
                    "ratio": m / max(s, 1e-300),
                    "alpha": curve.alpha, "beta": curve.beta,
                    "rel_err": curve.rel_err, "tag": source.tag})
    return out


def run():
    rows = []
    for d in calibration_delta():
        rows.append((f"roofline/calib_delta/{d['kernel']}", 0.0,
                     f"static_s={d['static_s']:.3g}"
                     f" measured_s={d['measured_s']:.3g}"
                     f" ratio={d['ratio']:.3g} alpha={d['alpha']:.4g}"
                     f" beta={d['beta']:.3g} tag={d['tag']}"))
    cells = table("16x16")
    ok = [r for r in cells if r.get("status") == "ok"]
    if not ok:
        rows.append(("roofline/missing", 0.0,
                     "run python -m repro.launch.dryrun --all first"))
        return rows
    for r in ok:
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"compute={r['compute_s']:.3g}s memory={r['memory_s']:.3g}s"
                     f" collective={r['collective_s']:.3g}s dom={r['dominant']}"
                     f" mfu_bound={r['mfu_bound']:.4f}"))
    worst = min(ok, key=lambda r: r["mfu_bound"])
    collb = max(ok, key=lambda r: r["collective_s"] / max(r["bound_s"], 1e-12))
    rows.append(("roofline/worst_fraction", 0.0,
                 f"{worst['arch']}/{worst['shape']} mfu={worst['mfu_bound']:.4f}"))
    rows.append(("roofline/most_collective_bound", 0.0,
                 f"{collb['arch']}/{collb['shape']}"
                 f" coll_share={collb['collective_s']/collb['bound_s']:.3f}"))
    return rows


def main() -> int:
    deltas = calibration_delta()
    if not deltas:
        print("CALIB_DELTA none (no calibration table for this substrate; "
              "run python -m repro.match.calibrate)")
    for d in deltas:
        print(f"CALIB_DELTA kernel={d['kernel']} "
              f"static_s={d['static_s']:.4g} "
              f"measured_s={d['measured_s']:.4g} ratio={d['ratio']:.4g} "
              f"alpha={d['alpha']:.4g} beta={d['beta']:.4g} "
              f"rel_err={d['rel_err']:.3g} tag={d['tag']}")
    print(markdown())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
