"""Model assembly: param specs, scanned layer stacks, train/prefill/decode.

The layer stack is organized as repeating *units* (= cfg.block_pattern), with
all full units stacked and executed under one ``jax.lax.scan`` (flat HLO,
depth-independent compile time) and any remainder layers unrolled.  Caches
are stacked the same way and threaded through the scan as per-unit xs/ys.

Three entry points (what the dry-run lowers):
  * ``loss_fn``      -- train forward + next-token CE (+ MoE aux)
  * ``prefill``      -- full-sequence forward filling a decode cache
  * ``decode_step``  -- one token against the cache
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.context import constrain

from . import layers, rglru, ssm
from .config import InputShape, ModelConfig
from .layers import COMPUTE_DTYPE
from .spec import P, abstract, initialize, stack, tree_axes


# ---------------------------------------------------------------------------
# Block-level dispatch
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, kind: str, cross: bool = False) -> Dict[str, Any]:
    if kind in ("attn", "local_attn"):
        d: Dict[str, Any] = {
            "ln1": layers.norm_specs(cfg),
            "attn": layers.attention_specs(cfg),
            "ln2": layers.norm_specs(cfg),
            "mlp": layers.mlp_specs(cfg),
        }
        if cross:
            d["lnx"] = layers.norm_specs(cfg)
            d["xattn"] = layers.attention_specs(cfg, cross=True)
        return d
    if kind == "moe":
        return {
            "ln1": layers.norm_specs(cfg),
            "attn": layers.attention_specs(cfg),
            "ln2": layers.norm_specs(cfg),
            "moe": layers.moe_specs(cfg),
        }
    if kind == "ssd":
        return {"ln1": layers.norm_specs(cfg), "ssd": ssm.ssd_specs(cfg)}
    if kind == "rglru":
        return {
            "ln1": layers.norm_specs(cfg),
            "rglru": rglru.rglru_specs(cfg),
            "ln2": layers.norm_specs(cfg),
            "mlp": layers.mlp_specs(cfg),
        }
    raise ValueError(kind)


def block_cache_specs(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      cross_len: int = 0) -> Dict[str, Any]:
    if kind in ("attn", "local_attn", "moe"):
        d = {"attn": layers.attn_cache_specs(cfg, batch, seq_len)}
        if cross_len:
            d["xattn"] = layers.attn_cache_specs(cfg, batch, cross_len)
        return d
    if kind == "ssd":
        return {"ssd": ssm.ssd_cache_specs(cfg, batch)}
    if kind == "rglru":
        return {"rglru": rglru.rglru_cache_specs(cfg, batch)}
    raise ValueError(kind)


def block_apply(cfg: ModelConfig, kind: str, p, x, *, positions, mode: str,
                cache=None, cache_index=None, xa=None, bidir=False):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    if kind in ("attn", "local_attn", "moe"):
        h = layers.apply_norm(cfg, p["ln1"], x)
        a, c = layers.attention_apply(
            cfg, p["attn"], h, positions=positions, mode=mode,
            cache=cache.get("attn") if cache else None,
            cache_index=cache_index, local=(kind == "local_attn"),
            bidir=bidir)
        if c is not None:
            new_cache["attn"] = c
        x = x + a
        if "xattn" in p:
            h = layers.apply_norm(cfg, p["lnx"], x)
            # cross-attn: full mode computes enc K/V; decode uses cache.
            xc, cc = layers.attention_apply(
                cfg, p["xattn"], h, positions=positions,
                mode=mode, cache=cache.get("xattn") if cache else None,
                cache_index=cache_index, xa=xa)
            if cc is not None:
                new_cache["xattn"] = cc
            x = x + xc
        h = layers.apply_norm(cfg, p["ln2"], x)
        if kind == "moe":
            m, aux = layers.moe_apply(cfg, p["moe"], h)
        else:
            m = layers.mlp_apply(cfg, p["mlp"], h)
        x = x + m
    elif kind == "ssd":
        h = layers.apply_norm(cfg, p["ln1"], x)
        s, c = ssm.ssd_apply(cfg, p["ssd"], h, mode=mode,
                             cache=cache.get("ssd") if cache else None)
        if c is not None:
            new_cache["ssd"] = c
        x = x + s
    elif kind == "rglru":
        h = layers.apply_norm(cfg, p["ln1"], x)
        r, c = rglru.rglru_apply(cfg, p["rglru"], h, mode=mode,
                                 cache=cache.get("rglru") if cache else None)
        if c is not None:
            new_cache["rglru"] = c
        x = x + r
        h = layers.apply_norm(cfg, p["ln2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stack layout: full units scanned, remainder unrolled
# ---------------------------------------------------------------------------

def _unit_layout(cfg: ModelConfig, n_layers: int) -> Tuple[int, Tuple[str, ...]]:
    unit = cfg.block_pattern
    n_units = n_layers // len(unit)
    rest = tuple(cfg.layer_pattern[n_units * len(unit): n_layers])
    return n_units, rest


def _stack_param_specs(cfg: ModelConfig, n_layers: int,
                       cross: bool = False) -> Dict[str, Any]:
    n_units, rest = _unit_layout(cfg, n_layers)
    unit_specs = {str(i): block_specs(cfg, kind, cross=cross)
                  for i, kind in enumerate(cfg.block_pattern)}
    out: Dict[str, Any] = {}
    if n_units:
        out["units"] = stack(n_units, unit_specs)
    if rest:
        out["rest"] = {str(i): block_specs(cfg, kind, cross=cross)
                       for i, kind in enumerate(rest)}
    return out


def _stack_cache_specs(cfg: ModelConfig, n_layers: int, batch: int,
                       seq_len: int, cross_len: int = 0) -> Dict[str, Any]:
    n_units, rest = _unit_layout(cfg, n_layers)
    unit = {str(i): block_cache_specs(cfg, kind, batch, seq_len, cross_len)
            for i, kind in enumerate(cfg.block_pattern)}
    out: Dict[str, Any] = {}
    if n_units:
        out["units"] = stack(n_units, unit)
    if rest:
        out["rest"] = {str(i): block_cache_specs(cfg, kind, batch, seq_len,
                                                 cross_len)
                       for i, kind in enumerate(rest)}
    return out


def _apply_stack(cfg: ModelConfig, stack_params, x, *, positions, mode,
                 caches=None, cache_index=None, xa=None, bidir=False,
                 pattern: Optional[Tuple[str, ...]] = None):
    """Run the (scanned units + unrolled rest) stack.

    Returns (x, new_caches, aux_total)."""
    pattern = pattern or cfg.block_pattern
    aux_total = jnp.zeros((), jnp.float32)

    def unit_fn(carry, unit_in):
        xx, aux = carry
        u_params, u_cache = unit_in
        new_u_cache = {}
        for i, kind in enumerate(pattern):
            c_i = u_cache[str(i)] if u_cache is not None else None
            xx, nc, a = block_apply(cfg, kind, u_params[str(i)], xx,
                                    positions=positions, mode=mode,
                                    cache=c_i, cache_index=cache_index,
                                    xa=xa, bidir=bidir)
            xx = constrain(xx, ("batch", None, None))
            if nc is not None:
                new_u_cache[str(i)] = nc
            aux = aux + a
        return (xx, aux), (new_u_cache or None)

    new_caches: Dict[str, Any] = {}
    if "units" in stack_params:
        u_caches = caches.get("units") if caches else None
        fn = unit_fn
        if cfg.remat and mode == "full" and caches is None:
            fn = jax.checkpoint(unit_fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        xs = (stack_params["units"], u_caches)
        (x, aux_total), ys = jax.lax.scan(fn, (x, aux_total), xs)
        if ys is not None:
            new_caches["units"] = ys
    if "rest" in stack_params:
        # Remainder layers continue the repeating pattern from a unit
        # boundary, so kind i is pattern[i % len(pattern)].
        new_rest = {}
        for i, key in enumerate(sorted(stack_params["rest"], key=int)):
            kind = pattern[i % len(pattern)]
            c_i = caches["rest"][key] if caches else None
            x, nc, a = block_apply(cfg, kind, stack_params["rest"][key], x,
                                   positions=positions, mode=mode, cache=c_i,
                                   cache_index=cache_index, xa=xa, bidir=bidir)
            if nc is not None:
                new_rest[key] = nc
            aux_total = aux_total + a
        if new_rest:
            new_caches["rest"] = new_rest
    return x, (new_caches or None), aux_total


# ---------------------------------------------------------------------------
# Whole-model specs
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab
    out: Dict[str, Any] = {}
    # The token embedding always exists (stub-frontend archs still decode
    # text tokens); stub modalities feed precomputed embeddings instead of
    # using it on the way in.
    out["embed"] = P((V, d), ("vocab", "embed"), "embed")
    if cfg.is_encdec:
        out["encoder"] = {
            "blocks": _stack_param_specs_enc(cfg),
            "ln_f": layers.norm_specs(cfg),
        }
        out["decoder"] = {
            "blocks": _stack_param_specs(cfg, cfg.n_layers, cross=True),
            "ln_f": layers.norm_specs(cfg),
        }
    else:
        out["blocks"] = _stack_param_specs(cfg, cfg.n_layers)
        out["ln_f"] = layers.norm_specs(cfg)
    if not cfg.tie_embeddings:
        out["unembed"] = P((d, V), ("embed", "vocab"))
    if cfg.param_dtype == "bf16":
        # Serving deployments hold weights in bf16 (halves decode weight
        # traffic; training keeps f32 master copies in the optimizer).
        out = jax.tree.map(
            lambda s: P(s.shape, s.axes, s.init, jnp.bfloat16), out,
            is_leaf=lambda x: isinstance(x, P))
    return out


def _stack_param_specs_enc(cfg: ModelConfig) -> Dict[str, Any]:
    unit = {"0": block_specs(cfg, "attn")}
    return {"units": stack(cfg.n_enc_layers, unit)}


def init_params(cfg: ModelConfig, rng) -> Any:
    return initialize(param_specs(cfg), rng)


def abstract_params(cfg: ModelConfig) -> Any:
    return abstract(param_specs(cfg))


def param_axes(cfg: ModelConfig) -> Any:
    return tree_axes(param_specs(cfg))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _sinusoid(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], -1).astype(np.float32)


def _sinusoid_at(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding at traced positions (B, S) -> (B, S, d)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, None, :]
    ang = positions[..., None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def encode(cfg: ModelConfig, params, frames) -> jnp.ndarray:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    x = frames.astype(COMPUTE_DTYPE)
    x = x + jnp.asarray(_sinusoid(x.shape[1], cfg.d_model),
                        COMPUTE_DTYPE)[None]
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (B, x.shape[1]))
    x, _, _ = _apply_stack(cfg, params["encoder"]["blocks"], x,
                           positions=positions, mode="full", bidir=True,
                           pattern=("attn",))
    return layers.apply_norm(cfg, params["encoder"]["ln_f"], x)


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            *, mode: str = "full", caches=None, cache_index=None):
    """Returns (logits_f32, new_caches, aux)."""
    if cfg.is_encdec:
        xa = encode(cfg, params, batch["frames"]) if "frames" in batch \
            else batch.get("enc_out")
    else:
        xa = None

    if cfg.input_mode == "embeddings" and "embeds" in batch:
        x = batch["embeds"].astype(COMPUTE_DTYPE)
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    x = constrain(x, ("batch", None, None))

    if cache_index is not None:
        ci = jnp.asarray(cache_index)
        if ci.ndim == 0:
            positions = jnp.broadcast_to((ci + jnp.arange(S))[None], (B, S))
        else:
            # Per-row cache positions (serving slots at diverging lengths).
            positions = ci[:, None] + jnp.arange(S)[None, :]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.is_encdec and cfg.rope_theta <= 0:
        x = x + _sinusoid_at(positions, cfg.d_model).astype(COMPUTE_DTYPE)

    blocks = params["decoder"]["blocks"] if cfg.is_encdec else params["blocks"]
    ln_f = params["decoder"]["ln_f"] if cfg.is_encdec else params["ln_f"]
    x, new_caches, aux = _apply_stack(
        cfg, blocks, x, positions=positions, mode=mode, caches=caches,
        cache_index=cache_index, xa=xa)
    x = layers.apply_norm(cfg, ln_f, x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits.astype(jnp.float32), new_caches, aux


def loss_fn(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    """Next-token CE over the batch (+ MoE aux loss)."""
    logits, _, aux = forward(cfg, params, batch, mode="full")
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# Caches / serving entry points
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    cross_len = cfg.n_audio_frames if cfg.is_encdec else 0
    return _stack_cache_specs(cfg, cfg.n_layers, batch, seq_len, cross_len)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Any:
    return initialize(cache_specs(cfg, batch, seq_len),
                      jax.random.PRNGKey(0))


def prefill(cfg: ModelConfig, params, batch, caches):
    """Full-sequence forward that fills the decode cache; returns
    (last_logits (B, V), caches)."""
    logits, new_caches, _ = forward(cfg, params, batch, mode="full",
                                    caches=caches, cache_index=0)
    return logits[:, -1], new_caches


def decode_step(cfg: ModelConfig, params, caches, tokens, cache_index,
                enc_out=None):
    """One decode step: tokens (B, 1) -> (logits (B, V), new caches).

    ``cache_index`` is a scalar (all rows at the same position) or a (B,)
    vector of per-row positions (serving slots whose lengths diverge);
    each row's KV is written at its own position either way.
    """
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["enc_out"] = enc_out
    logits, new_caches, _ = forward(cfg, params, batch, mode="decode",
                                    caches=caches, cache_index=cache_index)
    return logits[:, -1], new_caches


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for the dry-run
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract inputs for (arch x shape) -- no allocation (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        if cfg.is_encdec:
            return {"frames": jax.ShapeDtypeStruct(
                        (B, cfg.n_audio_frames, cfg.d_model), COMPUTE_DTYPE),
                    "tokens": tok, "labels": tok}
        if cfg.input_mode == "embeddings":
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   COMPUTE_DTYPE),
                    "labels": tok}
        return {"tokens": tok, "labels": tok}
    if shape.kind == "prefill":
        base = {"caches": abstract(cache_specs(cfg, B, S))}
        if cfg.is_encdec:
            base.update({"frames": jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model), COMPUTE_DTYPE),
                "tokens": tok})
        elif cfg.input_mode == "embeddings":
            base["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                  COMPUTE_DTYPE)
        else:
            base["tokens"] = tok
        return base
    if shape.kind == "decode":
        base = {
            "caches": abstract(cache_specs(cfg, B, S)),
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if cfg.is_encdec:
            base["enc_out"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model), COMPUTE_DTYPE)
        return base
    raise ValueError(shape.kind)
