"""Assigned-architecture configs (one module per arch) + registry."""

from .registry import (ARCHS, CONFIGS, OPTIMIZED_OVERRIDES, SMOKE_CONFIGS,
                       get_config)

__all__ = ["ARCHS", "CONFIGS", "OPTIMIZED_OVERRIDES", "SMOKE_CONFIGS",
           "get_config"]
