"""Paper Fig. 5: throughput + energy efficiency, Naive/Oracular x plain/Opt,
3M-pattern DNA pool, normalized to the GPU baseline."""

import time

from repro.core import costmodel as cm
from repro.core.tech import NEAR_TERM

PAPER = {("naive", False): 23215.3, ("oracular", False): 2.32}


def run():
    rows = []
    gpu = cm.GPUBaseline()
    for opt in (False, True):
        for sched in ("naive", "oracular"):
            t0 = time.perf_counter()
            d = cm.Design(tech=NEAR_TERM, opt=opt)
            r = cm.run_workload(d, 3_000_000, sched)
            us = (time.perf_counter() - t0) * 1e6
            name = f"fig5/{sched}{'Opt' if opt else ''}"
            paper_h = PAPER.get((sched, opt))
            rows.append((name, round(us, 1),
                         f"hours={r.total_time_s/3600:.2f}"
                         + (f" paper={paper_h}" if paper_h else "")
                         + f" rate={r.match_rate:.4g}/s"
                         f" vs_gpu={r.match_rate/gpu.match_rate:.3g}x"
                         f" eff={r.efficiency:.4g}/s/mW"
                         f" eff_vs_gpu={r.efficiency/gpu.efficiency:.3g}x"))
    return rows
