"""Device-resident growable packed corpus for the match engine
(DESIGN.md Sec. 3a/3f).

The paper's core discipline is that the reference never moves once laid out
(CRAM-PM keeps fragments resident in the array rows; Sec. 2-3).  The TPU
analogue: pack the fragment matrix into its kernel-native forms *once*, keep
both forms device-resident, and serve every subsequent query from the cached
arrays.  Two forms exist because the two kernels want different layouts:

* SWAR form  -- (C_pad, W) uint32, 16 two-bit chars per word, rows padded to
  ``match_swar.ROW_TILE``; consumed by the VPU bit-parallel kernel.
* one-hot form -- (C_pad, F4) bf16, char-major flattened one-hot; consumed
  by the MXU correlation kernel.

Both are built lazily on first use and grown *on device* (zero-extension via
``jnp`` concat/pad) when a query needs more padding than a previous one --
host repacking happens at most once per form for a given corpus lifetime.
``host_pack_count`` counts those host->device packing events; the
steady-state invariant (no repacking across repeated queries *or corpus
growth*) is asserted by ``tests/test_match_engine.py``,
``tests/test_match_ingest.py`` and the engine/ingest benchmarks.

The corpus is **growable in place** (Sec. 3f): ``capacity`` row slots are
reserved up front (and doubled on demand), ``n_rows`` counts the *live*
rows, and ``append_rows`` packs only the appended rows on the host and
splices them into the cached device forms with ``.at[].set`` -- the
resident rows are never repacked, mirroring a CRAM row write into an
already-laid-out array.  Capacity growth itself is a device-side
zero-extension (``jnp.concatenate`` with zero rows), not a host repack.
``generation`` bumps on every content mutation (``append_rows`` /
``set_rows`` / ``invalidate``) so result caches (match.service) never serve
scores computed against older corpus contents.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.kernels import match_swar as _swar

ROW_TILE = _swar.ROW_TILE


def _one_hot_flat(fragments: np.ndarray) -> np.ndarray:
    """(R, F) uint8 codes -> (R, F*4) float32 char-major one-hot."""
    R, F = fragments.shape
    f1h = np.zeros((R, F, 4), np.float32)
    f1h[np.arange(R)[:, None], np.arange(F)[None, :], fragments] = 1.0
    return f1h.reshape(R, F * 4)


class PackedCorpus:
    """Fragments packed once into device-resident, growable kernel forms.

    ``fragments`` is the (R, F) uint8 code matrix of *live* rows (host copy
    kept as the source of truth for incremental updates and for the ``ref``
    backend); ``capacity`` row slots are reserved so appends are in-place
    row writes.  ``row_pad`` rounds the device row count up; the engine
    raises it above ROW_TILE when sharding over a mesh rows axis.
    """

    def __init__(self, fragments: np.ndarray, *, row_pad: int = ROW_TILE,
                 capacity: Optional[int] = None):
        # Own copy: set_rows/append_rows mutate, and the caller's array
        # must not change underneath the packed device forms.
        fragments = np.array(fragments, np.uint8)
        if fragments.ndim != 2:
            raise ValueError("fragments must be (R, F)")
        if row_pad % ROW_TILE:
            raise ValueError(f"row_pad must be a multiple of {ROW_TILE}")
        self.row_pad = row_pad
        self._n_rows = fragments.shape[0]
        cap = max(self._n_rows, 0 if capacity is None else int(capacity))
        if cap > self._n_rows:
            buf = np.zeros((cap, fragments.shape[1]), np.uint8)
            buf[:self._n_rows] = fragments
            fragments = buf
        self._frags = fragments               # (capacity, F) host buffer
        # Cached device forms (lazy), sized to the padded capacity.
        self._swar: Optional[jnp.ndarray] = None      # (C_pad, W) uint32
        self._onehot: Optional[jnp.ndarray] = None    # (C_pad, F4) bf16
        # Host->device full-corpus packing events, per form.
        self.swar_pack_count = 0
        self.onehot_pack_count = 0
        # Incremental row writes (device splice, not a repack).
        self.row_update_count = 0
        # Content generation: bumped on every mutation (append_rows /
        # set_rows / invalidate).  Result caches keyed on it
        # (match.service) drop entries computed against older contents.
        self.generation = 0
        # Attached derived forms (match.index.CorpusIndex): observers that
        # mirror the residency protocol -- notified of exactly the touched
        # rows on splices, of capacity growth, and of invalidation, so
        # they stay incrementally up to date without ever re-reading the
        # resident rows.
        self._indexes: list = []

    # -- geometry ------------------------------------------------------------
    @property
    def fragments(self) -> np.ndarray:
        """(n_rows, F) live rows -- a view into the capacity buffer."""
        return self._frags[:self._n_rows]

    @property
    def n_rows(self) -> int:
        """Live (appended) rows; grows under ``append_rows``."""
        return self._n_rows

    @property
    def capacity(self) -> int:
        """Reserved row slots; appends within capacity never reallocate."""
        return self._frags.shape[0]

    @property
    def fragment_chars(self) -> int:
        return self._frags.shape[1]

    @property
    def n_rows_padded(self) -> int:
        """Live rows rounded up to ``row_pad`` (what queries stream over)."""
        return -(-self._n_rows // self.row_pad) * self.row_pad

    @property
    def capacity_padded(self) -> int:
        """Capacity rounded up to ``row_pad`` (device-form row count)."""
        return -(-self.capacity // self.row_pad) * self.row_pad

    @property
    def host_pack_count(self) -> int:
        """Total host-side full-corpus packing events (both forms)."""
        return self.swar_pack_count + self.onehot_pack_count

    def attach_index(self, index) -> None:
        """Register a derived-form observer (see ``match.index``).

        The observer must expose ``_on_rows_written(start, rows)``,
        ``_on_capacity()`` and ``_on_invalidate()``; it is driven by the
        same mutation events that keep the SWAR/one-hot forms current.
        """
        self._indexes.append(index)

    def detach_index(self, index) -> None:
        """Stop notifying (and so stop updating) an attached observer.

        An abandoned index otherwise keeps re-deriving signatures on
        every row splice and pins its device form for the corpus
        lifetime; detach before replacing one configuration with
        another.  Detaching an index that is not attached is a no-op.
        """
        self._indexes = [ix for ix in self._indexes if ix is not index]

    @classmethod
    def from_reference(cls, ref_codes: np.ndarray, fragment_len: int,
                       pattern_len: int, *, row_pad: int = ROW_TILE
                       ) -> "PackedCorpus":
        """Fold a long reference into overlapping rows (Fig. 3 layout)."""
        frags = encoding.fold_reference(ref_codes, fragment_len, pattern_len)
        return cls(frags, row_pad=row_pad)

    # -- SWAR form -----------------------------------------------------------
    def swar_words(self, need_words: int) -> jnp.ndarray:
        """(C_pad, W >= need_words) uint32, device-resident.

        First call packs on the host (one event); later calls reuse the
        cached array, zero-extending on device if a query needs deeper
        word reads than any previous one.  Reserved (not yet live) rows
        pack to zero words -- code 0 packs to 0 -- so the form covers the
        whole capacity and appends are pure row splices.
        """
        if self._swar is None:
            words = encoding.pack_codes_u32(self._frags)
            c_pad = self.capacity_padded
            if c_pad > words.shape[0]:
                words = np.concatenate(
                    [words, np.zeros((c_pad - words.shape[0], words.shape[1]),
                                     np.uint32)], 0)
            if words.shape[1] < need_words:
                words = np.concatenate(
                    [words, np.zeros((c_pad, need_words - words.shape[1]),
                                     np.uint32)], 1)
            self._swar = jnp.asarray(words)
            self.swar_pack_count += 1
        elif self._swar.shape[1] < need_words:
            grow = need_words - self._swar.shape[1]
            self._swar = jnp.concatenate(
                [self._swar,
                 jnp.zeros((self._swar.shape[0], grow), jnp.uint32)], 1)
        return self._swar

    # -- one-hot form ----------------------------------------------------------
    def onehot_flat(self, f_chars: int) -> jnp.ndarray:
        """(C_pad, F4 >= f_chars*4) bf16 one-hot, device-resident.

        Padding chars and reserved rows are all-zero one-hot (contribute 0
        to every score), so growing either way is a device-side
        zero-extension.  Rows are padded like the SWAR form so sharded
        chunks divide evenly over the mesh.
        """
        if self._onehot is None:
            base = _one_hot_flat(self._frags)
            base[self._n_rows:] = 0.0         # reserved rows: all-zero
            c_pad = self.capacity_padded
            if c_pad > base.shape[0]:
                base = np.concatenate(
                    [base, np.zeros((c_pad - base.shape[0], base.shape[1]),
                                    np.float32)], 0)
            need = max(f_chars, self.fragment_chars) * 4
            if base.shape[1] < need:
                base = np.concatenate(
                    [base, np.zeros((base.shape[0], need - base.shape[1]),
                                    np.float32)], 1)
            self._onehot = jnp.asarray(base, jnp.bfloat16)
            self.onehot_pack_count += 1
        elif self._onehot.shape[1] < f_chars * 4:
            grow = f_chars * 4 - self._onehot.shape[1]
            self._onehot = jnp.pad(self._onehot, ((0, 0), (0, grow)))
        return self._onehot

    # -- growth ----------------------------------------------------------------
    def reserve(self, capacity: int) -> None:
        """Grow reserved row slots to at least ``capacity``, in place.

        The host buffer extends with zero rows (a memcpy of raw codes, not
        a packing event) and the cached device forms pad-extend with
        device-side ``jnp.concatenate`` -- the resident packed rows are
        never re-read or re-packed on the host, and the pack counters do
        not move.  Contents are unchanged, so ``generation`` holds too.
        """
        capacity = int(capacity)
        if capacity < self._n_rows:
            # A shrink below the live region would drop resident rows the
            # device forms still serve; refuse loudly instead of silently
            # ignoring the request.
            raise ValueError(
                f"cannot reserve capacity {capacity} below the live row "
                f"count: corpus holds {self._n_rows} live rows (capacity "
                f"{self.capacity}); shrinking a PackedCorpus is not "
                "supported")
        if capacity <= self.capacity:
            return
        grow = np.zeros((capacity - self.capacity, self.fragment_chars),
                        np.uint8)
        self._frags = np.concatenate([self._frags, grow], 0)
        c_pad = self.capacity_padded
        if self._swar is not None and self._swar.shape[0] < c_pad:
            self._swar = jnp.concatenate(
                [self._swar,
                 jnp.zeros((c_pad - self._swar.shape[0],
                            self._swar.shape[1]), jnp.uint32)], 0)
        if self._onehot is not None and self._onehot.shape[0] < c_pad:
            self._onehot = jnp.concatenate(
                [self._onehot,
                 jnp.zeros((c_pad - self._onehot.shape[0],
                            self._onehot.shape[1]), jnp.bfloat16)], 0)
        for ix in self._indexes:
            ix._on_capacity()

    def append_rows(self, rows: np.ndarray) -> int:
        """Append live rows in place; returns the first new row's index.

        Packs only the appended rows on the host and splices them into the
        cached device forms (``.at[].set``) -- zero host repacks of the
        resident rows, ever.  Capacity doubles on demand (amortized O(1)
        row writes per append); ``generation`` bumps once per call so
        generation-keyed caches see every append.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[1] != self.fragment_chars:
            raise ValueError(
                f"appended rows must be (n, {self.fragment_chars}); got "
                f"shape {rows.shape}")
        n = rows.shape[0]
        start = self._n_rows
        if start + n > self.capacity:
            self.reserve(max(self.capacity * 2, start + n, ROW_TILE))
        self._frags[start:start + n] = rows
        self._n_rows = start + n
        self._splice_device(start, rows)
        self.generation += 1
        return start

    # -- incremental updates ---------------------------------------------------
    def _splice_device(self, start: int, rows: np.ndarray) -> None:
        """Pack ``rows`` (host, touched rows only) into the cached forms."""
        n = rows.shape[0]
        if self._swar is not None:
            words = encoding.pack_codes_u32(rows)
            w = self._swar.shape[1]
            if words.shape[1] < w:
                words = np.concatenate(
                    [words, np.zeros((n, w - words.shape[1]), np.uint32)], 1)
            self._swar = self._swar.at[start:start + n, :].set(
                jnp.asarray(words))
        if self._onehot is not None:
            oh = _one_hot_flat(rows)
            w = self._onehot.shape[1]
            if oh.shape[1] < w:
                oh = np.concatenate(
                    [oh, np.zeros((n, w - oh.shape[1]), np.float32)], 1)
            self._onehot = self._onehot.at[start:start + n, :].set(
                jnp.asarray(oh, jnp.bfloat16))
        for ix in self._indexes:
            ix._on_rows_written(start, rows)
        self.row_update_count += n

    def set_rows(self, start: int, rows: np.ndarray) -> None:
        """Overwrite live rows [start, start+n) -- packs only those rows.

        The cached device forms are updated in place (``.at[].set``), so a
        growing store (dedup) never repacks its resident rows.  Writes
        past the live region are rejected: grow with ``append_rows``.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        n = rows.shape[0]
        if rows.shape[1] != self.fragment_chars:
            raise ValueError(
                f"row width mismatch: rows have {rows.shape[1]} chars, "
                f"corpus fragments have {self.fragment_chars}")
        if start < 0 or start + n > self._n_rows:
            raise ValueError(
                f"row range [{start}, {start + n}) out of bounds for "
                f"{self._n_rows} live rows (capacity {self.capacity}); "
                "use append_rows to grow the corpus")
        self._frags[start:start + n] = rows
        self._splice_device(start, rows)
        self.generation += 1

    def invalidate(self) -> None:
        """Drop cached device forms (next query repacks)."""
        self._swar = None
        self._onehot = None
        for ix in self._indexes:
            ix._on_invalidate()
        self.generation += 1
