"""Serving launcher: batched requests through the Engine.

``python -m repro.launch.serve --arch llama3.2-1b --smoke`` boots a
randomly initialized reduced model, runs a batch of synthetic requests
through the continuous-batching engine, and reports decode throughput +
n-gram speculator acceptance (the paper's matcher in the serving plane).

``--workload match`` serves synthetic string-match traffic instead: many
small shared-mode queries through a ``MatchService`` over one resident
corpus (micro-batched multi-tenant execution, DESIGN.md Sec. 3d), and
reports coalescing + cache stats alongside QPS.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model
from repro.serving.engine import Engine, Request
from repro.serving.ngram_cache import NgramSpeculator, verify


def run_match_service(args) -> None:
    """Synthetic multi-tenant match traffic through one MatchService.

    Requests are declarative ``MatchQuery`` objects; ``--predicate
    wildcard`` turns a few positions of every pattern into ``N`` wildcards
    (accept-everything masks), exercising the accept-set kernel path under
    the same coalescing machinery.
    """
    from repro.match import MatchEngine, MatchQuery, MatchService

    rng = np.random.default_rng(0)
    frags = rng.integers(0, 4, (args.corpus_rows, args.fragment_chars),
                         np.uint8)
    svc = MatchService(MatchEngine(frags))
    pats = rng.integers(0, 4, (args.requests, args.pattern_chars), np.uint8)
    if args.predicate == "wildcard":
        masks = (np.uint8(1) << pats).astype(np.uint8)
        n_wild = max(1, args.pattern_chars // 8)
        for q in range(args.requests):
            masks[q, rng.integers(0, args.pattern_chars, n_wild)] = 0b1111
        queries = [MatchQuery.from_masks(m) for m in masks]
    else:
        queries = [MatchQuery.exact(p) for p in pats]
    t0 = time.perf_counter()
    tickets = [svc.submit(q) for q in queries]
    svc.flush()
    dt = time.perf_counter() - t0
    assert all(t.done for t in tickets)
    stats = svc.stats.snapshot()
    print(f"served {len(tickets)} {args.predicate} match queries in "
          f"{dt:.2f}s ({len(tickets)/dt:.1f} qps)")
    print(f"launches={stats['n_launches']} "
          f"coalesced={stats['n_coalesced_launches']} "
          f"(fused {stats['n_coalesced_queries']} queries) "
          f"cache_hits={stats['n_cache_hits']} "
          f"avg_latency={stats['avg_latency_s']*1e3:.1f}ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "match"), default="lm")
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--corpus-rows", type=int, default=64,
                    help="match workload: resident corpus rows")
    ap.add_argument("--fragment-chars", type=int, default=256,
                    help="match workload: fragment length")
    ap.add_argument("--pattern-chars", type=int, default=32,
                    help="match workload: query pattern length")
    ap.add_argument("--predicate", choices=("exact", "wildcard"),
                    default="exact",
                    help="match workload: exact queries or N-wildcard "
                         "accept-mask queries")
    args = ap.parse_args()

    if args.workload == "match":
        run_match_service(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    eng = Engine(cfg, params, max_seq=args.max_seq, n_slots=args.slots)
    t0 = time.perf_counter()
    eng.run(list(reqs))
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")

    # n-gram speculation demo on the generated streams
    spec = NgramSpeculator()
    acc, tries = 0, 0
    for r in reqs:
        spec.feed(r.out)
    for r in reqs:
        if len(r.out) > 8:
            prop, conf = spec.propose(r.out[:4], k=4)
            acc += verify(prop, np.asarray(r.out[4:8]))
            tries += 4
    if tries:
        print(f"ngram speculator acceptance: {acc}/{tries}")


if __name__ == "__main__":
    main()
