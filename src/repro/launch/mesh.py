"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two
    pods.  Uses the first prod(shape) available devices so a 512-way
    host-platform dry-run can build both meshes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} -- "
            "run under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires forced host devices)."""
    shape = ((2, n_data, n_model) if multi_pod else (n_data, n_model))
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
