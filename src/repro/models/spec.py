"""Parameter-spec system: shapes + logical sharding axes, declared once.

Each parameter is declared as a ``P(shape, axes, init)`` where ``axes`` names
the *logical* mesh axis of every dimension ("embed", "ff", "heads", "vocab",
"experts", "layers", None...).  From the same declaration we derive:

* ``abstract(specs)``  -- ShapeDtypeStructs for the dry-run (no allocation),
* ``initialize(specs, rng)`` -- materialized f32 params for training,
* ``tree_axes(specs)`` -- the logical-axis pytree consumed by
  ``repro.distributed.sharding`` to build NamedShardings.

This is the same layering MaxText uses (logical axis rules), implemented
minimally and explicitly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter declaration."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"       # fan_in | zeros | ones | normal | embed
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self}")


def is_spec(x) -> bool:
    return isinstance(x, P)


def abstract(specs) -> Any:
    """Pytree of ShapeDtypeStructs -- zero allocation (dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec)


def tree_axes(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def _init_leaf(s: P, key) -> jnp.ndarray:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        return 0.02 * jax.random.normal(key, s.shape, s.dtype)
    if s.init == "embed":
        return jax.random.normal(key, s.shape, s.dtype) / math.sqrt(s.shape[-1])
    if s.init == "fan_in":
        # fan-in = product of all dims except the last output group; use the
        # first dim(s) heuristically: treat last axis as output.
        fan_in = max(1, int(np.prod(s.shape[:-1])))
        scale = 1.0 / math.sqrt(fan_in)
        return scale * jax.random.normal(key, s.shape, s.dtype)
    raise ValueError(s.init)


def initialize(specs, rng) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def stack(n: int, specs) -> Any:
    """Add a leading stacked-layers dim to every spec (for lax.scan)."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype),
        specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))
