"""Observability overhead bench: instrumentation must be ~free.

The tracing/metrics layer (DESIGN.md Sec. 3l) rides inside the hot
serving path -- enqueue, plan, filter, launch, merge, pull -- so its
cost is a correctness property: the full-run gate asserts that
spans-enabled serving adds **< 3%** wall time over the identical
spans-disabled run at Q=64 (the service bench's headline level).  Both
paths share one engine (same compile cache, same resident corpus); the
bench just flips the tracer, which is exactly what ``--trace`` does in
the launcher, and takes best-of-N per path against CPU noise.

The second half validates the trace itself: a mini serve run (queries +
online ingest, coalesced ticks) must yield a Chrome/Perfetto-loadable
trace whose span tree covers plan/launch/merge/pull for every executed
launch and records one enqueue span per request.

Emits ``BENCH_match_obs.json`` at the repo root and exits nonzero if
the record is malformed or the overhead gate fails.  CI runs
``--smoke``: same pipeline and validation on a reduced shape (the
overhead gate is advisory there -- one-repeat smoke timings on a shared
CI box are noise), without overwriting the committed artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_obs.json"

# ``benchmarks.run`` prints the artifact line under this name:
# ``obs,artifact,<overhead_pct>,<n_spans>``.
SUMMARY_NAME = "obs"

FULL = dict(R=48, F=256, P=32, Q=64, repeats=5, block=4, rounds=4)
SMOKE = dict(R=48, F=128, P=16, Q=16, repeats=1, block=1, rounds=1)
BACKEND = "swar"
OVERHEAD_GATE_PCT = 3.0

REQUIRED_KEYS = ("shape", "kernel_backend", "device_kind", "backend",
                 "calibration", "n_processes", "n_hosts", "interpret",
                 "smoke", "Q", "off_s", "on_s", "overhead_pct",
                 "rounds_pct", "n_spans", "trace")
REQUIRED_TRACE_KEYS = ("n_requests", "n_enqueue_spans", "n_runs",
                       "runs_covered", "n_events", "chrome_valid")


def _serve_once(eng, pats, ingest_rows) -> float:
    """One fresh-service pass: submit all, mix in ingest, flush."""
    from repro.match import MatchService

    svc = MatchService(eng)          # fresh: no result-cache crossover
    t0 = time.perf_counter()
    for i, p in enumerate(pats):
        if ingest_rows is not None and i % 8 == 0:
            svc.ingest(ingest_rows[i // 8])
        svc.submit(p, backend=BACKEND)
        if (i + 1) % 16 == 0:
            svc.tick()
    svc.flush()
    return time.perf_counter() - t0


def bench_overhead(eng, cfg, rng) -> dict:
    """Spans-off vs spans-on over the identical engine + workload.

    Instrumentation cost here (~96 spans x ~2.5 us) is a few hundred
    microseconds against a ~15 ms serve pass -- the same order as
    scheduler jitter on a shared box, so a single differential
    min-of-N estimate flaps across the gate.  Two defenses:

    * Each timed sample is a *block* of ``block`` consecutive passes
      (amortizes per-pass jitter; off/on blocks alternate so drift
      hits both sides equally).  Reported ``off_s``/``on_s`` are
      per-pass (best block / block size).
    * The whole alternating min-of-N procedure runs ``rounds`` times
      and the gated ``overhead_pct`` is the *minimum* round estimate.
      Contention only ever inflates a differential estimate (it adds
      time, never removes it), so the minimum over independent rounds
      is the least-contaminated measurement of the deterministic
      instrumentation cost.  All round estimates are recorded in the
      artifact (``rounds_pct``) for transparency.
    """
    Q, P = cfg["Q"], cfg["P"]
    block = int(cfg.get("block", 1))
    rounds = int(cfg.get("rounds", 1))
    pats = rng.integers(0, 4, (Q, P), np.uint8)
    # Warm both code paths at the *timed* shapes (jit compile cache):
    # the tick cadence in ``_serve_once`` fixes the fused batch sizes,
    # so a reduced-Q warmup would leave the full-Q batched kernels to
    # compile inside the first timed repeat.  Once with spans on, so
    # the on-path's only marginal cost is instrumentation.
    eng.obs.tracer.enabled = True
    _serve_once(eng, pats, None)
    eng.obs.tracer.enabled = False
    _serve_once(eng, pats, None)

    def _block(enabled: bool) -> float:
        eng.obs.tracer.enabled = enabled
        t = 0.0
        for _ in range(block):
            eng.obs.tracer.clear()
            t += _serve_once(eng, pats, None)
        return t / block

    best = None
    n_spans = 0
    rounds_pct = []
    for _ in range(rounds):
        t_off = t_on = float("inf")
        for _ in range(cfg["repeats"]):
            t_off = min(t_off, _block(False))
            t_on = min(t_on, _block(True))
            n_spans = eng.obs.tracer.n_spans
        pct = (t_on - t_off) / t_off * 100.0
        rounds_pct.append(round(pct, 2))
        if best is None or pct < best[2]:
            best = (t_off, t_on, pct)
    eng.obs.tracer.enabled = False
    t_off, t_on, overhead_pct = best
    return {"off_s": round(t_off, 5), "on_s": round(t_on, 5),
            "overhead_pct": round(overhead_pct, 2),
            "rounds_pct": rounds_pct, "n_spans": n_spans}


def bench_trace(eng, cfg, rng) -> dict:
    """Traced mini serve run -> structural + schema validation inputs."""
    Q, P, F = cfg["Q"], cfg["P"], cfg["F"]
    pats = rng.integers(0, 4, (Q, P), np.uint8)
    ingest = rng.integers(0, 4, (max(1, Q // 8), F), np.uint8)
    tr = eng.obs.tracer
    tr.clear()
    tr.enabled = True
    _serve_once(eng, pats, ingest)
    tr.enabled = False

    spans = list(tr.iter_spans())
    runs = [s for s in spans if s.name == "match.run"]
    # Every executed launch must account for its full stage pipeline:
    # plan + launch always; merge/pull whenever the result left the
    # device (best-reduction queries always pull).
    def _subtree_names(s):
        return {c.name for c in s.walk()}
    covered = all({"plan", "launch", "merge", "pull"}
                  <= _subtree_names(s) for s in runs)
    chrome = tr.chrome_trace()
    events = chrome["traceEvents"]
    chrome_valid = (bool(events)
                    and all(set(("name", "ph", "ts", "dur", "pid",
                                 "tid")) <= set(e) for e in events)
                    and all(e["ph"] == "X" for e in events)
                    and json.loads(json.dumps(chrome)) is not None)
    return {
        "n_requests": int(Q),
        "n_enqueue_spans": sum(s.name == "service.enqueue"
                               for s in spans),
        "n_runs": len(runs),
        "runs_covered": bool(covered),
        "n_events": len(events),
        "chrome_valid": bool(chrome_valid),
    }


def validate(record: dict) -> None:
    """Schema + gate: fail loudly if the artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if record["off_s"] <= 0 or record["on_s"] <= 0:
        raise ValueError("non-positive serve timings")
    if record["n_spans"] <= 0:
        raise ValueError("instrumented run collected no spans")
    if not record["smoke"] and record["overhead_pct"] >= OVERHEAD_GATE_PCT:
        raise ValueError(
            f"instrumentation overhead {record['overhead_pct']}% >= "
            f"{OVERHEAD_GATE_PCT}% gate")
    tr = record["trace"]
    for key in REQUIRED_TRACE_KEYS:
        if key not in tr:
            raise ValueError(f"trace record missing key {key!r}")
    if tr["n_enqueue_spans"] != tr["n_requests"]:
        raise ValueError(
            f"trace lost requests: {tr['n_enqueue_spans']} enqueue "
            f"spans for {tr['n_requests']} submissions")
    if tr["n_runs"] <= 0 or not tr["runs_covered"]:
        raise ValueError("some executed launch is missing a "
                         "plan/launch/merge/pull stage span")
    if not tr["chrome_valid"]:
        raise ValueError("Chrome trace-event export failed validation")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.match import MatchEngine, Observability

    cfg = SMOKE if smoke else FULL
    R, F = cfg["R"], cfg["F"]
    rng = np.random.default_rng(11)
    obs = Observability(spans=False)
    eng = MatchEngine(rng.integers(0, 4, (R, F), np.uint8), obs=obs)
    overhead = bench_overhead(eng, cfg, rng)
    trace = bench_trace(eng, cfg, rng)
    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {"R": R, "F": F, "P": cfg["P"]},
        "kernel_backend": BACKEND,
        **bench_provenance(eng.planner.cost_source),
        "interpret": eng.interpret,
        "smoke": smoke,
        "Q": cfg["Q"],
        **overhead,
        "trace": trace,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the
        # committed full-run artifact with reduced-shape numbers.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    q = record["Q"]
    return [
        (f"obs/serve_Q{q}_spans_on",
         round(record["on_s"] / q * 1e6, 1),
         f"overhead={record['overhead_pct']}% "
         f"n_spans={record['n_spans']} "
         f"trace_covered={record['trace']['runs_covered']}"),
    ]


def artifact_summary() -> str:
    """Greppable artifact tail: ``<overhead_pct>,<n_spans>`` (the driver
    prefixes ``obs,artifact,``)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    return f"{rec['overhead_pct']},{rec['n_spans']}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape; gate advisory (CI schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    print(f"Q={record['Q']}  spans_off={record['off_s']}s  "
          f"spans_on={record['on_s']}s  "
          f"overhead={record['overhead_pct']}%  "
          f"(gate <{OVERHEAD_GATE_PCT}% on full runs)")
    t = record["trace"]
    print(f"trace: {record['n_spans']} spans, {t['n_events']} chrome "
          f"events, {t['n_runs']} launches covered="
          f"{t['runs_covered']}, enqueue {t['n_enqueue_spans']}/"
          f"{t['n_requests']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
