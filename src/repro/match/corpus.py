"""Device-resident packed corpus for the match engine (DESIGN.md Sec. 3a).

The paper's core discipline is that the reference never moves once laid out
(CRAM-PM keeps fragments resident in the array rows; Sec. 2-3).  The TPU
analogue: pack the fragment matrix into its kernel-native forms *once*, keep
both forms device-resident, and serve every subsequent query from the cached
arrays.  Two forms exist because the two kernels want different layouts:

* SWAR form  -- (R_pad, W) uint32, 16 two-bit chars per word, rows padded to
  ``match_swar.ROW_TILE``; consumed by the VPU bit-parallel kernel.
* one-hot form -- (R, F4) bf16, char-major flattened one-hot; consumed by
  the MXU correlation kernel.

Both are built lazily on first use and grown *on device* (zero-extension via
``jnp`` concat/pad) when a query needs more padding than a previous one --
host repacking happens at most once per form for a given corpus generation.
``host_pack_count`` counts those host->device packing events; the
steady-state invariant (no repacking across repeated queries) is asserted by
``tests/test_match_engine.py`` and the engine benchmark.

Incremental updates (``set_rows``) pack only the touched rows on the host
and splice them into the cached device arrays with ``.at[].set`` -- the
data-plane consumers (``data/dedup.py``) grow their store without ever
repacking the resident part, mirroring a CRAM row write.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.kernels import match_swar as _swar

ROW_TILE = _swar.ROW_TILE


def _one_hot_flat(fragments: np.ndarray) -> np.ndarray:
    """(R, F) uint8 codes -> (R, F*4) float32 char-major one-hot."""
    R, F = fragments.shape
    f1h = np.zeros((R, F, 4), np.float32)
    f1h[np.arange(R)[:, None], np.arange(F)[None, :], fragments] = 1.0
    return f1h.reshape(R, F * 4)


class PackedCorpus:
    """Fragments packed once into device-resident kernel-native forms.

    ``fragments`` is the (R, F) uint8 code matrix (host copy kept as the
    source of truth for incremental updates and for the ``ref`` backend).
    ``row_pad`` rounds the SWAR row count up; the engine raises it above
    ROW_TILE when sharding over a mesh rows axis.
    """

    def __init__(self, fragments: np.ndarray, *, row_pad: int = ROW_TILE):
        # Own copy: set_rows mutates, and the caller's array must not change
        # underneath the packed device forms.
        fragments = np.array(fragments, np.uint8)
        if fragments.ndim != 2:
            raise ValueError("fragments must be (R, F)")
        if row_pad % ROW_TILE:
            raise ValueError(f"row_pad must be a multiple of {ROW_TILE}")
        self.fragments = fragments
        self.row_pad = row_pad
        # Cached device forms (lazy).
        self._swar: Optional[jnp.ndarray] = None      # (R_pad, W) uint32
        self._onehot: Optional[jnp.ndarray] = None    # (R, F4) bf16
        # Host->device full-corpus packing events, per form.
        self.swar_pack_count = 0
        self.onehot_pack_count = 0
        # Incremental row writes (device splice, not a repack).
        self.row_update_count = 0
        # Content generation: bumped on every mutation (set_rows /
        # invalidate).  Result caches keyed on it (match.service) drop
        # entries computed against older corpus contents.
        self.generation = 0

    # -- geometry ------------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.fragments.shape[0]

    @property
    def fragment_chars(self) -> int:
        return self.fragments.shape[1]

    @property
    def n_rows_padded(self) -> int:
        return -(-self.n_rows // self.row_pad) * self.row_pad

    @property
    def host_pack_count(self) -> int:
        """Total host-side full-corpus packing events (both forms)."""
        return self.swar_pack_count + self.onehot_pack_count

    @classmethod
    def from_reference(cls, ref_codes: np.ndarray, fragment_len: int,
                       pattern_len: int, *, row_pad: int = ROW_TILE
                       ) -> "PackedCorpus":
        """Fold a long reference into overlapping rows (Fig. 3 layout)."""
        frags = encoding.fold_reference(ref_codes, fragment_len, pattern_len)
        return cls(frags, row_pad=row_pad)

    # -- SWAR form -----------------------------------------------------------
    def swar_words(self, need_words: int) -> jnp.ndarray:
        """(R_pad, W >= need_words) uint32, device-resident.

        First call packs on the host (one event); later calls reuse the
        cached array, zero-extending on device if a query needs deeper
        word reads than any previous one.
        """
        if self._swar is None:
            words = encoding.pack_codes_u32(self.fragments)
            r_pad = self.n_rows_padded
            if r_pad > words.shape[0]:
                words = np.concatenate(
                    [words, np.zeros((r_pad - words.shape[0], words.shape[1]),
                                     np.uint32)], 0)
            if words.shape[1] < need_words:
                words = np.concatenate(
                    [words, np.zeros((r_pad, need_words - words.shape[1]),
                                     np.uint32)], 1)
            self._swar = jnp.asarray(words)
            self.swar_pack_count += 1
        elif self._swar.shape[1] < need_words:
            grow = need_words - self._swar.shape[1]
            self._swar = jnp.concatenate(
                [self._swar,
                 jnp.zeros((self._swar.shape[0], grow), jnp.uint32)], 1)
        return self._swar

    # -- one-hot form ----------------------------------------------------------
    def onehot_flat(self, f_chars: int) -> jnp.ndarray:
        """(R_pad, F4 >= f_chars*4) bf16 one-hot, device-resident.

        Padding chars/rows are all-zero one-hot (contribute 0 to every
        score), so growing is a device-side ``jnp.pad``.  Rows are padded
        like the SWAR form so sharded chunks divide evenly over the mesh.
        """
        if self._onehot is None:
            base = _one_hot_flat(self.fragments)
            r_pad = self.n_rows_padded
            if r_pad > base.shape[0]:
                base = np.concatenate(
                    [base, np.zeros((r_pad - base.shape[0], base.shape[1]),
                                    np.float32)], 0)
            need = max(f_chars, self.fragment_chars) * 4
            if base.shape[1] < need:
                base = np.concatenate(
                    [base, np.zeros((base.shape[0], need - base.shape[1]),
                                    np.float32)], 1)
            self._onehot = jnp.asarray(base, jnp.bfloat16)
            self.onehot_pack_count += 1
        elif self._onehot.shape[1] < f_chars * 4:
            grow = f_chars * 4 - self._onehot.shape[1]
            self._onehot = jnp.pad(self._onehot, ((0, 0), (0, grow)))
        return self._onehot

    # -- incremental updates ---------------------------------------------------
    def set_rows(self, start: int, rows: np.ndarray) -> None:
        """Overwrite rows [start, start+n) -- packs only the touched rows.

        The cached device forms are updated in place (``.at[].set``), so a
        growing store (dedup) never repacks its resident rows.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        n = rows.shape[0]
        if rows.shape[1] != self.fragment_chars:
            raise ValueError("row width mismatch")
        if start + n > self.n_rows:
            raise ValueError("row range out of bounds")
        self.fragments[start:start + n] = rows
        if self._swar is not None:
            words = encoding.pack_codes_u32(rows)
            w = self._swar.shape[1]
            if words.shape[1] < w:
                words = np.concatenate(
                    [words, np.zeros((n, w - words.shape[1]), np.uint32)], 1)
            self._swar = self._swar.at[start:start + n, :].set(
                jnp.asarray(words))
        if self._onehot is not None:
            oh = _one_hot_flat(rows)
            w = self._onehot.shape[1]
            if oh.shape[1] < w:
                oh = np.concatenate(
                    [oh, np.zeros((n, w - oh.shape[1]), np.float32)], 1)
            self._onehot = self._onehot.at[start:start + n, :].set(
                jnp.asarray(oh, jnp.bfloat16))
        self.row_update_count += n
        self.generation += 1

    def invalidate(self) -> None:
        """Drop cached device forms (next query repacks)."""
        self._swar = None
        self._onehot = None
        self.generation += 1
