"""Declarative MatchQuery IR (DESIGN.md Sec. 3e).

The paper's substrate is *reconfigurable*: one resident array serves many
match flavors by reprogramming the in-memory logic, never by re-shipping
data (Sec. 1, 3).  The TPU analogue is a small, frozen, hashable query IR
that is *compiled once* against the engine (``MatchEngine.compile`` ->
``CompiledMatch``) and then reused: planning, pattern packing and kernel
selection happen at compile time, not per call.

A ``MatchQuery`` bundles

* **patterns as a predicate** -- the canonical pattern form is a
  per-position *accept mask*: uint8 with bit ``c`` set iff DNA code ``c``
  (A=0 C=1 G=2 T=3) is accepted at that position.  Exact characters are
  one-hot masks; IUPAC ambiguity codes (``N`` = 0b1111, ``R`` = A|G, ...)
  and arbitrary character classes are just wider masks.  Two spellings of
  the same query (codes vs. one-hot masks) canonicalize to the same IR and
  therefore the same digest.
* **a reduction spec** -- ``best | topk | threshold | full`` with
  per-query ``k`` / ``threshold`` for batched queries.
* **a row subset** and **backend hints** (kernel override, chunk size).

Everything is stored as hashable primitives (bytes + tuples), so a query
is a dict key: the engine's compile cache and the service's result cache
key on the query object itself (content equality -- collision-free).
``digest`` is the equivalent *stable content hash* for use outside the
process (distributed caches, logs, telemetry).
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import encoding

REDUCTIONS = ("best", "topk", "threshold", "full")
BACKENDS = ("swar", "mxu", "ref")
MODES = ("shared", "per_row", "batched")

_DEFAULT_K = 10


def _mask_array(masks) -> np.ndarray:
    masks = np.asarray(masks, np.uint8)
    if masks.ndim not in (1, 2):
        raise ValueError("patterns must be 1-D (shared) or 2-D")
    if masks.shape[-1] < 1:
        raise ValueError("pattern must have at least one character")
    if masks.size and ((masks < 1) | (masks > 15)).any():
        raise ValueError(
            "accept masks must be in [1, 15]: bit c accepts code c; 0 "
            "accepts nothing and bits >= 4 name no DNA code")
    return masks


@dataclasses.dataclass(frozen=True)
class MatchQuery:
    """Frozen declarative match query; construct via the classmethods.

    Fields are canonical hashable primitives -- use ``exact`` /
    ``from_masks`` / ``iupac`` rather than the raw constructor, and the
    ``masks`` / ``codes`` / ``rows`` properties rather than the ``*_b``
    bytes.  ``mode`` is ``None`` for shared (1-D) queries and for 2-D
    queries left to engine inference.
    """

    masks_b: bytes                          # uint8 accept masks, flattened
    shape: Tuple[int, ...]                  # (P,) or (Q, P)
    mode: Optional[str] = None              # None | "per_row" | "batched"
    reduction: str = "best"
    k: Tuple[int, ...] = ()                 # non-empty only for topk
    threshold: Optional[Tuple[float, ...]] = None
    rows_b: Optional[bytes] = None          # int64 row ids, flattened
    backend: Optional[str] = None           # kernel override
    chunk_rows: Optional[int] = None        # streaming chunk override
    # Q-gram filter hint (threshold queries, DESIGN.md Sec. 3g): None lets
    # the planner's two-stage cost model decide, False opts out, True
    # forces the filtered strategy whenever it is legal (the query has
    # prunable signature bits) -- the pricing is skipped, never the
    # conservativeness requirement.
    filter: Optional[bool] = None

    # -- constructors ---------------------------------------------------------
    @classmethod
    def exact(cls, patterns, **spec) -> "MatchQuery":
        """Query from uint8 character codes (values 0..3).

        Out-of-range codes are rejected here -- at the API boundary --
        instead of surfacing as garbage SWAR scores or an index error deep
        inside the MXU host packing.
        """
        patterns = np.asarray(patterns, np.uint8)
        if patterns.ndim not in (1, 2):
            raise ValueError("patterns must be 1-D (shared) or 2-D")
        if patterns.size and patterns.max() > 3:
            raise ValueError(
                f"pattern codes must be < 4 (A=0 C=1 G=2 T=3); got max "
                f"{int(patterns.max())}. Encode ambiguity codes with "
                "encoding.encode_iupac and MatchQuery.iupac/from_masks")
        return cls.from_masks(
            (np.uint8(1) << patterns).astype(np.uint8), **spec)

    @classmethod
    def from_masks(cls, masks, *, mode: Optional[str] = None,
                   reduction: str = "best", k=_DEFAULT_K, threshold=None,
                   rows=None, backend: Optional[str] = None,
                   chunk_rows: Optional[int] = None,
                   filter: Optional[bool] = None) -> "MatchQuery":
        """Query from per-position accept masks (uint8, bit c = code c)."""
        masks = _mask_array(masks)
        if mode == "shared" and masks.ndim == 1:
            mode = None                     # canonical: shared is default
        if masks.ndim == 1 and mode is not None:
            raise ValueError(f"1-D patterns are 'shared', got mode={mode!r}")
        if masks.ndim == 2 and mode is not None and mode not in (
                "per_row", "batched"):
            raise ValueError(f"2-D patterns need mode 'per_row' or "
                             f"'batched', got {mode!r}")
        if reduction not in REDUCTIONS:
            raise ValueError(f"unknown reduction {reduction!r}")
        if reduction == "threshold" and threshold is None:
            raise ValueError("reduction='threshold' requires a threshold")
        if backend is not None and backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        q = masks.shape[0] if masks.ndim == 2 else 1
        batched_ok = masks.ndim == 2 and mode != "per_row"
        k_norm: Tuple[int, ...] = ()
        if reduction == "topk":
            k_norm = tuple(int(x) for x in np.atleast_1d(np.asarray(k)))
            if len(k_norm) != 1 and not (batched_ok and len(k_norm) == q):
                raise ValueError("per-query k needs a batched query with "
                                 "one entry per pattern")
        thr_norm: Optional[Tuple[float, ...]] = None
        if reduction == "threshold":
            thr_norm = tuple(float(x) for x in
                             np.atleast_1d(np.asarray(threshold, np.float64)))
            if len(thr_norm) != 1:
                if not batched_ok:
                    raise ValueError("per-query thresholds need a batched "
                                     "query")
                if len(thr_norm) != q:
                    raise ValueError("per-query thresholds need one entry "
                                     "per pattern")
        rows_b = None
        if rows is not None:
            rows_b = np.asarray(rows, np.int64).reshape(-1).tobytes()
        if chunk_rows is not None and int(chunk_rows) < 1:
            raise ValueError("chunk_rows must be >= 1")
        if filter is not None and not isinstance(filter, bool):
            raise ValueError("filter must be None, True or False")
        if filter and reduction != "threshold":
            raise ValueError(
                "filter=True needs reduction='threshold': only a row-"
                "sparse reduction can skip pruned rows exactly (best/topk/"
                "full report every row)")
        return cls(masks_b=masks.tobytes(), shape=tuple(masks.shape),
                   mode=mode, reduction=reduction, k=k_norm,
                   threshold=thr_norm, rows_b=rows_b, backend=backend,
                   chunk_rows=None if chunk_rows is None
                   else int(chunk_rows), filter=filter)

    @classmethod
    def iupac(cls, pattern: Union[str, Sequence[str]],
              **spec) -> "MatchQuery":
        """Query from IUPAC string(s): ACGT + ambiguity codes + N wildcard."""
        if isinstance(pattern, str):
            masks = encoding.encode_iupac(pattern)
        else:
            masks = np.stack([encoding.encode_iupac(p) for p in pattern])
        return cls.from_masks(masks, **spec)

    # -- views ----------------------------------------------------------------
    @cached_property
    def masks(self) -> np.ndarray:
        """Accept masks, shape ``self.shape`` (read-only view)."""
        m = np.frombuffer(self.masks_b, np.uint8).reshape(self.shape)
        m.flags.writeable = False
        return m

    @cached_property
    def is_exact(self) -> bool:
        """True iff every position accepts exactly one character."""
        m = self.masks
        return bool(((m & (m - 1)) == 0).all())

    @cached_property
    def codes(self) -> np.ndarray:
        """uint8 character codes; only defined for exact queries."""
        if not self.is_exact:
            raise ValueError("codes are only defined for exact queries; "
                             "use .masks")
        c = np.zeros(self.shape, np.uint8)
        for b in range(4):
            c[self.masks == (1 << b)] = b
        c.flags.writeable = False
        return c

    @property
    def predicate(self) -> str:
        """Planner-facing predicate kind: "exact" or "accept"."""
        return "exact" if self.is_exact else "accept"

    @cached_property
    def rows(self) -> Optional[np.ndarray]:
        if self.rows_b is None:
            return None
        r = np.frombuffer(self.rows_b, np.int64)
        r.flags.writeable = False
        return r

    @property
    def pattern_chars(self) -> int:
        return self.shape[-1]

    @property
    def n_patterns(self) -> int:
        return self.shape[0] if len(self.shape) == 2 else 1

    @cached_property
    def digest(self) -> str:
        """Stable content hash (blake2b-128) over the canonical fields.

        Two queries are equal iff their digests agree; in-process caches
        key on the query object itself, this is the external spelling
        (distributed cache keys, logs, telemetry).
        """
        h = hashlib.blake2b(digest_size=16)
        h.update(self.masks_b)
        for part in (self.shape, self.mode, self.reduction, self.k,
                     self.threshold, self.backend, self.chunk_rows,
                     self.filter):
            h.update(repr(part).encode())
        h.update(self.rows_b if self.rows_b is not None else b"\xff")
        return h.hexdigest()


def as_masks(pattern) -> np.ndarray:
    """Normalize one pattern spelling to a 1-D uint8 accept-mask array.

    Accepts the three spellings the query constructors accept -- a 1-D
    ``MatchQuery`` (its masks are taken verbatim; reduction/rows baggage
    is ignored), an IUPAC string, or a raw array (uint8 character codes
    0..3, lifted to one-hot masks like ``MatchQuery.exact``).  The
    PatternBank registers through this so every spelling freezes to the
    same canonical form.
    """
    if isinstance(pattern, MatchQuery):
        if len(pattern.shape) != 1:
            raise ValueError("standing patterns are single patterns; got a "
                             f"{pattern.shape} query")
        return np.array(pattern.masks)
    if isinstance(pattern, str):
        return _mask_array(encoding.encode_iupac(pattern))
    codes = np.asarray(pattern, np.uint8)
    if codes.ndim != 1:
        raise ValueError("pattern arrays must be 1-D uint8 codes")
    if codes.size and codes.max() > 3:
        raise ValueError(
            f"pattern codes must be < 4 (A=0 C=1 G=2 T=3); got max "
            f"{int(codes.max())}. Spell ambiguity as an IUPAC string or "
            "a 1-D MatchQuery")
    return _mask_array((np.uint8(1) << codes).astype(np.uint8))


_SHIM_DEFAULTS = dict(reduction="best", k=_DEFAULT_K, threshold=None,
                      rows=None, backend=None, mode=None, chunk_rows=None,
                      filter=None)
# Unset marker, distinct from every real default, so an *explicitly passed*
# default value (match(query, reduction="best")) still counts as a clash.
_UNSET = object()


def as_query(patterns, **kw) -> MatchQuery:
    """Kwarg-shim normalizer: codes array + legacy kwargs -> MatchQuery.

    Passing an existing ``MatchQuery`` forwards it unchanged; combining it
    with any keyword is rejected (the query is the single source of
    truth).  Shim callers (``MatchEngine.match`` & co.) forward only the
    kwargs their caller actually supplied, leaving the rest ``_UNSET``.
    """
    if isinstance(patterns, MatchQuery):
        clash = [name for name in _SHIM_DEFAULTS
                 if kw.get(name, _UNSET) is not _UNSET]
        if clash:
            raise ValueError(
                f"got a MatchQuery plus keyword overrides {clash}; build "
                "the overrides into the query (dataclasses.replace)")
        return patterns
    merged = dict(_SHIM_DEFAULTS)
    merged.update({k_: v for k_, v in kw.items() if v is not _UNSET})
    mode = merged.pop("mode")
    return MatchQuery.exact(patterns, mode=mode, **{
        name: merged[name] for name in
        ("reduction", "k", "threshold", "rows", "backend", "chunk_rows",
         "filter")})
