"""Sharded streaming match executor + query compiler (DESIGN.md Sec. 3c/3e).

Single entry point for all string-matching workloads: owns a
``PackedCorpus`` (device-resident, packed once), lowers declarative
``MatchQuery`` objects through the ``Planner`` into ``CompiledMatch``
programs (kernel choice + geometry + packed pattern operands, computed
once and LRU-cached by query content), then streams corpus row-chunks through
the chosen Pallas kernel with a fused per-chunk reduction, so the full
(R, L, Q) score tensor is never materialized unless explicitly requested.

The query IR (``repro.match.query``) is the paper's reconfigurable-logic
discipline at the API: the corpus never moves; a small compiled program
(the query) is shipped to it.  ``match(patterns, **kwargs)`` remains as a
thin shim that builds the query for you.

Reductions (fused per chunk):
  best      -- per-row argmax over alignments (the paper's host extract,
               Sec. 3.2): (R,[Q]) locs + scores.
  topk      -- global top-k rows by best score (running merge across
               chunks): which corpus rows match best.
  threshold -- all (row, loc[, q]) hits with score >= threshold.
  full      -- materialized score tensor (small problems / compat path).

Predicates: exact queries ride the XOR SWAR kernel / one-hot MXU matrix;
accept-set queries (IUPAC, N wildcards, character classes) ride the
bit-plane SWAR variant / multi-hot MXU matrix -- same resident corpus
forms either way.

Sharding (DESIGN.md Sec. 3h/3k): with a ``jax.sharding.Mesh`` the corpus
rows distribute over the mesh axes mapped by the ``rows`` logical axis
(``distributed.sharding``).  Device forms and q-gram signatures live in
the *cyclic physical layout* (logical row r -> shard r % S, slot r // S)
under a ``NamedSharding``; chunks slice per-shard slot blocks (no
cross-device traffic), kernels run under ``shard_map``, and reductions
merge **device-side** through ``repro.match.merge.ShardMerger`` --
shard-local maxima combine with collectives under ``shard_map`` and only
the final reduced state crosses to the host, bit-identical to the
single-shard result at any shard *and process* count.  That is the
direct analogue of the paper's array-level parallelism (Sec. 3.4:
arrays compute independently and exchange reduced state) and of Jun et
al.'s multi-engine fan-out, and it is what lets the same engine run
multi-host on ``jax.distributed`` (``repro.launch.cluster``), where
per-shard results on another host's devices cannot be pulled at all.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.core import encoding
from repro.core.tech import CostSource
from repro.distributed import sharding as _sharding
from repro.obs import Observability
from repro.kernels import filter_qgram as _fq
from repro.kernels import match_mxu as _mxu
from repro.kernels import match_swar as _swar
from repro.kernels import ref as _kref

from .corpus import PackedCorpus
from .feedback import kernel_key
from . import index as _ix
from . import merge as _merge
from .merge import ShardMerger
from .index import CorpusIndex, FilterOperands, build_query_filter
from .planner import FilterContext, Plan, Planner, kernel_name
from .query import _UNSET, MatchQuery, as_query


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclasses.dataclass
class MatchResult:
    """Outcome of one engine query (reduced unless ``scores`` requested)."""

    plan: Plan
    best_locs: np.ndarray                 # (R,) or (R, Q) int
    best_scores: np.ndarray               # (R,) or (R, Q) int32
    scores: Optional[np.ndarray] = None   # (R, L[, Q]) when reduction="full"
    topk_rows: Optional[np.ndarray] = None     # (k,[Q]) best-matching rows
    topk_scores: Optional[np.ndarray] = None
    hits: Optional[np.ndarray] = None     # (n, 3|4): row, loc[, q], score
    n_chunks: int = 0
    # Filtered execution (plan.strategy == "filter"): the verify stage ran
    # on these corpus rows only; per-row arrays (best_locs/best_scores)
    # cover survivors in ascending corpus-row order, while ``hits`` stays
    # bit-identical to a full scan (the zero-false-negative invariant).
    survivor_rows: Optional[np.ndarray] = None  # (n_surv,) corpus row ids
    survivor_frac: Optional[float] = None       # n_surv / live rows
    # Resolved mesh row shards the query executed over (1 = unsharded).
    n_shards: int = 1
    # Where cross-shard results combined: "device" (collectives under
    # shard_map; only reduced state crossed to the host) or "host"
    # (single shard -- nothing to merge).  ``collective_bytes`` is the
    # estimated per-link collective traffic this run moved (ring
    # all_gather model), the quantity the Planner prices.
    merge_path: str = "host"
    collective_bytes: int = 0
    # Per-stage wall-second breakdown (plan/pack/filter/launch/merge/
    # pull) from the span tree -- populated only when the engine's
    # tracer is enabled (None otherwise), and kept out of ``repr``:
    # results print compactly either way.
    timings: Optional[dict] = dataclasses.field(default=None, repr=False)


def _valid_mask(P: int, wp: int) -> np.ndarray:
    """(1, Wp) low-bit-of-lane mask of the P valid pattern positions."""
    mask_codes = np.zeros(wp * 16, np.uint32)
    mask_codes[:P] = 1
    return encoding.pack_codes_u32(mask_codes[None, :])


def _pack_patterns_swar(codes: np.ndarray, wp: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-pack (tiny) exact pattern words + valid mask (SWAR kernel)."""
    return encoding.pack_codes_u32(codes), _valid_mask(codes.shape[-1], wp)


def _pack_mask_planes(masks: np.ndarray, wp: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-pack accept masks into (Q, 4*Wp) uint32 bit-planes + valid mask.

    Plane c has the low bit of lane i set iff code c is accepted at
    pattern position i (``match_swar_masks`` layout).
    """
    planes = [encoding.pack_codes_u32(((masks >> c) & 1).astype(np.uint32))
              for c in range(4)]
    return (np.concatenate(planes, axis=-1),
            _valid_mask(masks.shape[-1], wp))


def _pack_patterns_mxu(masks: np.ndarray, p_chars: int, q_pad: int
                       ) -> np.ndarray:
    """Host-pack (tiny) multi-hot pattern matrix (p_chars*4, q_pad).

    Column q gets a 1 at (position i, channel c) iff code c is accepted at
    position i of pattern q -- one-hot for exact queries (bit-identical to
    the historical packing), multi-hot for accept-set predicates.  The MXU
    contraction itself is unchanged: wildcards are free here.
    """
    Q, P = masks.shape
    pat_mat = np.zeros((p_chars, 4, q_pad), np.float32)
    bits = (masks[:, :, None] >> np.arange(4, dtype=np.uint8)) & 1
    pat_mat[:P, :, :Q] = bits.astype(np.float32).transpose(1, 2, 0)
    return pat_mat.reshape(p_chars * 4, q_pad)


class CompiledMatch:
    """One ``MatchQuery`` lowered against one engine: reusable, growth-safe.

    Construction does all per-query host work exactly once -- mode
    resolution (pinned: see below), planning (kernel + geometry), pattern
    packing (SWAR words / bit-planes / MXU multi-hot matrix), row-subset
    validation and padding.  ``run()`` then streams the engine's *current*
    resident corpus through the lowered program, so one compiled query
    serves every later call and every corpus generation (``set_rows``
    content updates *and* ``append_rows`` growth) without re-packing.

    Growth protocol (DESIGN.md Sec. 3f): the query **mode** is resolved
    once at compile time against the compile-time row count and pinned --
    the "(Q, P) with Q == n_rows reads as per_row" inference can never
    silently flip meaning as rows are appended.  Plan *geometry* (row
    count, chunking, padded tiling) is revalidated per run when the live
    row count moved; the packed pattern operands are row-count-independent
    and survive, unless growth shifts the roofline to a different kernel,
    in which case only the (tiny) pattern operands are re-packed -- the
    resident corpus forms are never touched.  A pinned ``per_row`` query
    is geometry-bound to its compile-time row count and refuses to run
    after growth.  Obtain via ``MatchEngine.compile`` (cached by query
    content) and treat results as read-only.
    """

    __slots__ = ("engine", "query", "plan", "_packed", "_pats2d", "_sel",
                 "_idx", "_pad_idx", "_idx_stride", "_k_eff", "_k_vec",
                 "_thr_vec", "_empty", "_mode", "_lowered", "_filter_ops",
                 "_filter_dev", "_fb_version", "_sel_max")

    def __init__(self, engine: "MatchEngine", query: MatchQuery):
        self.engine = engine
        self.query = query
        corpus = engine.corpus

        sel = query.rows
        self._sel = None if sel is None else np.asarray(sel, np.int64)
        self._empty = self._sel is not None and self._sel.size == 0
        self._packed = self._pats2d = self._idx = self._pad_idx = None
        self._idx_stride = 0
        self._sel_max = -1
        self._k_eff, self._k_vec, self._thr_vec = 0, None, None
        self._filter_ops: Optional[FilterOperands] = None
        self._filter_dev = None
        self._lowered = False
        self._fb_version = engine.planner.feedback.version
        if self._empty:
            # A legal query whose answer is no rows; geometry is still
            # validated (pattern longer than fragment, empty pattern).
            self.plan = engine._empty_plan(query)
            self._mode = self.plan.mode
            return

        if self._sel is not None:
            if self._sel.min() < 0 or self._sel.max() >= corpus.n_rows:
                # jnp gathers clamp out-of-range indices silently; fail
                # loudly instead of returning the wrong rows' scores.
                raise IndexError(
                    f"rows must be in [0, {corpus.n_rows}), got "
                    f"[{self._sel.min()}, {self._sel.max()}]")
            self._sel_max = int(self._sel.max())
            R = len(self._sel)
            R_pad = -(-R // corpus.row_pad) * corpus.row_pad
            pad_idx = np.zeros(R_pad, np.int64)
            pad_idx[:R] = self._sel
            # Logical padded ids are stable across growth; the device
            # gather indices are layout-dependent (the cyclic stride moves
            # when a sharded corpus's capacity grows) and are rebuilt
            # lazily by run() when stale.
            self._pad_idx = pad_idx
            self._idx = engine._device_gather_idx(pad_idx)
            self._idx_stride = corpus.shard_stride

        n_rows = len(self._sel) if self._sel is not None else corpus.n_rows
        # Mode pinned at compile time, before any growth can happen.
        self._mode = engine._infer_mode(query, n_rows)
        if n_rows == 0:
            # Reserved-but-empty corpus: geometry is validated now (the
            # empty plan raises on bad patterns); lowering is deferred to
            # the first run that sees live rows.
            self.plan = engine._empty_plan(query, mode=self._mode)
            return
        self._lower(n_rows)

    def _lower(self, n_rows: int) -> None:
        """Plan + pack against ``n_rows`` corpus rows (pinned mode)."""
        engine, query = self.engine, self.query
        # Filter operands are row-count independent (query content + index
        # parameters only), exactly like the packed pattern operands: they
        # are built once, survive growth and strategy changes, and the
        # device upload happens once, lazily.  Only the plan decides
        # whether run() uses them.
        ctx, self._filter_ops = engine._filter_context(
            query, self._mode, ops=self._filter_ops)
        self.plan = engine._plan_query(query, n_rows, mode=self._mode,
                                       filter_ctx=ctx)
        self._fb_version = engine.planner.feedback.version
        plan = self.plan

        # Per-query reduction parameters (batched runs only).
        k_vec = np.asarray(query.k if query.k else (10,), np.int64)
        if k_vec.size != 1 and (plan.mode != "batched"
                                or k_vec.size != plan.n_patterns):
            raise ValueError("per-query k needs a batched query with one "
                             "entry per pattern")
        self._k_vec = k_vec
        self._k_eff = int(k_vec.max())
        thr_vec = None
        if query.reduction == "threshold":
            thr_vec = np.asarray(query.threshold, np.float64)
            if plan.mode == "batched":
                if thr_vec.size == 1:
                    thr_vec = np.full(plan.n_patterns, thr_vec[0])
                elif thr_vec.size != plan.n_patterns:
                    raise ValueError("per-query thresholds need one entry "
                                     "per pattern")
            elif thr_vec.size != 1:
                raise ValueError("per-query thresholds need a batched query")
        self._thr_vec = thr_vec

        # Pattern operands, packed once (the compile-time win: repeated
        # runs skip all host-side pattern work).
        masks2d = query.masks if len(query.shape) == 2 else \
            query.masks[None, :]
        if plan.predicate == "exact":
            codes = query.codes
            self._pats2d = codes if codes.ndim == 2 else codes[None, :]
        else:
            self._pats2d = masks2d
        if plan.backend == "swar":
            if plan.predicate == "accept":
                pat_rows, valid = _pack_mask_planes(masks2d, plan.wp)
            else:
                pat_rows, valid = _pack_patterns_swar(self._pats2d, plan.wp)
            if engine.merger.multiprocess:
                # Multi-controller: keep the (tiny) operands as host
                # arrays -- every process holds identical copies, and the
                # jitted shard_map dispatch places them per its in_specs.
                # A committed single-device upload could not be resharded
                # onto a mesh spanning other processes' devices.
                self._packed = (pat_rows, valid)
            else:
                # Upload once at compile time; run() chunks reuse the
                # resident device operands.
                self._packed = (jnp.asarray(pat_rows), jnp.asarray(valid))
        elif plan.backend == "mxu":
            mat = _pack_patterns_mxu(masks2d, plan.p_chars_pad, plan.q_pad)
            self._packed = (np.asarray(mat, jnp.bfloat16)
                            if engine.merger.multiprocess
                            else jnp.asarray(mat, jnp.bfloat16))
        else:
            self._packed = None
        self._lowered = True

    def _revalidate(self, n_rows: int) -> None:
        """Refresh plan geometry for a corpus whose live row count moved.

        Mode stays pinned; the packed pattern operands are row-count
        independent, so only the plan (chunking, padded row count, cost
        estimate) is recomputed -- unless the roofline now picks a
        different kernel, in which case the tiny pattern operands are
        re-packed too.  The resident corpus forms are untouched either
        way.  The filter strategy is re-decided here too (scale and
        measured selectivity move the two-stage tradeoff); the cached
        filter operands are row-count independent and passed back so only
        the survivor estimate refreshes.
        """
        ctx, self._filter_ops = self.engine._filter_context(
            self.query, self._mode, ops=self._filter_ops)
        new_plan = self.engine._plan_query(self.query, n_rows,
                                           mode=self._mode, filter_ctx=ctx)
        self._fb_version = self.engine.planner.feedback.version
        if new_plan.backend != self.plan.backend:
            self._lower(n_rows)
        else:
            self.plan = new_plan

    # -- execution ------------------------------------------------------------
    def run(self) -> MatchResult:
        """Execute against the engine's current corpus contents.

        Safe across corpus growth: geometry is revalidated when the live
        row count changed since the last run (see class docstring).  A
        ``plan.strategy == "filter"`` query runs the two-stage pipeline:
        the q-gram filter kernel prunes rows that provably cannot reach
        the threshold, then the survivors verify through the same gather
        machinery that serves explicit ``rows=`` subsets -- ``hits`` are
        bit-identical to the full scan by the conservativeness of the
        filter (DESIGN.md Sec. 3g).

        With the engine's tracer enabled the whole execution runs under
        a ``match.run`` span (plan / pack / filter / launch / merge /
        pull children) and the result carries the per-stage breakdown in
        ``timings``; disabled (the default) this wrapper is two branch
        instructions.
        """
        tr = self.engine.obs.tracer
        if not tr.enabled:
            return self._run()
        with tr.span("match.run",
                     {"reduction": self.query.reduction}) as root:
            res = self._run()
        res.timings = root.stage_seconds()
        return res

    def _note_plan(self, sp) -> None:
        """Planner-decision attributes onto an open ``plan`` span."""
        p = self.plan
        sp.set("kernel", kernel_name(p.backend, p.predicate))
        sp.set("strategy", p.strategy)
        sp.set("cost_source", p.cost_source)
        sp.set("est_seconds", p.est_seconds)
        sp.set("est_collective_bytes", p.est_collective_bytes)
        sp.set("n_rows", p.n_rows)
        sp.set("n_shards", p.n_shards)

    def _run(self) -> MatchResult:
        """The streaming executor behind ``run()`` (span-instrumented)."""
        if self._empty:
            return self.engine._empty_result(self.query, self.plan)
        engine, query = self.engine, self.query
        tr = engine.obs.tracer
        reduction = query.reduction
        sel = self._sel
        survivor_frac = None
        # Tombstone mask (windowed corpus, DESIGN.md Sec. 3j): dead rows
        # stay physically resident so the kernels run unchanged; the
        # reductions below mask them out on the host.  None when nothing
        # is dead -- the append-only fast path pays zero extra work.
        dead_full = (engine.corpus.dead_mask if engine.corpus.n_dead
                     else None)
        if sel is not None:
            with tr.span("plan") as sp_plan:
                if self._sel_max >= engine.corpus.n_rows:
                    # compact() shrank the live region below a row this
                    # subset names; the gather would silently clamp to a
                    # wrong row.
                    raise IndexError(
                        f"rows subset names row {self._sel_max} but the "
                        f"corpus now holds {engine.corpus.n_rows} live rows "
                        "(did compact() reclaim evicted rows?); recompile "
                        "with current row ids")
                R = len(sel)
                if (engine._row_shards > 1
                        and self._idx_stride != engine.corpus.shard_stride):
                    # Sharded capacity growth moved the cyclic stride: the
                    # logical ids are unchanged, re-derive their physical
                    # positions.
                    self._idx = engine._device_gather_idx(self._pad_idx)
                    self._idx_stride = engine.corpus.shard_stride
                if tr.enabled:
                    self._note_plan(sp_plan)
            idx, idx_log = self._idx, self._pad_idx
            R_pad = idx.shape[0]
        else:
            idx = idx_log = None
            R = engine.corpus.n_rows
            if R == 0:
                # Reserved-but-empty corpus: the answer is no rows (yet).
                return engine._empty_result(query, self.plan)
            R_pad = engine.corpus.n_rows_padded
            with tr.span("plan") as sp_plan:
                if not self._lowered:
                    self._lower(R)
                elif (self.plan.n_rows != R
                      or engine.planner.feedback.version != self._fb_version):
                    # Row count moved *or* the feedback store re-priced some
                    # bucket since this program was planned: either can flip
                    # the kernel or strategy choice, so re-plan (a backend
                    # flip re-packs only the tiny pattern operands).
                    self._revalidate(R)
                if tr.enabled:
                    self._note_plan(sp_plan)
            if self.plan.strategy == "filter":
                with tr.span("filter") as sp_fil:
                    t0 = time.perf_counter()
                    flags = engine._run_filter(self, R)
                    t_fil = time.perf_counter() - t0
                    sel = np.flatnonzero(flags).astype(np.int64)
                    if dead_full is not None:
                        # Tombstoned rows can survive the signature test
                        # but must not reach the verify stage (nor the
                        # hits).
                        sel = sel[~dead_full[sel]]
                    survivor_frac = len(sel) / R
                    if tr.enabled:
                        sp_fil.set("survivor_frac", survivor_frac)
                ops = self._filter_ops
                engine.index.record_selectivity(
                    engine.index.estimate_survivor_frac(
                        ops.n_bits, ops.slacks, calibrated=False),
                    survivor_frac)
                # Plan-vs-actual: one record per executed filter stage,
                # same key and same floats as the feedback observation
                # (computed once, handed to both sinks -- the registry is
                # pure accounting and records unconditionally).
                p0 = self.plan
                r_sh = -(-p0.n_rows // p0.n_shards)
                f_key = kernel_key("filter", r_sh, p0.filter_words,
                                   ops.qsig_words.shape[0])
                engine.obs.record_plan_actual(
                    f_key, p0.est_filter_base_seconds, t_fil)
                if engine.record_runtimes:
                    engine.planner.feedback.observe(
                        f_key, p0.est_filter_base_seconds, t_fil)
                if len(sel) == 0:
                    res = engine._empty_result(query, self.plan)
                    res.survivor_rows = sel
                    res.survivor_frac = 0.0
                    return res
                R = len(sel)
                R_pad = -(-R // engine.corpus.row_pad) * \
                    engine.corpus.row_pad
                pad_idx = np.zeros(R_pad, np.int64)
                pad_idx[:R] = sel
                idx_log = pad_idx
                idx = engine._device_gather_idx(pad_idx)
        plan = self.plan
        step = plan.chunk_rows
        S = engine._row_shards
        merger = engine.merger
        coll0 = merger.collective_bytes
        if S > 1:
            tile = _swar.ROW_TILE * S
            step = max(tile, (step // tile) * tile)
        # Resident sharded streaming: device forms are in the cyclic
        # physical layout, so per-chunk kernel output rows come back in
        # physical (shard-major) order; the merge layer un-permutes
        # *inside* its collective pulls.  Gather paths (rows= subsets,
        # filter survivors) already follow logical order -- the gather
        # indices are physical, their order is not -- and the ref backend
        # reads the logical host buffer directly.
        shard_phys = S > 1 and idx is None and plan.backend != "ref"

        best_l: List[np.ndarray] = []
        best_s: List[np.ndarray] = []
        full: List[np.ndarray] = []
        hit_rows: List[np.ndarray] = []
        topk_state = None                 # running global top-k (device)
        n_topk_alive = 0
        n_chunks = 0
        thr_vec = self._thr_vec
        thr_int = None
        if thr_vec is not None:
            # Integer-exact device threshold: scores are ints, so
            # s >= t  <=>  s >= ceil(t).  The device hot-mask compares
            # int32; the host recomputes final hits with the original
            # float threshold over the gathered block -- the two select
            # exactly the same set (no float32 rounding can differ).
            thr_int = np.clip(np.ceil(thr_vec), -(2 ** 31),
                              2 ** 31 - 1).astype(np.int32)

        t_scan0 = time.perf_counter()
        for c0 in range(0, R_pad, step):
            c1 = min(c0 + step, R_pad)
            valid = min(c1, R) - c0       # rows in this chunk that are real
            if valid <= 0:
                break                     # pure-padding tail chunk
            # The launch span measures kernel *dispatch* (JAX is async);
            # the device wait lands in the merge layer's pull spans.
            with tr.span("launch",
                         {"c0": c0, "rows": valid} if tr.enabled else None):
                scores = engine._chunk_scores(plan, self._pats2d, c0, c1,
                                              self._packed, idx, idx_log)
            n_chunks += 1
            # Per-chunk tombstone mask in logical row order (None when the
            # whole chunk is alive).
            alive = None
            if dead_full is not None:
                chunk_ids = (np.arange(c0, c0 + valid, dtype=np.int64)
                             if sel is None
                             else np.asarray(sel[c0:c0 + valid]))
                alive = ~dead_full[chunk_ids]
                if alive.all():
                    alive = None
            if reduction == "full":
                # Host materialization is the point of this reduction (the
                # one case where the whole block crosses); the pull
                # replicates + un-permutes device-side first.
                sc = merger.pull(scores, unpermute=shard_phys,
                                 kind="block")[:valid]
                if alive is not None:
                    # Dead rows report the -1 sentinel (scores are >= 0
                    # for live rows, so the sentinel is unambiguous).
                    sc = sc.copy()
                    sc[~alive] = -1
                full.append(sc)
                continue
            # Fused per-chunk reduction, jitted through the merge layer:
            # only reduced per-row state ever crosses to the host, and no
            # eager op touches a (possibly non-addressable) sharded array.
            bl, bs = merger.chunk_best(scores)
            bl_np = merger.pull(bl, unpermute=shard_phys)[:valid]
            bs_np = merger.pull(bs, unpermute=shard_phys)[:valid]
            if alive is not None:
                bl_np, bs_np = bl_np.copy(), bs_np.copy()
                bl_np[~alive] = 0
                bs_np[~alive] = -1        # dead-row best-score sentinel
            best_l.append(bl_np)
            best_s.append(bs_np)
            # topk / threshold report *corpus* row ids; with a rows= subset
            # that means mapping chunk positions through the selection.
            if reduction == "threshold":
                # Two-phase sparse pull (the per-chunk host-transfer fix):
                # first a per-row any-hit bitmap, then a device gather of
                # only the hot rows' score vectors -- the full (chunk, L
                # [, Q]) block never crosses to the host.
                hot = merger.hot_mask(scores, thr_int)
                hot_np = merger.pull(hot, unpermute=shard_phys)[:valid]
                if alive is not None:
                    hot_np = hot_np & alive
                hot_rows = np.flatnonzero(hot_np)
                if hot_rows.size == 0:
                    continue
                if shard_phys:
                    # Physical positions of the hot logical rows inside
                    # this chunk's shard-major layout.
                    jc = int(scores.shape[0]) // S
                    pos = (hot_rows % S) * jc + hot_rows // S
                else:
                    pos = hot_rows
                # Pad the gather to a power of two so hot-count jitter
                # doesn't recompile the gather every chunk.
                n_hot = pos.size
                pad_n = max(8, 1 << (int(n_hot) - 1).bit_length())
                pos_pad = np.zeros(pad_n, np.int64)
                pos_pad[:n_hot] = pos
                sc = merger.pull(merger.gather_rows(scores, pos_pad),
                                 kind="block")[:n_hot]
                if plan.mode == "batched":
                    local = np.argwhere(sc >= thr_vec[None, None, :])
                else:
                    local = np.argwhere(sc >= float(thr_vec[0]))
                if local.size:
                    vals = sc[tuple(local.T)]
                    # Hot rows are ascending, so argwhere order over the
                    # gathered block equals the full-block hit order.
                    rows_chunk = hot_rows[local[:, 0]]
                    local[:, 0] = (sel[rows_chunk + c0] if sel is not None
                                   else rows_chunk + c0)
                    hit_rows.append(np.concatenate(
                        [local, vals[:, None].astype(np.int64)], 1))
            elif reduction == "topk":
                # Device-side tree merge (ShardMerger): shard-local maxima
                # + all_gather + replicated lexsort, or -- on logical-order
                # paths -- a jitted sentinel merge.  Dead/padding rows ride
                # the (-1, ROW_SENTINEL) sentinel pair and sort last.
                if topk_state is None:
                    topk_state = merger.topk_init(
                        self._k_eff,
                        plan.n_patterns if plan.mode == "batched" else 0)
                n_bs = int(bs.shape[0])
                alive_chunk = np.zeros(n_bs, bool)
                alive_chunk[:valid] = True if alive is None else alive
                n_topk_alive += valid if alive is None else int(alive.sum())
                if shard_phys:
                    topk_state = merger.topk_update(
                        topk_state, bs, phys=True,
                        alive_chunk=alive_chunk, c0=c0)
                else:
                    rows_full = np.zeros(n_bs, np.int64)
                    rows_full[:valid] = (np.arange(c0, c0 + valid)
                                         if sel is None
                                         else sel[c0:c0 + valid])
                    topk_state = merger.topk_update(
                        topk_state, bs, phys=False,
                        alive_chunk=alive_chunk, rows_np=rows_full)

        if n_chunks:
            # Observed scan/verify-stage wall time vs. the feedback-free
            # estimate at the *actual* rows scanned (for a filtered run the
            # plan priced estimated survivors; recomputing at the measured
            # count keeps selectivity error out of the kernel-cost EWMA --
            # selectivity has its own feedback in CorpusIndex).  The ref
            # backend is priced at total rows, kernels per shard.  The
            # plan-vs-actual registry always gets the record; the feedback
            # store (which mutates future plans) only when enabled.
            r_price = R if plan.backend == "ref" else -(-R // plan.n_shards)
            base = engine.planner.backend_seconds(
                plan.backend, r_price, plan.n_locs, plan.pattern_chars,
                plan.n_patterns, plan.predicate, base=True)
            s_key = kernel_key(kernel_name(plan.backend, plan.predicate),
                               r_price, plan.pattern_chars, plan.n_patterns)
            t_scan = time.perf_counter() - t_scan0
            engine.obs.record_plan_actual(s_key, base, t_scan)
            if engine.record_runtimes:
                engine.planner.feedback.observe(s_key, base, t_scan)

        if reduction == "full":
            all_scores = np.concatenate(full, 0)
            return MatchResult(plan=plan, best_locs=all_scores.argmax(1),
                               best_scores=all_scores.max(1),
                               scores=all_scores, n_chunks=n_chunks,
                               n_shards=S, merge_path=merger.merge_path,
                               collective_bytes=merger.collective_bytes
                               - coll0)
        best_locs = np.concatenate(best_l, 0)
        best_scores = np.concatenate(best_s, 0)
        res = MatchResult(plan=plan, best_locs=best_locs,
                          best_scores=best_scores, n_chunks=n_chunks,
                          n_shards=S, merge_path=merger.merge_path)
        if survivor_frac is not None:
            res.survivor_rows = sel
            res.survivor_frac = survivor_frac
        if reduction == "threshold":
            width = 3 + (1 if plan.mode == "batched" else 0)
            res.hits = (np.concatenate(hit_rows, 0) if hit_rows
                        else np.zeros((0, width), np.int64))
        elif reduction == "topk":
            if topk_state is None or n_topk_alive == 0:
                # Every scanned row was tombstoned: a well-formed empty
                # top-k (matches the empty-subset result shape).
                shape0 = ((0, plan.n_patterns) if plan.mode == "batched"
                          else (0,))
                res.topk_rows = np.zeros(shape0, np.int64)
                res.topk_scores = np.zeros(shape0, np.int32)
            else:
                res.topk_rows, res.topk_scores = merger.topk_finalize(
                    topk_state, n_topk_alive, self._k_eff)
        res.collective_bytes = merger.collective_bytes - coll0
        return res

    __call__ = run


class MatchEngine:
    """Planner + packed corpus + query compiler + streaming executor.

    ``corpus`` may be a PackedCorpus or a raw (R, F) uint8 fragment matrix.
    ``mesh`` (optional) shards corpus rows over the mesh axes the ``rows``
    logical rule maps to; pass ``rules`` to use a non-default rule table.
    ``compile(query)`` is the primary API; ``match`` / ``scores`` are
    kwarg shims that build (and content-cache) the query for you.
    """

    def __init__(self, corpus: Union[PackedCorpus, np.ndarray], *,
                 planner: Optional[Planner] = None,
                 cost_source: Optional[CostSource] = None,
                 record_runtimes: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None, rules=None,
                 compile_cache_size: int = 128,
                 index: Union[bool, CorpusIndex] = True,
                 obs: Optional[Observability] = None):
        # Observability handle (DESIGN.md Sec. 3l): spans off by default
        # (and free when off); the metrics registry is always on -- it
        # only observes, never feeds back into plans, so it is safe at
        # any process count.  Shared with the corpus, index, merger, and
        # any MatchService/PatternBank built on this engine.
        self.obs = obs if obs is not None else Observability()
        n_row_slots = (corpus.capacity if isinstance(corpus, PackedCorpus)
                       else np.asarray(corpus).shape[0])
        if n_row_slots < 1:
            # Fail at construction, not deep inside the planner on the
            # first query ("corpus has no rows" with no context).  A
            # growable corpus with reserved capacity but no live rows yet
            # is fine: queries answer "no rows" until the first append.
            raise ValueError("MatchEngine needs a non-empty corpus: got 0 "
                             "fragment rows and no reserved capacity "
                             "(PackedCorpus(..., capacity=N) to start "
                             "empty)")
        self.mesh = mesh
        self.rules = rules
        self._row_shards = 1
        self._row_axes: Optional[Tuple[str, ...]] = None
        row_pad = _swar.ROW_TILE
        if mesh is not None:
            # warn=True: an indivisible row count silently replicating is
            # the invisible perf cliff of the satellite fix -- the caller
            # asked for a mesh and gets 1 shard; say so.
            r = _sharding.resolve_axis(
                "rows", -(-n_row_slots // _swar.ROW_TILE) * _swar.ROW_TILE,
                mesh, rules, warn=True)
            if r is not None:
                self._row_axes = r if isinstance(r, tuple) else (r,)
                self._row_shards = int(
                    np.prod([mesh.shape[a] for a in self._row_axes]))
                row_pad = _swar.ROW_TILE * self._row_shards
        if isinstance(corpus, PackedCorpus):
            self.corpus = corpus
        else:
            self.corpus = PackedCorpus(np.asarray(corpus, np.uint8),
                                       row_pad=row_pad)
        # Pack/splice/compact spans record into this engine's tracer
        # (engines sharing a corpus share whichever was attached last).
        self.corpus.obs = self.obs
        # Configure the cyclic row layout + NamedSharding placement (a
        # no-op when the corpus already has this exact layout).
        self.corpus.shard_rows(
            mesh if self._row_shards > 1 else None,
            self._row_axes if self._row_axes is None or
            len(self._row_axes) > 1 else self._row_axes[0],
            self._row_shards)
        # Cross-shard merge layer (DESIGN.md Sec. 3k): every reduction
        # and host pull routes through it, so cross-shard combines run
        # device-side under shard_map and work at any process count.
        self.merger = ShardMerger(
            self.mesh if self._row_shards > 1 else None,
            self._row_axes, self._row_shards, obs=self.obs)
        # Jitted multi-controller launch cache (keyed by kernel + shape
        # geometry): a fresh jit per chunk would retrace every call.
        self._mp_cache: dict = {}
        if planner is None:
            planner = Planner(cost_source=cost_source)
        elif cost_source is not None:
            planner.cost_source = cost_source
        self.planner = planner
        # Runtime feedback (DESIGN.md Sec. 3i): record observed per-launch
        # wall times into the planner's FeedbackStore so drifted (kernel,
        # shape-bucket) estimates get re-priced online.  Default: on when
        # the source is calibrated (feedback is the serving half of that
        # discipline), off for the static fallback -- whose decisions are
        # a deterministic baseline that must not drift mid-session.
        if record_runtimes is None:
            # Multi-controller: per-process wall clocks differ, so
            # feedback re-pricing would drift the SPMD plans apart across
            # processes (divergent plans mean divergent collective
            # programs -- a hang).  Default off beyond one process.
            record_runtimes = (self.planner.cost_source.name != "static"
                               and jax.process_count() == 1)
        self.record_runtimes = bool(record_runtimes)
        self.interpret = default_interpret() if interpret is None else interpret
        self.compile_cache_size = int(compile_cache_size)
        self._compiled: "OrderedDict[MatchQuery, CompiledMatch]" = \
            OrderedDict()
        # Q-gram filter index (DESIGN.md Sec. 3g): attached up front (the
        # signature pack itself is lazy, so an engine that never runs a
        # filtered query pays nothing); ``index=False`` disables the
        # two-stage strategy, a ``CorpusIndex`` instance overrides the
        # default (q, n_bits) configuration.
        if isinstance(index, CorpusIndex):
            if index.corpus is not self.corpus:
                raise ValueError("index is attached to a different corpus")
            self.index: Optional[CorpusIndex] = index
        elif index and self.corpus.fragment_chars >= _ix.DEFAULT_Q:
            # Engines sharing a corpus share its index (and its resident
            # signatures + selectivity calibration) instead of stacking a
            # fresh observer per engine.
            self.index = next(
                (ix for ix in self.corpus._indexes
                 if isinstance(ix, CorpusIndex)), None) \
                or CorpusIndex(self.corpus)
        else:
            self.index = None

    def __repr__(self) -> str:
        c = self.corpus
        axes = (None if self._row_axes is None else
                ",".join(self._row_axes))
        return (f"MatchEngine(rows={c.n_rows}, capacity={c.capacity}, "
                f"shards={self._row_shards}"
                + (f" over {axes}" if axes else "")
                + f", interpret={self.interpret}"
                + f", cost={self.planner.cost_source.tag})")

    @property
    def n_shards(self) -> int:
        """Resolved mesh row shards (1 when unsharded or replicated)."""
        return self._row_shards

    def shard_live_rows(self) -> np.ndarray:
        """(S,) live rows per shard (cyclic layout: balanced to +-1 row)."""
        return self.corpus.shard_live_rows

    def _device_gather_idx(self, pad_idx: np.ndarray) -> np.ndarray:
        """Gather indices (host array) for logical padded row ids.

        Sharded forms store row r at physical position (r % S) * J +
        r // S; gathers must address that layout.  The gather *output*
        follows the order of ``pad_idx`` (logical query order), so
        downstream reductions never see physical order on this path.
        Kept as a host array: identical on every process, handed to the
        (jitted) gather at dispatch time.
        """
        return _sharding.cyclic_physical_rows(
            pad_idx, self._row_shards, self.corpus.shard_stride)

    # -- compilation ----------------------------------------------------------
    def compile(self, query: MatchQuery, *,
                cached: bool = True) -> CompiledMatch:
        """Lower a query once (plan + pack); LRU-cached by query content.

        The returned ``CompiledMatch`` is reusable across calls and corpus
        generations -- the warm path pays zero planning or pattern-packing
        work.  ``cached=False`` forces a fresh lowering (benchmarks use it
        to measure exactly that work).
        """
        if not isinstance(query, MatchQuery):
            raise TypeError("compile() takes a MatchQuery; use "
                            "MatchQuery.exact/from_masks/iupac or the "
                            "match(patterns, ...) shim")
        if cached:
            hit = self._compiled.get(query)
            if hit is not None:
                self._compiled.move_to_end(query)
                return hit
        cm = CompiledMatch(self, query)
        if cached:
            self._compiled[query] = cm
            while len(self._compiled) > self.compile_cache_size:
                self._compiled.popitem(last=False)
        return cm

    # -- planning -------------------------------------------------------------
    def _infer_mode(self, query: MatchQuery, n_rows: int) -> str:
        ndim = len(query.shape)
        if ndim == 1:
            return "shared"
        mode = query.mode
        if mode is not None:
            if mode == "per_row" and query.shape[0] != n_rows:
                raise ValueError(
                    "per_row patterns must have one row per corpus row: "
                    f"got {query.shape[0]} pattern rows for {n_rows} live "
                    "rows (did the corpus grow since the query was "
                    "compiled?)")
            return mode
        # (Q, P) with Q == n_rows is ambiguous; resolve like the historical
        # ops API: the mxu kernel is inherently batched, everything else
        # reads a row-count match as per-row.  Pass mode= to be explicit.
        # CompiledMatch pins this resolution at compile time, so appends
        # can never flip an inferred per_row into batched (or vice versa).
        if query.backend == "mxu":
            return "batched"
        return "per_row" if query.shape[0] == n_rows else "batched"

    def _plan_query(self, query: MatchQuery, n_rows: int,
                    mode: Optional[str] = None,
                    filter_ctx: Optional[FilterContext] = None) -> Plan:
        if mode is None:
            mode = self._infer_mode(query, n_rows)
        elif mode == "per_row" and query.shape[0] != n_rows:
            raise ValueError(
                f"per_row query compiled for {query.shape[0]} corpus rows "
                f"cannot run against {n_rows} live rows; per_row queries "
                "are geometry-bound to their compile-time corpus -- "
                "recompile with one pattern per current corpus row")
        topk_k = 0
        if query.reduction == "topk":
            kv = np.asarray(query.k if query.k else (10,), np.int64)
            topk_k = int(kv.max()) if kv.size else 10
        return self.planner.plan(
            n_rows=n_rows,
            fragment_chars=self.corpus.fragment_chars,
            pattern_chars=query.pattern_chars,
            n_patterns=query.n_patterns if mode == "batched" else None,
            per_row=mode == "per_row", backend=query.backend,
            chunk_rows=query.chunk_rows, predicate=query.predicate,
            filter_ctx=filter_ctx, n_shards=self._row_shards,
            reduction=query.reduction, topk_k=topk_k)

    # -- q-gram filter stage (DESIGN.md Sec. 3g) ------------------------------
    def _filter_context(self, query: MatchQuery, mode: Optional[str],
                        ops: Optional[FilterOperands] = None
                        ) -> Tuple[Optional[FilterContext],
                                   Optional[FilterOperands]]:
        """Filter eligibility + pricing inputs + operands for one query.

        Returns ``(None, None)`` when the two-stage strategy is not legal:
        the filter prunes whole rows, so only the row-sparse ``threshold``
        reduction (whose deliverable, ``hits``, provably loses nothing to
        conservative pruning) qualifies; explicit row subsets keep their
        own gather path; per-row patterns have no shared signature.
        Sharded engines participate like single-shard ones (the signature
        form mirrors the corpus layout and the filter kernel runs per
        shard under shard_map).  Ineligible or unprunable queries simply
        scan -- the filter is an optimization, never a semantic change.

        ``ops`` short-circuits the operand build: the operands derive
        from (query content, index q, index B) only, so a caller holding
        them from an earlier lowering (CompiledMatch revalidating across
        growth) passes them back and only the survivor estimate -- which
        tracks measured density and selectivity -- is refreshed.
        """
        if query.filter is True and self._row_shards > 1:
            # Sharded engines must never *silently* drop filter=True to a
            # full scan (the pre-Sec.-3h engine did exactly that): when
            # the forced strategy is structurally impossible, say so.
            why = None
            if self.index is None:
                why = "no CorpusIndex is attached (index=False)"
            elif query.rows_b is not None:
                why = "row-subset queries keep their own gather path"
            elif mode == "per_row":
                why = "per-row patterns have no shared signature"
            elif query.pattern_chars < self.index.q:
                why = (f"pattern ({query.pattern_chars} chars) is shorter "
                       f"than the index q-gram (q={self.index.q})")
            if why is not None:
                raise ValueError(
                    f"sharded engine cannot honor filter=True: {why}; "
                    "pass filter=None to let the planner decide or "
                    "filter=False to scan")
        if (self.index is None or query.filter is False
                or query.reduction != "threshold"
                or query.rows_b is not None or mode == "per_row"
                or query.pattern_chars < self.index.q):
            return None, None
        masks2d = query.masks if len(query.shape) == 2 else \
            query.masks[None, :]
        if ops is None:
            thr = query.threshold
            if len(thr) == 1 and masks2d.shape[0] > 1:
                thr = thr * masks2d.shape[0]
            ops = build_query_filter(masks2d, thr, self.index.q,
                                     self.index.n_bits)
        # A query whose slack covers all its required bits passes every
        # row (so does one with no fully-exact q-grams): with a survivor
        # union, one such member makes the whole filter pointless.
        # Prunability is content-derived and never changes across growth,
        # so the operands are still returned (and cached by the caller) --
        # a held unprunable query must not rebuild them on every
        # revalidation just to re-learn it scans.
        prunable = all(s < 0 or (b > 0 and s < b)
                       for b, s in zip(ops.n_bits, ops.slacks))
        if not prunable:
            return None, ops
        frac = self.index.estimate_survivor_frac(ops.n_bits, ops.slacks)
        ctx = FilterContext(sig_words=self.index.sig_words,
                            n_queries=masks2d.shape[0], prunable=True,
                            survivor_frac=frac,
                            force=query.filter is True)
        return ctx, ops

    def _run_filter(self, cm: CompiledMatch, n_rows: int) -> np.ndarray:
        """Filter stage: (n_rows,) bool candidate flags for one query.

        One ``filter_qgram`` dispatch per pattern; a row survives if any
        pattern's test admits it (the batched union).  Signatures stream
        from the device-resident index -- the exact scan's data is never
        touched for pruned rows.

        Sharded engines run the kernel per shard under ``shard_map`` over
        the sharded signature form: each shard tests its own rows (the
        q-gram lemma is a per-row property, so it holds per shard), the
        per-pattern union happens device-side, and the cross-shard
        survivor union is a device all_gather + un-permute through the
        merge layer -- the host receives only the final replicated
        bitmap, at any process count.
        """
        ops = cm._filter_ops
        merger = self.merger
        if cm._filter_dev is None:
            # Multi-controller: keep the tiny query signatures as host
            # arrays (identical everywhere); the jitted dispatch places
            # them replicated per its in_specs.
            cm._filter_dev = (np.asarray(ops.qsig_words)
                              if merger.multiprocess
                              else jnp.asarray(ops.qsig_words))
        sigs = self.index.signatures()
        tile = _fq.FILTER_ROW_TILE
        S = self._row_shards
        if S == 1:
            r_pad = -(-n_rows // tile) * tile
            rows = sigs[:r_pad]
            flags = None
            for qi in range(ops.qsig_words.shape[0]):
                f = _fq.filter_qgram(rows, cm._filter_dev[qi:qi + 1],
                                     slack=ops.slacks[qi],
                                     interpret=self.interpret)
                flags = f if flags is None else flags | f
            return np.asarray(flags)[:n_rows, 0].astype(bool)
        # Per-shard live extent: shard 0 holds ceil(n/S) live rows, pad it
        # to the filter tile; slicing [:jn] per shard block is collective-
        # free (same reshape trick as the match chunks).
        jf = sigs.shape[0] // S
        jn = min(jf, -(-(-(-n_rows // S)) // tile) * tile)
        if merger.multiprocess:
            rows = _merge._resident_slicer(S, jf, 0, jn, sigs.shape[1])(sigs)
        else:
            rows = sigs.reshape(S, jf, sigs.shape[1])[:, :jn].reshape(
                S * jn, sigs.shape[1])
        flags = None
        for qi in range(ops.qsig_words.shape[0]):
            def call(r, q, _slack=ops.slacks[qi]):
                return _fq.filter_qgram(r, q, slack=_slack,
                                        interpret=self.interpret)
            f = self._shard_wrap(
                call, PartitionSpec(None, None),
                cache_key=("filter", ops.slacks[qi], rows.shape,
                           cm._filter_dev.shape))(
                rows, cm._filter_dev[qi:qi + 1])
            flags = f if flags is None else merger.or_(flags, f)
        return merger.survivor_union(flags, n_rows)

    def plan(self, patterns, *, backend=_UNSET, mode=_UNSET, rows=_UNSET,
             chunk_rows=_UNSET) -> Plan:
        """Plan without executing (kwarg shim over ``_plan_query``)."""
        query = as_query(patterns, backend=backend, mode=mode, rows=rows,
                         chunk_rows=chunk_rows)
        n_rows = (len(query.rows) if query.rows is not None
                  else self.corpus.n_rows)
        return self._plan_query(query, n_rows)

    # -- kernel dispatch (one chunk, pure device) -----------------------------
    def _shard_wrap(self, call, pat_spec=None, cache_key=None):
        if self.mesh is None or self._row_axes is None:
            return call
        from jax.experimental.shard_map import shard_map
        if self.merger.multiprocess and cache_key is not None:
            hit = self._mp_cache.get(cache_key)
            if hit is not None:
                return hit
        spec = PartitionSpec(self._row_axes if len(self._row_axes) > 1
                             else self._row_axes[0])
        fn = shard_map(call, mesh=self.mesh,
                       in_specs=(spec, spec if pat_spec is None
                                 else pat_spec),
                       out_specs=spec, check_rep=False)
        if self.merger.multiprocess:
            # Multi-controller: eager dispatch on global arrays is not
            # generally supported -- stage the whole launch through jit
            # (host-array operands get placed per the in_specs).
            fn = jax.jit(fn)
            if cache_key is not None:
                self._mp_cache[cache_key] = fn
        return fn

    def _swar_chunk(self, words: jnp.ndarray, pat_rows: jnp.ndarray,
                    mask: jnp.ndarray, plan: Plan) -> jnp.ndarray:
        if plan.predicate == "accept":
            def call(w, p):
                return _swar.match_swar_masks(
                    w, p, mask, n_locs=plan.n_locs,
                    pattern_chars=plan.pattern_chars,
                    interpret=self.interpret)
        else:
            def call(w, p):
                return _swar.match_swar(w, p, mask, n_locs=plan.n_locs,
                                        pattern_chars=plan.pattern_chars,
                                        interpret=self.interpret)
        return self._shard_wrap(call)(words, pat_rows)

    def _swar_chunk_mp(self, words, pat_rows, mask, plan: Plan):
        """Multi-controller SWAR dispatch: one jitted shard_map launch.

        The (tiny, replicated) host pattern operands enter with a
        replicated spec and broadcast to each shard's block *inside* the
        body -- an eager full-size broadcast would be a committed local
        array that cannot be resharded onto other processes' devices.
        Shared-pattern queries only: per-row and batched SWAR layouts
        interleave pattern rows across shards (tile/repeat on a sharded
        chunk), which has no multi-process lowering yet.
        """
        if plan.mode in ("per_row", "batched"):
            raise NotImplementedError(
                f"{plan.mode} SWAR queries are not supported on a "
                "multi-process mesh (shared-pattern queries and the "
                "batched MXU backend are); use backend=\"mxu\" or run "
                "the patterns as separate queries")
        key = ("swar_mp", plan.predicate, plan.n_locs, plan.pattern_chars,
               tuple(words.shape), tuple(np.shape(pat_rows)))
        fn = self._mp_cache.get(key)
        if fn is None:
            from jax.experimental.shard_map import shard_map
            spec = PartitionSpec(self._row_axes if len(self._row_axes) > 1
                                 else self._row_axes[0])
            rep = PartitionSpec(None, None)
            kern = (_swar.match_swar_masks if plan.predicate == "accept"
                    else _swar.match_swar)

            def call(w, p, m):
                pr = jnp.broadcast_to(p[0][None, :],
                                      (w.shape[0], p.shape[1]))
                return kern(w, pr, m, n_locs=plan.n_locs,
                            pattern_chars=plan.pattern_chars,
                            interpret=self.interpret)

            fn = jax.jit(shard_map(call, mesh=self.mesh,
                                   in_specs=(spec, rep, rep),
                                   out_specs=spec, check_rep=False))
            self._mp_cache[key] = fn
        return fn(words, np.asarray(pat_rows), np.asarray(mask))

    def _mxu_chunk(self, ref_flat: jnp.ndarray, pat_mat: jnp.ndarray,
                   plan: Plan) -> jnp.ndarray:
        mp = self.merger.multiprocess

        def call(r, p):
            out = _mxu.match_mxu(r, p, l_pad=plan.l_pad,
                                 interpret=self.interpret)
            if mp:
                # Fold the round/slice into the staged launch: no eager
                # op may touch the sharded output multi-controller.  The
                # arithmetic is identical to the host-side epilogue.
                out = jnp.round(out[:, :plan.n_locs, :plan.n_patterns]
                                ).astype(jnp.int32)
                if plan.mode != "batched":
                    out = out[:, :, 0]
            return out
        return self._shard_wrap(
            call, PartitionSpec(None, None),
            cache_key=("mxu", plan.l_pad, plan.n_locs, plan.n_patterns,
                       plan.mode, tuple(ref_flat.shape),
                       tuple(np.shape(pat_mat))))(ref_flat, pat_mat)

    def _slice_resident(self, base: jnp.ndarray, c0: int,
                        c1: int) -> jnp.ndarray:
        """Rows [c0, c1) of a resident form, in its own layout.

        Unsharded: a plain slice.  Sharded: logical rows [c0, c1) are
        slots [c0/S, c1/S) *on every shard* under the cyclic layout, so
        the chunk is a per-shard block slice -- reshape (S, J, w), slice
        the slot axis, reshape back -- which XLA lowers without any
        cross-device movement (the chunk stays sharded like the form).
        The result is in physical (shard-major) order; ``run()``
        un-permutes after the kernel.
        """
        S = self._row_shards
        if S == 1:
            return base[c0:c1]
        j = base.shape[0] // S
        if self.merger.multiprocess:
            # Jitted (cached by geometry): the eager reshape would touch
            # non-addressable shards.
            return _merge._resident_slicer(S, j, c0 // S, c1 // S,
                                           base.shape[1])(base)
        return base.reshape(S, j, base.shape[1])[:, c0 // S:c1 // S].reshape(
            c1 - c0, base.shape[1])

    def _chunk_scores(self, plan: Plan, pats2d: np.ndarray, c0: int,
                      c1: int, packed, idx: Optional[jnp.ndarray],
                      idx_log: Optional[np.ndarray] = None) -> jnp.ndarray:
        """Scores for query rows [c0, c1): (rows, L) or (rows, L, Q).

        ``pats2d`` is the 2-D pattern operand for the ref backend -- codes
        for exact plans, accept masks for accept plans.  ``idx`` (padded
        *physical* gather indices) is set for row-subset queries: the
        chunk is gathered from the resident device forms instead of
        sliced -- still no host repacking; ``idx_log`` carries the same
        rows as logical ids for the host-side ref backend.  Resident
        sharded chunks come back in physical order (see
        ``_slice_resident``).
        """
        if plan.backend == "ref":
            if idx is not None:
                sel = idx_log[c0:min(c1, plan.n_rows)]
                frags = jnp.asarray(self.corpus.fragments[sel])
            else:
                frags = jnp.asarray(self.corpus.fragments[c0:min(c1,
                                    self.corpus.n_rows)])
            fn = (_kref.match_scores_masks_ref if plan.predicate == "accept"
                  else _kref.match_scores_ref)
            if plan.mode == "batched":
                outs = [fn(frags, pats2d[q]) for q in range(plan.n_patterns)]
                return jnp.stack(outs, -1)
            pats = pats2d[c0:c1] if plan.mode == "per_row" else pats2d
            return fn(frags, pats)

        if plan.backend == "swar":
            base = self.corpus.swar_words(plan.need_words)
            if idx is not None:
                # Cross-shard gather: device-side (replicated output)
                # multi-controller, plain fancy-index otherwise.
                words = (self.merger.gather_rows(base, idx[c0:c1])
                         if self.merger.multiprocess else base[idx[c0:c1]])
            else:
                words = self._slice_resident(base, c0, c1)
            pat_rows, mask = packed
            if self.merger.multiprocess:
                return self._swar_chunk_mp(words, pat_rows, mask, plan)
            pat_rows = jnp.asarray(pat_rows)   # (Q, Wp) words or (Q, 4*Wp)
            mask = jnp.asarray(mask)
            if plan.mode == "per_row":
                r_pad = words.shape[0]
                rows = pat_rows[c0:min(c1, pat_rows.shape[0])]
                if rows.shape[0] < r_pad:
                    rows = jnp.concatenate(
                        [rows, jnp.zeros((r_pad - rows.shape[0],
                                          rows.shape[1]), jnp.uint32)], 0)
                if idx is None and self._row_shards > 1:
                    # Resident chunk rows are physical: permute the per-row
                    # patterns the same way so row i still meets pattern i.
                    rows = _sharding.cyclic_permute(rows, self._row_shards)
                return self._swar_chunk(words, rows, mask, plan)
            if plan.mode == "batched":
                # Fused batched launch: tile the chunk Q times and ride
                # each pattern as a per-row pattern -- one kernel dispatch
                # for all Q queries (the lock-step multi-pattern search of
                # the paper's Sec. 3.4) instead of a Q-pass Python loop.
                Q = plan.n_patterns
                Rc = words.shape[0]
                words_t = jnp.tile(words, (Q, 1))
                pw_t = jnp.repeat(pat_rows, Rc, axis=0)
                out = self._swar_chunk(words_t, pw_t, mask, plan)
                return out.reshape(Q, Rc, plan.n_locs).transpose(1, 2, 0)
            pw = jnp.broadcast_to(pat_rows[0][None, :],
                                  (words.shape[0], pat_rows.shape[1]))
            return self._swar_chunk(words, pw, mask, plan)

        # mxu
        base = self.corpus.onehot_flat(plan.f_chars)
        if idx is not None:
            ref_flat = (self.merger.gather_rows(base, idx[c0:c1])
                        if self.merger.multiprocess else base[idx[c0:c1]])
        else:
            ref_flat = self._slice_resident(base, c0, c1)
        out = self._mxu_chunk(ref_flat, packed, plan)
        if self.merger.multiprocess:
            return out                    # epilogue folded into the launch
        scores = jnp.round(out[:, :plan.n_locs, :plan.n_patterns]
                           ).astype(jnp.int32)
        return scores[:, :, 0] if plan.mode != "batched" else scores

    # -- empty subsets --------------------------------------------------------
    def _empty_plan(self, query: MatchQuery,
                    mode: Optional[str] = None) -> Plan:
        """Zero-row plan for a query with no rows to scan (geometry checked).

        The planner (rightly) refuses zero-row workloads and the streaming
        loop would otherwise ``np.concatenate`` empty chunk lists; an empty
        row subset -- or a reserved-but-still-empty growable corpus -- is a
        legal query whose answer is simply no rows.  ``mode`` carries the
        pinned compile-time resolution when the caller has one.
        """
        P = query.pattern_chars
        F = self.corpus.fragment_chars
        if P < 1:
            raise ValueError("pattern must have at least one character")
        L = F - P + 1
        if L <= 0:
            raise ValueError("pattern longer than fragment")
        if len(query.shape) == 1:
            mode, Q = "shared", 1
        else:
            if mode is None:
                mode = query.mode if query.mode is not None else "batched"
            Q = query.n_patterns
        return Plan(backend="ref", mode=mode, n_rows=0, fragment_chars=F,
                    pattern_chars=P, n_patterns=Q if mode == "batched"
                    else 1, n_locs=L, chunk_rows=0,
                    reason="empty row subset", predicate=query.predicate)

    def _empty_result(self, query: MatchQuery, plan: Plan) -> MatchResult:
        """Well-formed all-empty MatchResult for a zero-row subset query."""
        batched = plan.mode == "batched"
        Q = plan.n_patterns
        shape0 = (0, Q) if batched else (0,)
        res = MatchResult(plan=plan,
                          best_locs=np.zeros(shape0, np.int32),
                          best_scores=np.zeros(shape0, np.int32),
                          n_shards=self._row_shards,
                          merge_path=self.merger.merge_path)
        if query.reduction == "full":
            res.scores = np.zeros((0, plan.n_locs, Q) if batched
                                  else (0, plan.n_locs), np.int32)
        elif query.reduction == "topk":
            res.topk_rows = np.zeros(shape0, np.int32)
            res.topk_scores = np.zeros(shape0, np.int32)
        elif query.reduction == "threshold":
            res.hits = np.zeros((0, 4 if batched else 3), np.int64)
        return res

    # -- execution ------------------------------------------------------------
    def match(self, patterns, *, backend=_UNSET, mode=_UNSET, rows=_UNSET,
              reduction=_UNSET, k=_UNSET, threshold=_UNSET,
              chunk_rows=_UNSET, filter=_UNSET) -> MatchResult:
        """Run one query; see module docstring for reductions.

        ``patterns`` is either a ``MatchQuery`` (the declarative API; any
        explicit kwarg alongside it is rejected) or a uint8 code array --
        (P,) shared, (R, P) per-row, (Q, P) batched -- with the legacy
        kwargs (defaults: reduction="best", k=10), which this shim folds
        into a ``MatchQuery`` and compiles (content-cached, so repeated
        calls hit the warm path).  ``rows`` restricts the query to a
        subset of corpus rows (device gather from the resident forms;
        results are in subset order; an empty subset yields an all-empty
        result).  ``threshold`` is in characters (absolute score).  In
        batched mode ``k`` and ``threshold`` may be per-query sequences of
        length Q (the top-k merge runs at max(k); slice
        ``topk_rows[:k_q, q]`` per query).
        """
        query = as_query(patterns, backend=backend, mode=mode, rows=rows,
                         reduction=reduction, k=k, threshold=threshold,
                         chunk_rows=chunk_rows, filter=filter)
        return self.compile(query).run()

    def scores(self, patterns, *, backend=_UNSET, mode=_UNSET, rows=_UNSET,
               chunk_rows=_UNSET) -> np.ndarray:
        """Full materialized score tensor (compat path for small problems)."""
        query = as_query(patterns, backend=backend, mode=mode, rows=rows,
                         chunk_rows=chunk_rows)
        query = dataclasses.replace(query, reduction="full", k=(),
                                    threshold=None)
        return self.match(query).scores
