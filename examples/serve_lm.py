"""Serving example: continuous-batching engine + CRAM-PM n-gram speculator.

Boots a reduced model, serves a wave of requests through slot-based
batched decode, then demonstrates the paper's matcher as a prompt-cache /
n-gram speculative proposer over the generated streams.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serving.engine import Engine, Request, generate_greedy
from repro.serving.ngram_cache import NgramSpeculator, verify


def main() -> None:
    cfg = get_config("llama3.2-1b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print("== batched greedy generation ==")
    prompts = rng.integers(0, cfg.vocab, (4, 8), dtype=np.int32)
    t0 = time.perf_counter()
    out = generate_greedy(cfg, params, prompts, max_new=24, max_seq=64)
    dt = time.perf_counter() - t0
    print(f"generated {out.size} tokens in {dt:.2f}s "
          f"({out.size/dt:.0f} tok/s); first row: {out[0][:10].tolist()}...")

    print("\n== continuous-batching engine ==")
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
                    max_new=16) for _ in range(6)]
    eng = Engine(cfg, params, max_seq=64, n_slots=3)
    t0 = time.perf_counter()
    eng.run(list(reqs))
    dt = time.perf_counter() - t0
    done = sum(len(r.out) for r in reqs)
    print(f"6 requests through 3 slots: {done} tokens in {dt:.2f}s")

    print("\n== n-gram speculation over generated history ==")
    spec = NgramSpeculator(suffix_tokens=4)
    for r in reqs:
        spec.feed(r.out)
    hits = total = 0
    for r in reqs:
        for t in range(4, len(r.out) - 4, 4):
            prop, conf = spec.propose(r.out[t - 4:t], k=4)
            if conf == 1.0:
                hits += verify(prop, np.asarray(r.out[t:t + 4]))
                total += 4
    if total:
        print(f"speculative acceptance on replayed streams: {hits}/{total} "
              f"({hits/total:.0%})")


if __name__ == "__main__":
    main()
