"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Recurrence: with r_t = sigma(W_a x_t + b_a), i_t = sigma(W_x x_t + b_x),

    log a_t = -c * softplus(Lambda) * r_t
    h_t     = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill runs the recurrence as a ``jax.lax.associative_scan`` over
time (log-depth, shardable); decode is the O(1) per-step update.  The full
recurrent block is: x -> [linear -> conv1d(4) -> RG-LRU] * gelu(linear) ->
out projection.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import COMPUTE_DTYPE
from .spec import P
from .ssm import _causal_conv


def rglru_specs(cfg: ModelConfig) -> Dict[str, P]:
    d = cfg.d_model
    r = cfg.rnn_width or d
    nb = cfg.rglru_block_diag
    if nb:
        # Block-diagonal gates: blocks shard over the model axis, so the
        # whole branch (projection -> conv -> gates -> recurrence) stays
        # within one shard -- no activation collectives until wo.
        gate = lambda: P((nb, r // nb, r // nb), ("ff", None, None))
    else:
        gate = lambda: P((r, r), ("ff", None))
    return {
        "wx": P((d, r), ("embed", "ff")),
        "wy": P((d, r), ("embed", "ff")),
        "conv": P((4, r), (None, "ff"), "normal"),
        "w_a": gate(),
        "b_a": P((r,), ("ff",), "zeros"),
        "w_i": gate(),
        "b_i": P((r,), ("ff",), "zeros"),
        "lam": P((r,), ("ff",), "ones"),
        "wo": P((r, d), ("ff", "embed")),
    }


def _gate_matmul(cfg: ModelConfig, x, w):
    """x (B,S,r) @ w, dense or block-diagonal."""
    if cfg.rglru_block_diag:
        nb = cfg.rglru_block_diag
        B, S, r = x.shape
        xb = x.reshape(B, S, nb, r // nb)
        out = jnp.einsum("bsnk,nkj->bsnj", xb, w.astype(x.dtype))
        return out.reshape(B, S, r)
    return x @ w.astype(x.dtype)


def _rglru_core(cfg, p, x, h0: Optional[jnp.ndarray], c: float, mode: str):
    """x (B,S,r) branch input; returns (h (B,S,r), h_last)."""
    r_gate = jax.nn.sigmoid(
        _gate_matmul(cfg, x, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i_gate = jax.nn.sigmoid(
        _gate_matmul(cfg, x, p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -c * jax.nn.softplus(p["lam"]) * r_gate          # (B,S,r) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i_gate * x.astype(jnp.float32)

    if mode == "decode":
        h = a[:, 0] * (h0 if h0 is not None else 0.0) + gated[:, 0]
        return h[:, None].astype(x.dtype), h

    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None], gated], axis=1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rglru_apply(cfg: ModelConfig, p, x, *, mode: str,
                cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full recurrent block.  x (B,S,d) -> (y (B,S,d), new_cache)."""
    xb = x @ p["wx"].astype(x.dtype)
    yb = jax.nn.gelu(x @ p["wy"].astype(x.dtype))
    conv_state = cache.get("conv") if cache else None
    xb, new_conv = _causal_conv(xb, p["conv"], conv_state)
    h0 = cache["h"].astype(jnp.float32) if cache and "h" in cache else None
    hh, h_last = _rglru_core(cfg, p, xb, h0, cfg.rglru_c, mode)
    out = (hh * yb) @ p["wo"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_last.astype(COMPUTE_DTYPE)}
    return out, new_cache


def rglru_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, P]:
    r = cfg.rnn_width or cfg.d_model
    return {
        "conv": P((batch, 3, r), ("batch", None, "ff"), "zeros", COMPUTE_DTYPE),
        "h": P((batch, r), ("batch", "ff"), "zeros", COMPUTE_DTYPE),
    }
