"""Post-SPMD HLO analysis: flops / bytes / collective traffic with
while-loop trip-count multiplication.

XLA's built-in ``cost_analysis()`` counts each while-loop *body once*, which
silently drops ~all of the compute in scan-over-layers / microbatch /
flash-attention programs.  This module re-derives the three roofline terms
by walking the optimized HLO text:

* computations are parsed into instruction lists,
* ``while`` ops multiply their body+condition cost by the trip count
  recovered from the loop condition's comparison constant,
* ``fusion``/``call`` recurse into their called computations for FLOPs
  (internal traffic stays on-chip and is excluded from the bytes term;
  the fusion's own operands+outputs are the HBM traffic),
* collective operand bytes are accumulated by kind, also trip-multiplied.

The result feeds the roofline terms of EXPERIMENTS.md; XLA's own numbers
are retained as a cross-check field by the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "sign", "floor", "ceil", "round",
    "sine", "cosine", "logistic", "atan2", "remainder", "and", "or", "xor",
    "not", "select", "compare", "clamp", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "erf", "cbrt",
}

MOVEMENT = {
    "copy", "transpose", "reshape", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "slice", "concatenate", "pad", "broadcast",
    "convert", "reverse", "reduce-window", "select-and-scatter", "sort",
    "copy-start", "copy-done",
}

# Movement ops whose real traffic is the *slice*, not the full operand
# (a dynamic-slice of a stacked scan parameter reads one layer, not all).
_SLICE_LIKE = {"dynamic-slice", "gather", "slice"}
# Ops that a TPU compile fuses into consumers: charge strict only.
_FUSED_AWAY = {"broadcast", "convert", "reshape", "iota", "pad"}

FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "iota", "after-all", "partition-id", "replica-id",
    "rng", "rng-bit-generator", "rng-get-and-update-state", "domain",
    "opt-barrier", "custom-call", "infeed", "outfeed", "send", "recv",
    "send-done", "recv-done", "add-dependency",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        if dtype not in DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    # scalars like f32[] are matched with empty dims; bare "f32" (no
    # brackets) appears only in operand annotations we don't need.
    return out


def _shape_bytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_elems(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    args: str
    attrs: str


def _split_rhs(rhs: str) -> Optional[Tuple[str, str, str, str]]:
    """rhs of '=' -> (type_str, opcode, args, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for j, c in enumerate(rhs):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                break
        type_str, rest = rhs[:j + 1], rhs[j + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    depth = 0
    for j in range(par, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    args = rest[par + 1:j]
    attrs = rest[j + 1:]
    return type_str, opcode, args, attrs


_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HDR.match(line)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            s = line.strip()
            if s.startswith("ROOT "):
                s = s[5:]
            eq = s.find(" = ")
            if eq < 0:
                continue
            name = s[:eq].strip().lstrip("%")
            parsed = _split_rhs(s[eq + 3:])
            if not parsed:
                continue
            type_str, opcode, args, attrs = parsed
            self.computations[cur].append(
                Instr(name, type_str, opcode, args, attrs))

        # name -> parsed output shapes, per computation (names are unique
        # module-wide in post-opt HLO, so a flat dict is fine).
        self.shapes: Dict[str, List[Tuple[str, List[int]]]] = {}
        for comp in self.computations.values():
            for ins in comp:
                self.shapes[ins.name] = _parse_shapes(ins.type_str)

    # -- helpers ----------------------------------------------------------
    def operand_names(self, ins: Instr) -> List[str]:
        return re.findall(r"%([\w.\-]+)", ins.args)

    def operand_bytes(self, ins: Instr) -> int:
        return sum(_shape_bytes(self.shapes.get(o, [])) for o in
                   self.operand_names(ins))

    def _called(self, ins: Instr, key: str) -> List[str]:
        return [m.lstrip("%") for m in
                re.findall(key + r"=\s*%?([\w.\-]+)", ins.attrs)]

    def trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name, [])
        consts = []
        for ins in comp:
            if ins.opcode == "constant":
                m = re.match(r"^\s*(-?\d+)\s*$", ins.args)
                if m:
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1


@dataclasses.dataclass
class Cost:
    """``bytes`` is the TPU-proxy traffic (dot/movement/reduce boundaries --
    elementwise chains are assumed fused as a TPU compile would);
    ``bytes_strict`` additionally charges every CPU-fusion boundary
    (upper bound; recorded for the cross-check column)."""

    flops: float = 0.0
    bytes: float = 0.0
    bytes_strict: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_strict += other.bytes_strict * mult
        for k in COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _dot_flops(mod: HloModule, ins: Instr) -> float:
    out_elems = _shape_elems(mod.shapes.get(ins.name, []))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    ops = mod.operand_names(ins)
    if not m or not ops:
        return 2.0 * out_elems
    lhs_shapes = mod.shapes.get(ops[0], [])
    if not lhs_shapes:
        return 2.0 * out_elems
    dims = lhs_shapes[0][1]
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(dims):
            contract *= dims[int(d)]
    return 2.0 * out_elems * contract


def _flops_only(mod: HloModule, comp_name: str,
                memo: Dict[str, float]) -> float:
    """FLOPs inside a fusion computation (recursive, bytes-free)."""
    if comp_name in memo:
        return memo[comp_name]
    total = 0.0
    for ins in mod.computations.get(comp_name, []):
        if ins.opcode == "dot":
            total += _dot_flops(mod, ins)
        elif ins.opcode == "convolution":
            total += 2.0 * _shape_elems(mod.shapes.get(ins.name, []))
        elif ins.opcode in ELEMENTWISE:
            total += _shape_elems(mod.shapes.get(ins.name, []))
        elif ins.opcode == "reduce":
            total += sum(_shape_elems(mod.shapes.get(o, []))
                         for o in mod.operand_names(ins))
        elif ins.opcode in ("fusion", "call", "map"):
            for c in mod._called(ins, "calls") + mod._called(ins, "to_apply"):
                total += _flops_only(mod, c, memo)
    memo[comp_name] = total
    return total


HEAVY_OPS = {"dot", "convolution", "reduce", "gather", "scatter",
             "dynamic-slice", "dynamic-update-slice", "sort"}


def _comp_has_heavy(mod: HloModule, comp_name: str,
                    memo: Dict[str, bool]) -> bool:
    """Does this (fusion) computation contain non-elementwise work?  Pure
    elementwise fusions would be fused into neighbors by a TPU compile, so
    their boundary traffic is excluded from the TPU-proxy bytes term."""
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = False
    heavy = False
    for ins in mod.computations.get(comp_name, []):
        if ins.opcode in HEAVY_OPS:
            heavy = True
            break
        if ins.opcode in ("fusion", "call", "map"):
            for c in mod._called(ins, "calls") + mod._called(ins, "to_apply"):
                if _comp_has_heavy(mod, c, memo):
                    heavy = True
                    break
        if heavy:
            break
    memo[comp_name] = heavy
    return heavy


@functools.lru_cache(maxsize=8)
def analyze_hlo(text: str) -> Cost:
    mod = HloModule(text)
    fmemo: Dict[str, float] = {}
    hmemo: Dict[str, bool] = {}
    cmemo: Dict[str, Cost] = {}

    def walk(comp_name: str) -> Cost:
        if comp_name in cmemo:
            return cmemo[comp_name]
        cost = Cost()
        for ins in mod.computations.get(comp_name, []):
            op = ins.opcode
            out_b = _shape_bytes(mod.shapes.get(ins.name, []))
            base = op.replace("-start", "").replace("-done", "")
            if op == "while":
                conds = mod._called(ins, "condition")
                bodies = mod._called(ins, "body")
                trip = mod.trip_count(conds[0]) if conds else 1
                for b in bodies:
                    cost.add(walk(b), trip)
                for c in conds:
                    cost.add(walk(c), trip)
            elif base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                ob = mod.operand_bytes(ins)
                cost.coll_bytes[base] += ob or out_b
                cost.coll_counts[base] += 1
            elif op == "fusion":
                heavy = False
                for c in mod._called(ins, "calls"):
                    cost.flops += _flops_only(mod, c, fmemo)
                    heavy |= _comp_has_heavy(mod, c, hmemo)
                io = mod.operand_bytes(ins) + out_b
                cost.bytes_strict += io
                if heavy:
                    cost.bytes += io
            elif op in ("call", "map"):
                for c in mod._called(ins, "to_apply"):
                    cost.add(walk(c))
            elif op == "conditional":
                branches = mod._called(ins, "branch_computations") or \
                    mod._called(ins, "true_computation") + \
                    mod._called(ins, "false_computation")
                sub = [walk(b) for b in branches]
                if sub:
                    best = max(sub, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            elif op == "dot":
                cost.flops += _dot_flops(mod, ins)
                cost.bytes += mod.operand_bytes(ins) + out_b
                cost.bytes_strict += mod.operand_bytes(ins) + out_b
            elif op == "convolution":
                cost.flops += 2.0 * _shape_elems(mod.shapes.get(ins.name, []))
                cost.bytes += mod.operand_bytes(ins) + out_b
                cost.bytes_strict += mod.operand_bytes(ins) + out_b
            elif op in ELEMENTWISE:
                cost.flops += _shape_elems(mod.shapes.get(ins.name, []))
                cost.bytes_strict += mod.operand_bytes(ins) + out_b
            elif op == "reduce":
                cost.flops += sum(_shape_elems(mod.shapes.get(o, []))
                                  for o in mod.operand_names(ins))
                cost.bytes += mod.operand_bytes(ins) + out_b
                cost.bytes_strict += mod.operand_bytes(ins) + out_b
            elif op in MOVEMENT:
                if op in _SLICE_LIKE:
                    io = 2 * out_b                     # read slice + write
                elif op == "dynamic-update-slice":
                    ops_ = mod.operand_names(ins)
                    upd = (_shape_bytes(mod.shapes.get(ops_[1], []))
                           if len(ops_) > 1 else out_b)
                    io = 2 * upd                       # read + write the slice
                elif op == "scatter":
                    ops_ = mod.operand_names(ins)
                    upd = (_shape_bytes(mod.shapes.get(ops_[2], []))
                           if len(ops_) > 2 else out_b)
                    io = 2 * upd
                else:
                    io = mod.operand_bytes(ins) + out_b
                cost.bytes_strict += io
                if op not in _FUSED_AWAY:
                    cost.bytes += io
            # FREE ops: no cost.
        cmemo[comp_name] = cost
        return cost

    if mod.entry is None:
        return Cost()
    return walk(mod.entry)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    """Per-device roofline terms in seconds (assignment Sec. ROOFLINE)."""

    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collectives: Dict[str, float]
    collective_counts: Dict[str, float]


def top_bytes_contributors(text: str, n: int = 15) -> List[Tuple[str, float]]:
    """Debug/profile helper for the perf loop: heaviest byte contributors
    (opcode + output type), trip-multiplied, TPU-proxy rules."""
    mod = HloModule(text)
    hmemo: Dict[str, bool] = {}
    contrib: Dict[str, float] = {}

    def walk(comp_name: str, mult: float) -> None:
        for ins in mod.computations.get(comp_name, []):
            op = ins.opcode
            out_b = _shape_bytes(mod.shapes.get(ins.name, []))
            if op == "while":
                conds = mod._called(ins, "condition")
                trip = mod.trip_count(conds[0]) if conds else 1
                for b in mod._called(ins, "body"):
                    walk(b, mult * trip)
                continue
            if op in ("call", "map"):
                for c in mod._called(ins, "to_apply"):
                    walk(c, mult)
                continue
            io = 0.0
            if op == "fusion":
                if any(_comp_has_heavy(mod, c, hmemo)
                       for c in mod._called(ins, "calls")):
                    io = mod.operand_bytes(ins) + out_b
            elif op in ("dot", "convolution", "reduce"):
                io = mod.operand_bytes(ins) + out_b
            elif op in _SLICE_LIKE:
                io = 2 * out_b
            elif op == "dynamic-update-slice":
                ops_ = mod.operand_names(ins)
                io = 2 * (_shape_bytes(mod.shapes.get(ops_[1], []))
                          if len(ops_) > 1 else out_b)
            elif op in MOVEMENT and op not in _FUSED_AWAY:
                io = mod.operand_bytes(ins) + out_b
            if io:
                key = f"{op}:{ins.type_str.split('{')[0]}"
                contrib[key] = contrib.get(key, 0.0) + io * mult

    if mod.entry:
        walk(mod.entry, 1.0)
    return sorted(contrib.items(), key=lambda kv: -kv[1])[:n]


def roofline_from_cost(cost: Cost, peak_flops: float = 197e12,
                       hbm_bw: float = 819e9,
                       link_bw: float = 50e9) -> Roofline:
    terms = {
        "compute": cost.flops / peak_flops,
        "memory": cost.bytes / hbm_bw,
        "collective": cost.total_coll_bytes / link_bw,
    }
    dom = max(terms, key=terms.get)
    return Roofline(cost.flops, cost.bytes, cost.total_coll_bytes,
                    terms["compute"], terms["memory"], terms["collective"],
                    dom, cost.coll_bytes, cost.coll_counts)
