"""Macro -> micro instruction code generation (paper Sec. 3.3).

Macro-instructions operate on multi-bit column operands, all rows at once;
code generation lowers them into straight-line ``MicroOp`` sequences with
explicit output presets.  The spatio-temporal scheduling choices of the paper
are reproduced:

* **Interleaved presets** (Naive/Oracular): every gate's output column is
  preset immediately before the gate fires, via *row-sequential* standard
  writes (the expensive path that dominates latency, Fig. 6).
* **Coalesced gang presets** (NaiveOpt/OracularOpt): consecutive computation
  steps are laid out on disjoint scratch columns so all presets of a phase are
  hoisted to the start and issued as gang presets (Sec. 3.4 "gang preset"),
  which the cost model prices as a single parallel COPY-class operation.

The number of presets is identical in both schedules (the paper: "energy
consumption of the optimized case is unchanged"); only their scheduling and
hence latency differs.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .array import MicroOp, Program

PRESET_FOR = {  # required output preset per gate type (Sec. 2.2)
    "NOR": 0, "OR": 1, "NAND": 0, "AND": 1, "INV": 0, "COPY": 1,
    "MAJ3": 1, "MAJ5": 1, "TH": 0,
}


class ColumnAllocator:
    """Scratch column allocator for one row-compartment (Fig. 3 layout).

    Fresh columns come from [lo, hi); dead columns at or above ``reuse_lo``
    may be recycled (every gate presets its output before writing, so reuse
    is always safe once all readers have executed -- programs are straight
    line).  Setting ``reuse_lo`` below ``lo`` lets e.g. consumed match-string
    compartment columns be recycled by the reduction tree, which is how the
    paper fits Phase 2 into the ~2K-cell row.
    """

    def __init__(self, lo: int, hi: int, reuse_lo: int | None = None):
        self.lo, self.hi = lo, hi
        self.reuse_lo = lo if reuse_lo is None else reuse_lo
        self.next = lo
        self.free: List[int] = []

    def alloc(self, n: int = 1) -> List[int]:
        cols = []
        while n > 0 and self.free:
            cols.append(self.free.pop())
            n -= 1
        if n > 0:
            if self.next + n > self.hi:
                raise RuntimeError(
                    f"scratch overflow: need {n} cols beyond {self.next}/{self.hi}")
            cols.extend(range(self.next, self.next + n))
            self.next += n
        return cols

    def release(self, cols: Sequence[int]) -> None:
        self.free.extend(c for c in cols if c >= self.reuse_lo)

    @property
    def high_water(self) -> int:
        return self.next


@dataclasses.dataclass
class CodeGen:
    """Emits micro-ops; `opt=True` coalesces presets into gang presets."""

    scratch: ColumnAllocator
    opt: bool = False

    def __post_init__(self):
        self.prog = Program()
        self._pending_presets: List[MicroOp] = []

    # -- primitive emission -------------------------------------------------
    def _preset(self, col: int, val: int) -> None:
        op = MicroOp(f"PRESET{val}", (), col, gang=self.opt)
        if self.opt:
            # Hoist: gang presets are batched ahead of the computation they
            # feed; functionally we can emit in place (columns are disjoint
            # by construction under opt), the *cost model* prices them as
            # hoisted gangs.
            self.prog.append(op)
        else:
            self.prog.append(op)

    def gate(self, kind: str, ins: Tuple[int, ...], out: int) -> int:
        self._preset(out, PRESET_FOR[kind])
        self.prog.append(MicroOp(kind, ins, out))
        return out

    # -- derived operations (Sec. 2.2) --------------------------------------
    def xor(self, a: int, b: int) -> int:
        """2-input XOR: S1 = NOR(a,b); S2 = COPY(S1); out = TH(a,b,S1,S2)."""
        s1, s2, out = self.scratch.alloc(3)
        self.gate("NOR", (a, b), s1)
        self.gate("COPY", (s1,), s2)
        self.gate("TH", (a, b, s1, s2), out)
        self.scratch.release([s1, s2])
        return out

    def xnor(self, a: int, b: int) -> int:
        x = self.xor(a, b)
        out = self.scratch.alloc(1)[0]
        self.gate("INV", (x,), out)
        self.scratch.release([x])
        return out

    def char_match(self, a0: int, a1: int, b0: int, b1: int) -> int:
        """2-bit character compare (Fig. 4a): NOR of the two bit-XORs.

        Yields 1 iff both bit pairs are equal (character match)."""
        x0 = self.xor(a0, b0)
        x1 = self.xor(a1, b1)
        out = self.scratch.alloc(1)[0]
        self.gate("NOR", (x0, x1), out)
        self.scratch.release([x0, x1])
        return out

    def full_adder(self, a: int, b: int, cin: int) -> Tuple[int, int]:
        """MAJ-gate full adder (Fig. 2): returns (sum, carry_out).

        Steps: Cout = MAJ3(a,b,cin); S1 = INV(Cout); S2 = COPY(S1);
               Sum  = MAJ5(a,b,cin,S1,S2).
        """
        cout, s1, s2, s = self.scratch.alloc(4)
        self.gate("MAJ3", (a, b, cin), cout)
        self.gate("INV", (cout,), s1)
        self.gate("COPY", (s1,), s2)
        self.gate("MAJ5", (a, b, cin, s1, s2), s)
        self.scratch.release([s1, s2])
        return s, cout

    def half_adder(self, a: int, b: int) -> Tuple[int, int]:
        """Half adder still costs one 1-bit FA pass in the paper's accounting;
        we implement it as a full adder with a preset-0 carry-in."""
        zero = self.scratch.alloc(1)[0]
        self._preset(zero, 0)
        s, cout = self.full_adder(a, b, zero)
        self.scratch.release([zero])
        return s, cout

    def ripple_add(self, a_cols: Sequence[int], b_cols: Sequence[int]) -> List[int]:
        """Add two little-endian multi-bit operands; returns sum columns
        (len = max+1).  Costs max(len) 1-bit FAs, as the paper counts.
        Consumed operand and dead carry columns are recycled."""
        n = max(len(a_cols), len(b_cols))
        zero = None
        carry = None
        out: List[int] = []
        for i in range(n):
            if i < len(a_cols) and i < len(b_cols):
                a, b = a_cols[i], b_cols[i]
            else:
                if zero is None:
                    zero = self.scratch.alloc(1)[0]
                    self._preset(zero, 0)
                a = a_cols[i] if i < len(a_cols) else zero
                b = b_cols[i] if i < len(b_cols) else zero
            if carry is None:
                s, new_carry = self.half_adder(a, b)
            else:
                s, new_carry = self.full_adder(a, b, carry)
                self.scratch.release([carry])
            carry = new_carry
            # Operand bits are dead after this FA.
            dead = [c for c in (a, b) if c != zero]
            self.scratch.release(dead)
            out.append(s)
        if zero is not None:
            self.scratch.release([zero])
        out.append(carry)
        return out

    def popcount_tree(self, bit_cols: Sequence[int]) -> List[int]:
        """Reduction tree of 1-bit adders (Fig. 4b): popcount of the match
        string.  Pairs equal-width operands level by level; the total 1-bit-FA
        count for 100 inputs is ~188, matching the paper's Sec. 3.2 estimate.
        Returns little-endian score columns (N = floor(log2 n) + 1 bits).
        """
        operands: List[List[int]] = [[c] for c in bit_cols]
        while len(operands) > 1:
            operands.sort(key=len)
            nxt: List[List[int]] = []
            i = 0
            while i + 1 < len(operands):
                nxt.append(self.ripple_add(operands[i], operands[i + 1]))
                i += 2
            if i < len(operands):
                nxt.append(operands[i])
            operands = nxt
        # The result can never exceed n = len(bit_cols); top columns beyond
        # N = floor(log2 n) + 1 bits are provably zero -- drop them (paper:
        # N = 7 for a 100-char pattern).
        n_bits = int(np.floor(np.log2(len(bit_cols)))) + 1 if bit_cols else 1
        result = operands[0]
        self.scratch.release(result[n_bits:])
        return result[:n_bits]

    def fa_count(self) -> int:
        """Number of 1-bit full-adder invocations emitted (MAJ3 count)."""
        return self.prog.op_counts().get("MAJ3", 0)
