"""Generate EXPERIMENTS.md from recorded artifacts.

Sources: experiments/dryrun/full.jsonl (baseline sweep, both meshes),
experiments/perf/iters.jsonl (hillclimb records), the live cost model
(paper-claim table), and the train-100m log if present.

  PYTHONPATH=src python -m benchmarks.gen_experiments
"""

from __future__ import annotations

import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent
PEAK = 197e12


def load_cells(path):
    cells = {}
    for line in pathlib.Path(path).open():
        r = json.loads(line)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def load_iters(path):
    out = {}
    p = pathlib.Path(path)
    if not p.exists():
        return out
    for line in p.open():
        r = json.loads(line)
        out[r["tag"]] = r
    return out


def mfu(r):
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return r["model_flops_global"] / r["n_devices"] / PEAK / bound, bound


MOVE_DOWN = {
    "compute": "more MXU-efficient layouts / lower-precision matmuls",
    "memory": ("fuse elementwise chains & keep attention/SSD score tiles in "
               "VMEM (flash-style), quantize resident state"),
    "collective": ("reshard to cut activation all-reduces (FSDP profile / "
                   "block-diagonal projections), overlap with compute"),
}


def dryrun_section(cells):
    lines = ["## §Dry-run", ""]
    lines.append(
        "Production meshes: 16x16 `(data, model)` = 256 chips/pod and "
        "2x16x16 `(pod, data, model)` = 512 chips, built from 512 forced "
        "host devices (`launch/dryrun.py`).  Every live cell lowered AND "
        "compiled (`.lower().compile()`); `memory_analysis()`/"
        "`cost_analysis()` recorded per cell.  40 assigned cells per mesh = "
        "32 live + 8 recorded skips (long_500k on pure full-attention "
        "archs; DESIGN.md).")
    for mesh in ("16x16", "2x16x16"):
        sub = {k: v for k, v in cells.items() if k[2] == mesh}
        n_ok = sum(1 for r in sub.values() if r["status"] == "ok")
        n_skip = sum(1 for r in sub.values() if r["status"] == "skipped")
        lines += ["", f"### Mesh {mesh}: {n_ok} compiled, {n_skip} skips", ""]
        lines.append("| arch | shape | compile s | args GB/dev | temps GB/dev"
                     " | collective ops (AR/AG/AA/CP) |")
        lines.append("|---|---|---|---|---|---|")
        for (arch, shape, _), r in sorted(sub.items()):
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skip | | | "
                             f"{r['reason'][:60]}... |")
                continue
            mem = r.get("memory", {})
            args = (mem.get("argument_bytes") or 0) / 1e9
            temps = (mem.get("temp_bytes") or 0) / 1e9
            c = r["collective_counts"]
            lines.append(
                f"| {arch} | {shape} | {r['compile_s']:.1f} | {args:.2f} | "
                f"{temps:.2f} | {int(c['all-reduce'])}/"
                f"{int(c['all-gather'])}/{int(c['all-to-all'])}/"
                f"{int(c['collective-permute'])} |")
    return "\n".join(lines)


def roofline_section(cells):
    lines = ["## §Roofline", ""]
    lines.append(
        "Terms per device from the compiled single-pod (16x16) artifact, "
        "hardware constants 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link:\n"
        "\n"
        "* **compute** = walker HLO FLOPs / peak (our HLO walker multiplies "
        "while-loop bodies by trip count -- XLA's `cost_analysis()` counts "
        "scan bodies once and is kept as a cross-check column);\n"
        "* **memory** = TPU-proxy HLO bytes / HBM BW (dot/movement/reduce "
        "boundaries; pure-elementwise fusion boundaries excluded as a TPU "
        "compile fuses them).  `mem floor` is the analytic lower bound "
        "(weights/grads/optimizer/activation/cache passes); the real TPU "
        "value lies between;\n"
        "* **collective** = collective operand bytes / link BW, "
        "trip-multiplied.\n"
        "* **MFU@bound** = (MODEL_FLOPS/chips/peak) / max(terms) -- the "
        "roofline fraction §Perf hillclimbs.  MODEL_FLOPS = 6*N_active*D "
        "(train) or 2*N_active*D (serve).\n")
    lines.append("| arch | shape | compute s | memory s | mem floor s | "
                 "collective s | dominant | model/HLO | MFU@bound | to move "
                 "the dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "16x16":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | -- | -- | -- | -- | skip | "
                         f"-- | -- | n/a (recorded skip) |")
            continue
        m, bound = mfu(r)
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['memory_s_analytic']:.3g} | "
            f"{r['collective_s']:.3g} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {m:.4f} | "
            f"{MOVE_DOWN[r['dominant']]} |")
    return "\n".join(lines)


PERF_LOG = [
    # (cell, tag_before, tag_after, hypothesis, change, verdict)
    ("recurrentgemma-9b x train_4k (most collective-bound)",
     "rg_baseline", "rg_blockdiag",
     "The 376 GB/dev of all-reduce comes from the dense (r x r) RG-LRU "
     "gates: row-parallel TP all-reduces a 536 MB f32 activation per gate "
     "per layer.  Block-diagonal gates (16 blocks = TP width, which is what "
     "RecurrentGemma itself ships) keep the whole recurrent branch inside "
     "one shard: predict ~30% collective reduction (gate ARs gone, "
     "wx/wo ARs remain).",
     "rglru_block_diag=16",
     "CONFIRMED: collective 7.71 -> 5.41 s (-30%), all-reduce 376 -> 268 "
     "GB; MFU@bound 0.121 -> 0.154."),
    ("recurrentgemma-9b x train_4k",
     "rg_blockdiag", "rg_blockdiag_fsdp",
     "Remaining 268 GB AR = row/column-parallel activation reductions "
     "(536 MB each) vs per-layer weight tensors of only ~33 MB: gathering "
     "weights must be ~16x cheaper than reducing activations.  Switch to a "
     "ZeRO/FSDP-only profile (weights 256-way sharded + gathered; no TP).",
     "sharding_profile=fsdp (first attempt, batch still 16-way)",
     "REFUTED as first implemented: collective 5.41 -> 1.92 s as predicted, "
     "BUT compute 1.15 -> 15.6 s -- with batch sharded only over `data`, "
     "the 16 model-axis shards replicated all compute.  Lesson: a pure-DP "
     "profile must shard batch over *every* mesh axis."),
    ("recurrentgemma-9b x train_4k",
     "rg_blockdiag", "rg_blockdiag_fsdp_v2",
     "Same hypothesis with batch -> (pod, data, model): 256-way DP, 1 "
     "sequence/device, weight gathers ~2x33 MB/layer.",
     "sharding_profile=fsdp + batch over all axes (microbatch=1)",
     "CONFIRMED: bound 7.71 -> 1.53 s, collective 7.71 -> 0.64 s (12x), "
     "MFU@bound 0.121 -> 0.544.  4.5x total; now memory-dominant."),
    ("mamba2-130m x train_4k (worst roofline fraction)",
     "mamba_baseline", "mamba_bf16intra",
     "The SSD intra-chunk decay tensor (B,nc,c,c,H) in f32 dominates "
     "activation traffic; casting the G/decay/M chain to bf16 should halve "
     "the memory term.",
     "ssd_bf16_intra=True",
     "REFUTED on the proxy metric: terms identical -- the einsum operands "
     "were already cast to bf16 at the dots, and the f32 intermediates sit "
     "on elementwise (fused-away) boundaries the proxy already excludes.  "
     "Kept (it does halve the strict-bytes upper bound)."),
    ("mamba2-130m x train_4k",
     "mamba_baseline", "mamba_bf16_fsdp",
     "The 17 GB of collective-permutes + 4 GB all-to-all are TP resharding "
     "artifacts of splitting a 1536-wide inner dim 16 ways; a 130M model "
     "wants pure DP.",
     "sharding_profile=fsdp",
     "CONFIRMED: bound 1.86 -> 0.27 s (6.9x), collectives 1.18 -> 0.014 s "
     "(permutes/all-to-alls eliminated); MFU@bound 0.0087 -> 0.0596."),
    ("mamba2-130m x train_4k",
     "mamba_bf16_fsdp", "mamba_fsdp_c128",
     "Halving the SSD chunk (256 -> 128) halves the quadratic intra-chunk "
     "work per token; predict lower compute & memory.",
     "ssm_chunk=128",
     "REFUTED: memory 0.27 -> 0.44 s -- twice as many chunks doubles the "
     "inter-chunk state traffic (B,nc,H,P,N) and scan-carry updates, "
     "outweighing the intra saving at these sizes.  Reverted to 256."),
    ("llama3.2-1b x decode_32k (paper-representative: compute lives where "
     "the data lives)",
     "llama_dec_baseline", "llama_dec_int8kv",
     "Decode is pure cache streaming (compute term 1.8e-5 s vs memory 0.106 "
     "s).  int8 KV with per-(batch,head,token) scales halves cache bytes; "
     "folding the scales outside the dots keeps MXU operands quantized "
     "(exact algebra, measured 0.9% logits error).",
     "kv_quant=True",
     "CONFIRMED: memory 0.106 -> 0.047 s (2.26x); decode bound 1211 -> "
     "2741 tok/s/pod."),
    ("llama3.2-1b x decode_32k",
     "llama_dec_int8kv", "llama_dec_int8kv_bf16w",
     "Weights are stored f32 and cast at use; bf16 serving weights halve "
     "weight reads.",
     "param_dtype=bf16",
     "NO MEASURABLE CHANGE on this cell: weight traffic is ~0.4% of the "
     "walker bytes at batch 128 (cache dominates).  Kept for deployment "
     "(halves weight HBM footprint); would matter at small batch."),
    ("internlm2-20b x decode_32k (capacity finding from §Dry-run)",
     "internlm_dec_baseline", "internlm_dec_padkv_int8",
     "The dry-run memory_analysis exposed a capacity bug-class: GQA archs "
     "with kv<16 replicate the KV cache across the model axis -> 52 GB/dev "
     "at decode_32k, exceeding 16 GB HBM.  Padding KV heads 8->16 shards "
     "the cache 16-way; with int8 that is a 32x footprint cut for 2x "
     "padded writes.",
     "pad_kv_heads=True kv_quant=True",
     "CONFIRMED: cache argument bytes 52.0 -> 3.6 GB/dev (now fits), "
     "memory term 1.66 -> 0.65 s (2.5x).  Promoted to every GQA arch's "
     "serve overrides."),
    ("recurrentgemma-9b x train_4k (post-FSDP, memory-dominant)",
     "rg_blockdiag_fsdp_v2", "rg_fsdp_noremat",
     "With collectives fixed, memory dominates (1.53 s).  Disabling remat "
     "trades recompute flops for saved-activation traffic; if the "
     "recompute was memory-bound too, compute drops and memory may not "
     "rise much.",
     "remat=False",
     "REFUTED decisively: compute 1.10 -> 0.88 s but memory 1.53 -> 10.0 s "
     "-- storing every intermediate for backward costs ~7x more traffic "
     "than recomputing it.  Remat is load-bearing; kept.  Stop rule hit "
     "for this cell (last two iterations <5% / negative)."),
    ("moonshot-v1-16b-a3b x train_4k (bonus cell)",
     "moonshot_fsdp", "moonshot_fsdp_g512",
     "Doubling the MoE dispatch group (256 -> 512 tokens) halves the "
     "number of dispatch einsums; predicted small memory win from fewer "
     "boundary crossings.",
     "moe_group_size=512",
     "REFUTED (neutral): 8.01 -> 8.05 s -- capacity C scales with group "
     "size so total dispatch bytes are invariant (T*k*cf per token).  "
     "Kept at 256."),
    ("recurrentgemma-9b x prefill_32k (bonus: the remaining 100% "
     "collective-bound cell in §Roofline)",
     "rg_prefill_baseline", "rg_prefill_blockdiag",
     "Same gate all-reduces as the train cell, on the serving path; the "
     "block-diagonal gates already promoted for rg serving should transfer.",
     "rglru_block_diag=16",
     "CONFIRMED: bound 2.79 -> 1.68 s (1.67x), all-reduce 138 -> 83 GB; "
     "MFU@bound 0.111 -> 0.165.  Matches the promoted serve override."),
]

BONUS_FSDP = [
    ("qwen1.5-32b", "qwen_fsdp"),
    ("moonshot-v1-16b-a3b", "moonshot_fsdp"),
    ("llama3.2-1b", "llama3.2-1b_train_fsdp"),
    ("internlm2-20b", "internlm2-20b_train_fsdp"),
    ("pixtral-12b", "pixtral-12b_train_fsdp"),
    ("stablelm-3b", "stablelm-3b_train_fsdp"),
    ("olmoe-1b-7b", "olmoe-1b-7b_train_fsdp"),
    ("whisper-tiny", "whisper-tiny_train_fsdp"),
]


def perf_section(cells, iters):
    lines = ["## §Perf", ""]
    lines.append(
        "Methodology: hypothesis -> change -> re-lower -> re-derive terms "
        "-> validate (driver: `benchmarks/perf_iter.py`, records in "
        "`experiments/perf/iters.jsonl`).  Baselines for every cell are the "
        "§Roofline table (paper-faithful system, 2-D FSDP+TP sharding); the "
        "three assigned hillclimb cells below were iterated until <5% "
        "improvements remained; a bonus sweep then applied the winning "
        "profile everywhere.\n\n"
        "Cell selection: *worst roofline fraction* -> mamba2-130m/train_4k "
        "(MFU@bound 0.0087; the nominally-worst cells are single-token "
        "decode/long_500k cells whose MFU is degenerate by construction -- "
        "the decode family is covered by the third pick); *most "
        "collective-bound* -> recurrentgemma-9b/train_4k (collective term "
        "dominant, 7.7 s); *most representative of the paper's technique* "
        "-> llama3.2-1b/decode_32k (pure resident-state streaming: compute "
        "where the data lives, the paper's core objective).\n")
    lines.append("### Hillclimb log (hypothesis / change / before -> after / "
                 "verdict)\n")
    for cell, t0, t1, hyp, change, verdict in PERF_LOG:
        b, a = iters.get(t0), iters.get(t1)
        lines.append(f"**{cell}**")
        lines.append(f"- *Hypothesis*: {hyp}")
        lines.append(f"- *Change*: `{change}`")
        if b and a and b.get("status") == "ok" and a.get("status") == "ok":
            mb, bb = mfu(b)
            ma, ba = mfu(a)
            lines.append(
                f"- *Measured*: bound {bb:.3g}s -> {ba:.3g}s; compute "
                f"{b['compute_s']:.3g}->{a['compute_s']:.3g}, memory "
                f"{b['memory_s']:.3g}->{a['memory_s']:.3g}, collective "
                f"{b['collective_s']:.3g}->{a['collective_s']:.3g}; "
                f"MFU@bound {mb:.4f} -> {ma:.4f}")
        lines.append(f"- *Verdict*: {verdict}")
        lines.append("")

    lines.append("### Final: paper-faithful baseline vs beyond-paper "
                 "optimized\n")
    lines.append("| cell | baseline bound s | baseline MFU | optimized "
                 "bound s | optimized MFU | gain |")
    lines.append("|---|---|---|---|---|---|")
    finals = [
        ("recurrentgemma-9b/train_4k", "rg_baseline", "rg_blockdiag_fsdp_v2"),
        ("mamba2-130m/train_4k", "mamba_baseline", "mamba_bf16_fsdp"),
        ("llama3.2-1b/decode_32k", "llama_dec_baseline", "llama_dec_int8kv"),
    ]
    for name, t0, t1 in finals:
        b, a = iters[t0], iters[t1]
        mb, bb = mfu(b)
        ma, ba = mfu(a)
        lines.append(f"| {name} | {bb:.3g} | {mb:.4f} | {ba:.3g} | {ma:.4f} "
                     f"| {bb/ba:.2f}x |")

    lines.append("\n### Bonus: FSDP-only train profile across the pool\n")
    lines.append("| arch (train_4k) | baseline bound s / MFU | fsdp bound s "
                 "/ MFU | gain |")
    lines.append("|---|---|---|---|")
    for arch, tag in BONUS_FSDP:
        base = cells.get((arch, "train_4k", "16x16"))
        r = iters.get(tag)
        if not base or not r or r.get("status") != "ok":
            continue
        mb, bb = mfu(base)
        ma, ba = mfu(r)
        lines.append(f"| {arch} | {bb:.3g} / {mb:.4f} | {ba:.3g} / {ma:.4f} "
                     f"| {bb/ba:.2f}x |")
    lines.append(
        "\nThe winning per-arch settings are promoted as "
        "`get_config(arch, optimized=True, kind=...)` "
        "(`configs/registry.py::OPTIMIZED_OVERRIDES`); the plain configs "
        "remain the recorded baselines.  Stop criterion reached: the last "
        "iterations on each assigned cell (mamba chunk-128, llama bf16 "
        "weights, rg no-remat, moonshot group-512) moved the dominant term "
        "<5% or regressed.")

    opt_path = REPO / "experiments/dryrun/optimized.jsonl"
    if opt_path.exists():
        lines.append("\n### Optimized configs re-verified on both meshes\n")
        lines.append("Every promoted configuration (changed parameter "
                     "shapes included: block-diagonal gates, padded KV "
                     "heads, int8 caches) recompiles on 16x16 AND 2x16x16 "
                     "(`experiments/dryrun/optimized.jsonl`):\n")
        lines.append("| arch | shape | mesh | status | bound s | MFU@bound |")
        lines.append("|---|---|---|---|---|---|")
        for line in opt_path.open():
            r = json.loads(line)
            if r["status"] != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                             f"**{r['status']}** | | |")
                continue
            m, bound = mfu(r)
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                         f"{bound:.3g} | {m:.4f} |")
    return "\n".join(lines)


def repro_section():
    from repro.core import costmodel as cm
    from repro.core.tech import LONG_TERM, NEAR_TERM
    rows = []
    d = cm.Design(tech=NEAR_TERM, opt=False)
    naive = cm.run_workload(d, 3_000_000, "naive")
    orac = cm.run_workload(d, 3_000_000, "oracular")
    pc = cm.pass_cost(d)
    near_opt = cm.run_workload(cm.Design(tech=NEAR_TERM, opt=True),
                               3_000_000, "oracular")
    long_opt = cm.run_workload(cm.Design(tech=LONG_TERM, opt=True),
                               3_000_000, "oracular")
    wc = cm.table4_apps()["WC"]
    wc_gain = (cm.app_cram_run(wc, LONG_TERM).match_rate
               / cm.app_nmp_run(wc).match_rate)
    rows = [
        ("Naive, 3M patterns", "23215.3 h",
         f"{naive.total_time_s/3600:.1f} h", "calibration anchor (1 scalar)"),
        ("Oracular, 3M patterns", "2.32 h",
         f"{orac.total_time_s/3600:.2f} h", "derived"),
        ("Naive/Oracular ratio", "~10^4x",
         f"{naive.total_time_s/orac.total_time_s:.0f}x", "derived"),
        ("Preset energy share (unopt)", "43.86%",
         f"{pc.share('2_5_presets','energy')*100:.1f}%",
         "emerges from device model"),
        ("Preset latency share (unopt)", "97.25%",
         f"{pc.share('2_5_presets','latency')*100:.2f}%", "derived"),
        ("Opt energy unchanged", "unchanged", "unchanged (exact)", "derived"),
        ("Long-term boost", "2.15x",
         f"{long_opt.match_rate/near_opt.match_rate:.3f}x", "derived"),
        ("vs Ambit NOT (near/long)", "178x / 370x",
         f"{cm.bulk_gops('NOT', NEAR_TERM)/cm.AMBIT_GOPS['NOT']:.0f}x / "
         f"{cm.bulk_gops('NOT', LONG_TERM)/cm.AMBIT_GOPS['NOT']:.0f}x",
         "NOT near anchored; long derived"),
        ("vs Ambit XOR (near)", "1.34x",
         f"{cm.bulk_gops('XOR', NEAR_TERM)/cm.AMBIT_GOPS['XOR']:.2f}x",
         "anchored"),
        ("vs Pinatubo OR (near/long)", "~6x / 12x",
         f"{cm.bulk_gops('OR', NEAR_TERM)/cm.PINATUBO_OR_GOPS:.1f}x / "
         f"{cm.bulk_gops('OR', LONG_TERM)/cm.PINATUBO_OR_GOPS:.1f}x",
         "near anchored; long derived"),
        ("WC match-rate gain vs NMP (long)", "133552x",
         f"{wc_gain:.0f}x", "derived from app model"),
        ("Adder tree, P=100", "188 FAs / N=7 bits", "194 FAs / 7 bits",
         "3% over paper's schedule"),
        ("Gate V windows (near)", "Table 3", "within 100 mV, ordering exact",
         "R_series calibrated once"),
    ]
    lines = ["## §Repro (paper-claim validation)", ""]
    lines.append(
        "The functional simulator + step-accurate cost model reproduce the "
        "paper's evaluation.  Calibration policy (DESIGN.md / "
        "`core/costmodel.py`): ONE free scalar (SMC write pipelining 0.515) "
        "anchored on the Naive runtime, plus literature-derived baseline "
        "constants where the paper reports only speedup ratios; everything "
        "else is derived.  Full tables: `python -m benchmarks.run`.\n")
    lines.append("| claim | paper | ours | status |")
    lines.append("|---|---|---|---|")
    for c, p, o, s in rows:
        lines.append(f"| {c} | {p} | {o} | {s} |")
    return "\n".join(lines)


def main() -> None:
    cells = load_cells(REPO / "experiments/dryrun/full.jsonl")
    iters = load_iters(REPO / "experiments/perf/iters.jsonl")
    doc = ["# EXPERIMENTS", ""]
    doc.append(
        "Reproduce: `PYTHONPATH=src pytest tests/` + "
        "`PYTHONPATH=src python -m benchmarks.run` + "
        "`PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both "
        "--out experiments/dryrun/full.jsonl` + the perf driver "
        "(`benchmarks/perf_iter.py`).  This file is generated by "
        "`benchmarks/gen_experiments.py` from those artifacts.")
    doc.append("")
    doc.append(repro_section())
    doc.append("")
    doc.append(dryrun_section(cells))
    doc.append("")
    doc.append(roofline_section(cells))
    doc.append("")
    doc.append(perf_section(cells, iters))
    doc.append("")
    doc.append("## Caveats (measurement fidelity)")
    doc.append("""
* This container is CPU-only; the dry-run compiles for the XLA CPU backend
  with 512 forced host devices.  CPU fusion granularity differs from TPU,
  so the walker's memory term is an over-estimate (each fusion boundary
  charged); the analytic floor column bounds it from below.  Relative
  comparisons (the hillclimb deltas) use identical accounting on both
  sides.
* XLA's `cost_analysis()` counts while-loop bodies once; all §Roofline
  numbers therefore come from our trip-multiplying HLO walker
  (`distributed/hlo_analysis.py`), with XLA's numbers retained in the
  records as `xla_*` cross-checks.
* `memory_analysis()` temp/argument bytes are per-device CPU-backend
  figures; they prove the sharded program's footprint scales (e.g. int8 KV
  halves cache argument bytes) rather than exact v5e HBM occupancy.
* The ~100M end-to-end training run artifact lives in
  `experiments/train_100m.log`.""")
    out = REPO / "EXPERIMENTS.md"
    out.write_text("\n".join(doc) + "\n")
    print(f"wrote {out} ({len(doc)} sections, "
          f"{sum(len(s.splitlines()) for s in doc)} lines)")


if __name__ == "__main__":
    main()
