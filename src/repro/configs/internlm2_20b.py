"""internlm2-20b [dense]: GQA kv=8.

[arXiv:2403.17297; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544, head_dim=128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92_544,
    rope_theta=1e6, act="silu", norm="rms",
    microbatch=4,
)

SMOKE = ModelConfig(
    name="internlm2-20b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    rope_theta=1e4,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
