"""Observability layer tests (DESIGN.md Sec. 3l).

Covers the contracts the layer is trusted for:

* ``LogHistogram`` quantiles within one bucket width of exact numpy
  percentiles, over several distributions;
* span nesting / attribute / stage-breakdown invariants, including the
  disjoint self-time accounting;
* Chrome/Perfetto trace-event export schema;
* plan-vs-actual records agreeing **bit-for-bit** with what
  ``FeedbackStore.observe`` receives on a feedback-enabled engine;
* the disabled fast path allocating nothing (singleton no-op span,
  tracemalloc-asserted);
* the AST lint (``tools/lint_obs_spans.py``) passing on the tree and
  catching a planted uncovered dispatch;
* ``MatchResult.timings`` / ``ServiceStats`` histogram views.
"""

from __future__ import annotations

import json
import math
import pathlib
import subprocess
import sys
import tracemalloc

import numpy as np
import pytest

from repro.obs import (NOOP_SPAN, STAGES, LogHistogram, MetricsRegistry,
                       Observability, Tracer)

REPO = pathlib.Path(__file__).resolve().parent.parent
LINT = REPO / "tools" / "lint_obs_spans.py"


# -- LogHistogram ------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential",
                                  "bimodal"])
def test_histogram_quantiles_within_one_bucket(dist):
    rng = np.random.default_rng(3)
    if dist == "lognormal":
        xs = rng.lognormal(-5, 2, 5000)
    elif dist == "uniform":
        xs = rng.uniform(1e-4, 1e-1, 5000)
    elif dist == "exponential":
        xs = rng.exponential(0.01, 5000)
    else:
        xs = np.concatenate([rng.normal(1e-3, 1e-4, 2500),
                             rng.normal(1e-1, 1e-2, 2500)])
        xs = np.abs(xs) + 1e-9
    h = LogHistogram()
    for x in xs:
        h.record(float(x))
    for q in (0.01, 0.25, 0.50, 0.90, 0.95, 0.99):
        true = float(np.quantile(xs, q, method="lower"))
        est = h.quantile(q)
        assert est > 0.0
        # One bucket width of log-error max (plus the min/max clamp can
        # only *reduce* the error).
        assert abs(math.log(est) - math.log(true)) <= math.log(h.base) \
            + 1e-9, (dist, q, est, true)


def test_histogram_edge_cases():
    h = LogHistogram()
    assert h.quantile(0.5) == 0.0 and h.mean == 0.0
    h.record(0.0)                      # underflow bucket
    h.record(-1.0)
    h.record(4.0)
    assert h.count == 3 and h.n_under == 2
    assert h.quantile(0.0) == 0.0      # underflow sorts first
    assert h.quantile(1.0) == pytest.approx(4.0)   # clamped to max
    assert h.sum == pytest.approx(3.0)
    snap = h.snapshot()
    assert snap["count"] == 3 and "p99" in snap
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        LogHistogram(base=1.0)


def test_histogram_single_value_exact():
    h = LogHistogram()
    for _ in range(100):
        h.record(0.125)
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == pytest.approx(0.125)


# -- spans -------------------------------------------------------------------

def test_span_nesting_and_attrs():
    tr = Tracer(enabled=True)
    with tr.span("service.tick", {"tick": 0}) as t:
        with tr.span("match.run") as r:
            with tr.span("plan", {"kernel": "swar"}) as p:
                p.set("est_seconds", np.float64(0.5))
            with tr.span("launch", {"c0": 0}):
                pass
        assert tr.current() is t
    assert tr.current() is None
    assert len(tr.roots) == 1
    root = tr.roots[0]
    assert [s.name for s in root.walk()] == \
        ["service.tick", "match.run", "plan", "launch"]
    assert r.parent_id == root.span_id
    assert p.attrs["kernel"] == "swar"
    # numpy scalar coerced to a plain JSON float
    assert isinstance(p.attrs["est_seconds"], float)
    assert root.duration_s >= r.duration_s >= 0.0
    # span ids unique + parent ids resolve within the tree
    ids = [s.span_id for s in root.walk()]
    assert len(set(ids)) == len(ids)
    for s in root.walk():
        if s.parent_id is not None:
            assert s.parent_id in ids


def test_stage_seconds_disjoint():
    tr = Tracer(enabled=True)
    with tr.span("match.run") as root:
        with tr.span("filter"):
            with tr.span("pull"):     # nested stage: counts as pull only
                pass
        with tr.span("launch"):
            pass
    stages = root.stage_seconds()
    assert set(stages) == set(STAGES)
    fil = next(s for s in root.children if s.name == "filter")
    pull = fil.children[0]
    # Disjoint self-times: filter excludes the nested pull.
    assert stages["pull"] == pytest.approx(pull.duration_s)
    assert stages["filter"] == pytest.approx(
        fil.duration_s - pull.duration_s)
    assert sum(stages.values()) <= root.duration_s + 1e-9


def test_span_exception_unwind():
    tr = Tracer(enabled=True)
    with pytest.raises(RuntimeError):
        with tr.span("a"):
            with tr.span("b"):
                raise RuntimeError("boom")
    assert tr.current() is None        # stack fully unwound
    assert [s.name for s in tr.iter_spans()] == ["a", "b"]


def test_max_spans_bounds_roots():
    tr = Tracer(enabled=True, max_spans=2)
    for _ in range(5):
        with tr.span("r"):
            pass
    assert len(tr.roots) == 2 and tr.n_dropped == 3
    assert tr.n_spans == 5


# -- export ------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("match.run", {"reduction": "best"}):
        with tr.span("launch"):
            pass
    path = tmp_path / "trace.json"
    n = tr.write_chrome(path)
    trace = json.loads(path.read_text())
    assert n == 2 and len(trace["traceEvents"]) == 2
    for ev in trace["traceEvents"]:
        assert set(("name", "cat", "ph", "ts", "dur", "pid", "tid",
                    "args")) <= set(ev)
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
    # child starts within parent's [ts, ts+dur] (Perfetto nests by
    # time containment)
    parent, child = trace["traceEvents"]
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] \
        + 1.0   # 1us slack for float rounding
    assert trace["otherData"]["n_spans"] == 2


def test_jsonl_export(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("a", {"x": 1}):
        with tr.span("b"):
            pass
    path = tmp_path / "spans.jsonl"
    assert tr.write_jsonl(path) == 2
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["name"] for r in recs] == ["a", "b"]
    assert recs[1]["parent_id"] == recs[0]["span_id"]
    assert recs[0]["attrs"] == {"x": 1}


# -- disabled fast path ------------------------------------------------------

def test_disabled_span_is_singleton_noop():
    tr = Tracer(enabled=False)
    s = tr.span("anything", None)
    assert s is NOOP_SPAN
    assert tr.span("other") is s       # same object every call
    with s as inner:
        inner.set("k", "v")            # swallowed
    assert tr.n_spans == 0 and tr.roots == []


def test_disabled_span_zero_allocations():
    tr = Tracer(enabled=False)

    def hot():
        for _ in range(100):
            with tr.span("launch"):
                pass

    hot()                              # warm any lazy state
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    hot()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    # Zero allocations attributable to the obs layer itself (the test
    # harness's own snapshot bookkeeping is excluded by the filter).
    grew = [st for st in after.compare_to(before, "lineno")
            if st.size_diff > 0
            and any("repro" in str(f) and "obs" in str(f)
                    for f in st.traceback)]
    assert not grew, f"disabled span path allocated: {grew[:3]}"


# -- registry / plan-vs-actual ----------------------------------------------

def test_registry_instruments():
    m = MetricsRegistry()
    m.counter("x").inc()
    m.counter("x").inc(2)
    m.gauge("g").set(1.5)
    m.histogram("h").record(0.25)
    assert m.counter("x").value == 3
    assert m.gauge("g").value == 1.5
    snap = m.snapshot()
    assert snap["counters"]["x"] == 3
    assert snap["histograms"]["h"]["count"] == 1
    json.dumps(snap)                   # JSON-safe end to end


def test_plan_actual_mispredict_accounting():
    m = MetricsRegistry(drift_bound=2.0)
    key = ("swar", 5, 3, 0)
    m.record_plan_actual(key, 1.0, 1.5)     # within bound
    m.record_plan_actual(key, 1.0, 8.0)     # outside
    m.record_plan_actual(key, 0.0, 1.0)     # degenerate -> mispredict
    assert m.mispredict_rate() == pytest.approx(2 / 3)
    assert m.mispredict_rate("swar") == pytest.approx(2 / 3)
    assert m.mispredict_rate("mxu") == 0.0
    summary = m.plan_actual_summary()
    assert summary["swar/5/3/0"]["n"] == 3
    assert summary["swar/5/3/0"]["last_obs_s"] == 1.0


def test_plan_actual_matches_feedback_bit_for_bit():
    """Every (key, est, obs) the engine hands FeedbackStore.observe is
    the identical record in the obs registry (same tuples, same float
    bits) -- the two accountings are one accounting."""
    from repro.match import MatchEngine

    rng = np.random.default_rng(5)
    rows = rng.integers(0, 4, (48, 64), np.uint8)
    eng = MatchEngine(rows, record_runtimes=True)
    observed = []
    orig = eng.planner.feedback.observe
    eng.planner.feedback.observe = (
        lambda key, est, obs: (observed.append((key, est, obs)),
                               orig(key, est, obs))[-1])
    for i in range(4):
        eng.match(rows[i, :12].copy())
    eng.match(rows[0, :12].copy(), reduction="threshold", threshold=12.0)
    assert observed, "feedback-enabled engine recorded nothing"
    records = eng.obs.metrics.plan_actual_records
    assert len(records) >= len(observed)
    # every feedback observation appears verbatim (tuple identity +
    # float equality, not approx) in the registry's record list
    reg = {(k, e, o) for k, e, o in records}
    for key, est, obs in observed:
        assert (key, est, obs) in reg
    # and the registry saw them under the same kernel names
    kernels = {k[0] for k, _, _ in records}
    assert kernels <= {"swar", "mxu", "ref", "filter"}


def test_plan_actual_always_on_without_feedback():
    from repro.match import MatchEngine

    rng = np.random.default_rng(6)
    rows = rng.integers(0, 4, (32, 64), np.uint8)
    eng = MatchEngine(rows, record_runtimes=False)
    eng.match(rows[0, :8].copy())
    eng.match(rows[1, :8].copy())
    assert eng.planner.feedback.n_observations == 0
    assert eng.obs.metrics.plan_actual      # registry recorded anyway
    assert eng.obs.metrics.mispredict_rate() >= 0.0


# -- engine / service integration -------------------------------------------

@pytest.fixture(scope="module")
def traced_service():
    from repro.match import MatchEngine, MatchService

    rng = np.random.default_rng(9)
    rows = rng.integers(0, 4, (48, 64), np.uint8)
    obs = Observability(spans=True)
    eng = MatchEngine(rows, obs=obs)
    svc = MatchService(eng)
    pats = [rows[i, :10].copy() for i in range(6)]
    tickets = [svc.submit(p) for p in pats]
    svc.ingest(rng.integers(0, 4, (4, 64), np.uint8))
    svc.flush()
    return svc, tickets, obs


def test_match_result_timings(traced_service):
    svc, tickets, obs = traced_service
    res = tickets[0].result
    assert res.timings is not None
    assert set(res.timings) == set(STAGES)
    assert all(v >= 0.0 for v in res.timings.values())
    assert res.timings["launch"] > 0.0
    # timings excluded from the dataclass repr (compact result)
    assert "timings" not in repr(res)


def test_timings_absent_when_disabled():
    from repro.match import MatchEngine

    rng = np.random.default_rng(10)
    rows = rng.integers(0, 4, (32, 64), np.uint8)
    eng = MatchEngine(rows)            # obs default: spans off
    res = eng.match(rows[0, :8].copy())
    assert res.timings is None
    assert eng.obs.tracer.n_spans == 0


def test_service_stats_histogram_views(traced_service):
    svc, tickets, obs = traced_service
    s = svc.stats
    assert s.latency_hist.count == s.n_completed
    # deprecated running-sum accessors remain as thin views
    assert s.total_latency_s == pytest.approx(s.latency_hist.sum)
    assert s.avg_latency_s == pytest.approx(
        s.latency_hist.sum / s.n_completed)
    snap = s.snapshot()
    assert 0.0 < snap["latency_p50_s"] <= snap["latency_p95_s"] \
        <= snap["latency_p99_s"]
    # snapshot rounds to 6 decimals, which can nudge p99 above the true
    # max by up to 5e-7 -- tolerance must cover the rounding step
    assert snap["latency_p99_s"] <= s.latency_hist.max + 1e-6
    assert set(snap["timings"]) == set(STAGES)
    assert snap["plan_actual"]
    assert snap["plan_mispredict_rate"] >= 0.0
    json.dumps(snap)


def test_service_trace_covers_stages(traced_service):
    svc, tickets, obs = traced_service
    spans = list(obs.tracer.iter_spans())
    names = {s.name for s in spans}
    assert {"service.enqueue", "service.tick", "match.run", "plan",
            "launch", "merge", "pull", "pack"} <= names
    n_enq = sum(s.name == "service.enqueue" for s in spans)
    assert n_enq == svc.stats.n_submitted
    for run in (s for s in spans if s.name == "match.run"):
        sub = {c.name for c in run.walk()}
        assert {"plan", "launch", "pull"} <= sub


def test_corpus_counters(traced_service):
    svc, tickets, obs = traced_service
    counters = obs.metrics.counters
    assert counters["corpus.packs"].value >= 1
    assert counters["corpus.splice_rows"].value >= 4   # the ingest


# -- lint --------------------------------------------------------------------

def test_lint_passes_on_tree():
    proc = subprocess.run([sys.executable, str(LINT)],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_lint_catches_uncovered_dispatch(tmp_path):
    k = tmp_path / "src" / "repro" / "kernels"
    m = tmp_path / "src" / "repro" / "match"
    k.mkdir(parents=True)
    m.mkdir(parents=True)
    (k / "foo.py").write_text(
        "import jax.experimental.pallas as pl\n"
        "def kern(x):\n"
        "    return pl.pallas_call(lambda r: r)(x)\n")
    (m / "eng.py").write_text(
        "from repro.kernels import foo as _f\n"
        "def run(x):\n"
        "    return _f.kern(x)\n")
    bad = subprocess.run([sys.executable, str(LINT), str(tmp_path)],
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "eng.py:3" in bad.stderr
    (m / "eng.py").write_text(
        "from repro.kernels import foo as _f\n"
        "def run(x, tr):\n"
        "    with tr.span('launch'):\n"
        "        return _f.kern(x)\n")
    good = subprocess.run([sys.executable, str(LINT), str(tmp_path)],
                          capture_output=True, text=True)
    assert good.returncode == 0, good.stderr
