"""Standing-query bank bench: one fused bank launch vs. per-pattern loop.

The regime from DESIGN.md Sec. 3j: thousands of standing patterns scored
against every arriving document batch.  The naive serving shape compiles
each pattern as an ad-hoc threshold query and launches it alone -- Q
dispatches per batch, the launch-overhead regime the roles-swapped fused
scan exists to kill.  The bench times three ways of scoring the same
batch against the same bank:

* ``loop``     -- per-pattern ad-hoc compiles over the batch corpus (the
                  baseline; compile cache warmed so only launches are
                  timed);
* ``bank``     -- one fused ``PatternBank.scan`` with the prefilter off;
* ``bank+filter`` -- the same scan with the pattern-side q-gram
                  prefilter forced on.

Correctness gates before any timing is reported:

* **bit-identity** -- the fused scan's per-pattern hit streams are
  asserted equal to every ad-hoc compile's hits;
* **zero false negatives** -- the prefiltered scan's hits are asserted
  identical to the unfiltered scan's (the pattern-side q-gram lemma);
* **one launch per batch** -- each scan increments the bank's verify
  dispatch counter by exactly one, regardless of bank size.

Emits ``BENCH_match_standing.json`` at the repo root and exits nonzero
if the record is malformed.  CI runs ``--smoke`` as a schema guard on a
reduced shape without overwriting the committed artifact; the full run
additionally asserts the fused path beats the per-pattern loop (the
acceptance regime is >= 1k standing patterns).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_standing.json"

FULL = dict(n_patterns=1024, D=64, F=256, P=32, planted=24, repeats=3)
SMOKE = dict(n_patterns=64, D=16, F=128, P=16, planted=6, repeats=1)

REQUIRED_KEYS = ("shape", "device_kind", "backend", "calibration",
                 "n_processes", "n_hosts", "interpret", "smoke", "bank",
                 "results")
REQUIRED_RESULT_KEYS = ("case", "loop_s", "bank_s", "speedup",
                        "survivor_frac", "n_hits", "n_launches",
                        "identical")


def make_inputs(cfg: dict, rng):
    """Random pattern set + one doc batch with a few patterns planted."""
    Qp, D, F, P = cfg["n_patterns"], cfg["D"], cfg["F"], cfg["P"]
    pats = rng.integers(0, 4, (Qp, P), np.uint8)
    docs = rng.integers(0, 4, (D, F), np.uint8)
    for i in rng.choice(Qp, cfg["planted"], replace=False):
        d = int(rng.integers(0, D))
        off = int(rng.integers(0, F - P + 1))
        docs[d, off:off + P] = pats[i]
    return pats, docs


def build_bank(cfg: dict, pats, *, filter):
    from repro.match import PatternBank

    bank = PatternBank(cfg["F"], cfg["P"], capacity=cfg["n_patterns"],
                       filter=filter)
    pids = [bank.register(p, threshold=float(cfg["P"])) for p in pats]
    return bank, pids


def run_bench(smoke: bool) -> dict:
    from repro.match import MatchEngine, PackedCorpus
    from repro.match.calibrate import bench_provenance

    cfg = SMOKE if smoke else FULL
    rng = np.random.default_rng(7)
    pats, docs = make_inputs(cfg, rng)
    bank, pids = build_bank(cfg, pats, filter=False)
    fbank, _ = build_bank(cfg, pats, filter=True)

    # Per-pattern baseline: the batch as a corpus, one ad-hoc compiled
    # threshold query per standing pattern.  The cache is sized to hold
    # every compiled program so the timed loop pays launches only.
    eng = MatchEngine(PackedCorpus(docs),
                      compile_cache_size=cfg["n_patterns"] + 8)
    queries = [bank.pattern(pid).query for pid in pids]

    # Warm every path (jit compiles + the one-time operand packs) and
    # gate correctness BEFORE any timing: per-pattern bit-identity, then
    # prefilter zero-false-negative, then the one-launch invariant.
    loop_hits = {pid: eng.match(q).hits for pid, q in zip(pids, queries)}
    t_scan = bank.scan(docs)
    t_fil = fbank.scan(docs)
    identical = all(
        np.array_equal(t_scan.hits[t_scan.hits[:, 2] == pid][:, [0, 1, 3]],
                       loop_hits[pid]) for pid in pids)
    zero_fn = bool(np.array_equal(t_scan.hits, t_fil.hits))
    if not identical:
        raise ValueError("fused bank hits diverged from the per-pattern "
                         "ad-hoc compiles")
    if not zero_fn:
        raise ValueError("prefiltered bank hits diverged from the "
                         "unfiltered scan (false negatives!)")
    if bank.n_bank_launches != 1 or t_scan.n_bank_launches != 1:
        raise ValueError("unfiltered scan did not cost exactly one fused "
                         "launch")

    t_loop = t_bank = t_bankf = float("inf")
    # Best-of-N per path: CPU-container timings are noisy; the minimum is
    # the least-contended observation of the same work.
    for _ in range(cfg["repeats"]):
        t0 = time.perf_counter()
        for q in queries:
            eng.match(q)
        t_loop = min(t_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        t_scan = bank.scan(docs)
        t_bank = min(t_bank, time.perf_counter() - t0)
        t0 = time.perf_counter()
        t_fil = fbank.scan(docs)
        t_bankf = min(t_bankf, time.perf_counter() - t0)
    launches_per_scan = bank.n_bank_launches / bank.n_scans

    results = [
        {"case": "bank_vs_loop", "loop_s": round(t_loop, 4),
         "bank_s": round(t_bank, 4),
         "speedup": round(t_loop / t_bank, 2),
         "survivor_frac": None, "n_hits": int(t_scan.hits.shape[0]),
         "n_launches": int(t_scan.n_bank_launches), "identical": identical},
        {"case": "bank_prefilter_vs_loop", "loop_s": round(t_loop, 4),
         "bank_s": round(t_bankf, 4),
         "speedup": round(t_loop / t_bankf, 2),
         "survivor_frac": (None if t_fil.survivor_frac is None
                           else round(t_fil.survivor_frac, 5)),
         "n_hits": int(t_fil.hits.shape[0]),
         "n_launches": int(t_fil.n_bank_launches), "identical": zero_fn},
    ]
    record = {
        "shape": {"n_patterns": cfg["n_patterns"], "D": cfg["D"],
                  "F": cfg["F"], "P": cfg["P"], "planted": cfg["planted"]},
        **bench_provenance(eng.planner.cost_source),
        "interpret": eng.interpret,
        "smoke": smoke,
        "bank": {k: bank.stats()[k] for k in
                 ("n_live", "capacity", "plane_pack_count",
                  "sig_pack_count", "n_scans", "n_bank_launches")},
        "launches_per_scan": round(launches_per_scan, 4),
        "filter_plan": t_fil.plan.strategy,
        "results": results,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with the reduced shape.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if not record["results"]:
        raise ValueError("BENCH record has no results")
    if record["bank"]["plane_pack_count"] > 1 \
            or record["bank"]["sig_pack_count"] > 1:
        raise ValueError("bank residency violated: operands repacked "
                         f"({record['bank']})")
    if record["launches_per_scan"] != 1.0:
        raise ValueError("one-fused-launch-per-batch invariant violated: "
                         f"{record['launches_per_scan']} launches/scan")
    for row in record["results"]:
        for key in REQUIRED_RESULT_KEYS:
            if key not in row:
                raise ValueError(f"result row missing key {key!r}: {row}")
        if not row["identical"]:
            raise ValueError(f"{row['case']}: hits diverged (the gate ran "
                             "before timing; this record is inconsistent)")
        if row["n_hits"] < 1:
            raise ValueError(f"{row['case']}: planted patterns produced "
                             "no hits")
        if row["n_launches"] != 1:
            raise ValueError(f"{row['case']}: scan cost "
                             f"{row['n_launches']} fused launches, not 1")
        if not record["smoke"] and row["speedup"] < 1.5:
            raise ValueError(
                f"{row['case']}: fused bank path only {row['speedup']}x "
                "over the per-pattern loop (acceptance floor is 1.5x at "
                f"{record['shape']['n_patterns']} patterns)")
    fil = record["results"][1]
    if fil["survivor_frac"] is None or fil["survivor_frac"] > 0.25:
        raise ValueError("pattern-side prefilter did not prune "
                         f"(survivor_frac={fil['survivor_frac']})")
    json.loads(json.dumps(record))      # round-trips as JSON


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    return [
        (f"standing/{row['case']}",
         round(row["bank_s"] * 1e6, 1),
         f"loop_us={row['loop_s']*1e6:.1f} speedup={row['speedup']}x "
         f"survivors={row['survivor_frac']} hits={row['n_hits']} "
         f"identical={row['identical']}")
        for row in record["results"]
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cases = " ".join(
        f"{r['case']}:speedup={r['speedup']}x:surv={r['survivor_frac']}"
        for r in rec["results"])
    return (f"{BENCH_JSON.name} Q={rec['shape']['n_patterns']} "
            f"D={rec['shape']['D']} launches/scan="
            f"{rec['launches_per_scan']} {cases}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small bank + batch (CI schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for row in record["results"]:
        print(f"{row['case']:>24}  loop={row['loop_s']*1e3:8.1f}ms  "
              f"bank={row['bank_s']*1e3:8.1f}ms  "
              f"speedup={row['speedup']:.2f}x  "
              f"survivors={row['survivor_frac']}  "
              f"identical={row['identical']}")
    print(f"filter plan: {record['filter_plan']}  "
          f"launches/scan: {record['launches_per_scan']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
