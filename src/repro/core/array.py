"""CRAM-PM array: state + row-parallel micro-instruction interpreter.

The array is a 2-D grid of cells (``uint8`` logic states).  Per the paper
(Sec. 2.4) a *single* gate may be active per row at a time, but every row
executes that same gate on the same columns simultaneously -- i.e. each
micro-instruction is a column-wise SIMD operation across all rows.  That
execution model maps 1:1 onto a JAX array program: one micro-op = gather the
input columns, apply the gate function, scatter the output column.

The interpreter is written as a ``lax.scan`` over an encoded program so a
whole micro-program JIT-compiles into a single XLA computation; this is the
reproduction's "array simulator" and also what the data-pipeline dedup filter
runs on.  Cost accounting is done on the *program* (host side), never inside
the traced computation -- see ``costmodel.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MAX_ARITY = 5

# Opcode table. PRESET0/PRESET1 write a constant into the output column;
# whether a preset is issued as a gang preset (one op, Sec. 3.4) or as
# row-sequential writes is a *scheduling* attribute (MicroOp.gang) consumed by
# the cost model -- the functional result is identical.
OPCODES: Tuple[str, ...] = (
    "PRESET0", "PRESET1", "NOR", "OR", "NAND", "AND", "INV", "COPY",
    "MAJ3", "MAJ5", "TH",
)
OPCODE_ID: Dict[str, int] = {name: i for i, name in enumerate(OPCODES)}
ARITY: Dict[str, int] = {
    "PRESET0": 0, "PRESET1": 0, "NOR": 2, "OR": 2, "NAND": 2, "AND": 2,
    "INV": 1, "COPY": 1, "MAJ3": 3, "MAJ5": 5, "TH": 4,
}


@dataclasses.dataclass(frozen=True)
class MicroOp:
    """One CRAM-PM micro-instruction (Sec. 3.3 code generation)."""

    op: str
    ins: Tuple[int, ...] = ()
    out: int = 0
    gang: bool = True  # presets only: gang preset vs row-sequential write

    def __post_init__(self):
        if self.op not in OPCODE_ID:
            raise ValueError(f"unknown opcode {self.op}")
        if len(self.ins) != ARITY[self.op]:
            raise ValueError(
                f"{self.op} expects {ARITY[self.op]} inputs, got {len(self.ins)}")


class Program:
    """A straight-line micro-program plus scheduling statistics."""

    def __init__(self, ops: Iterable[MicroOp] = ()):  # noqa: D401
        self.ops: List[MicroOp] = list(ops)

    def append(self, op: MicroOp) -> None:
        self.ops.append(op)

    def extend(self, ops: Iterable[MicroOp]) -> None:
        self.ops.extend(ops)

    def __len__(self) -> int:
        return len(self.ops)

    def __iter__(self):
        return iter(self.ops)

    def op_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for op in self.ops:
            key = op.op
            if key.startswith("PRESET"):
                key = "PRESET_GANG" if op.gang else "PRESET_ROW"
            counts[key] = counts.get(key, 0) + 1
        return counts

    def n_logic_ops(self) -> int:
        return sum(1 for op in self.ops if not op.op.startswith("PRESET"))

    def n_presets(self) -> Tuple[int, int]:
        """(gang, row-sequential) preset counts."""
        gang = sum(1 for o in self.ops if o.op.startswith("PRESET") and o.gang)
        row = sum(1 for o in self.ops if o.op.startswith("PRESET") and not o.gang)
        return gang, row

    # -- encoding for the JAX interpreter ---------------------------------
    def encode(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = len(self.ops)
        opc = np.zeros((n,), np.int32)
        ins = np.zeros((n, MAX_ARITY), np.int32)
        out = np.zeros((n,), np.int32)
        for i, op in enumerate(self.ops):
            opc[i] = OPCODE_ID[op.op]
            for j, c in enumerate(op.ins):
                ins[i, j] = c
            out[i] = op.out
        return opc, ins, out


def _apply_gate(opc: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """vals: (rows, MAX_ARITY) uint8 gathered inputs -> (rows,) uint8 output."""
    v = vals.astype(jnp.int32)
    s2 = v[:, 0] + v[:, 1]
    s3 = s2 + v[:, 2]
    s4 = s3 + v[:, 3]
    s5 = s4 + v[:, 4]
    one = jnp.ones_like(v[:, 0])
    zero = jnp.zeros_like(v[:, 0])
    branches = [
        zero,                       # PRESET0
        one,                        # PRESET1
        (s2 == 0).astype(jnp.int32),  # NOR
        (s2 > 0).astype(jnp.int32),   # OR
        (s2 < 2).astype(jnp.int32),   # NAND
        (s2 == 2).astype(jnp.int32),  # AND
        1 - v[:, 0],                # INV
        v[:, 0],                    # COPY
        (s3 >= 2).astype(jnp.int32),  # MAJ3
        (s5 >= 3).astype(jnp.int32),  # MAJ5
        (s4 <= 1).astype(jnp.int32),  # TH
    ]
    stacked = jnp.stack(branches, axis=0)        # (n_ops_kinds, rows)
    return jnp.take(stacked, opc, axis=0).astype(jnp.uint8)


def _interp_step(state, instr):
    opc, ins, out = instr
    vals = jnp.take(state, ins, axis=1)          # (rows, MAX_ARITY)
    res = _apply_gate(opc, vals)
    state = state.at[:, out].set(res)
    return state, None


@jax.jit
def execute(state: jnp.ndarray, opc: jnp.ndarray, ins: jnp.ndarray,
            out: jnp.ndarray) -> jnp.ndarray:
    """Run an encoded micro-program on array ``state`` (rows, cols) uint8."""
    state, _ = jax.lax.scan(_interp_step, state, (opc, ins, out))
    return state


def run_program(state: jnp.ndarray, program: Program) -> jnp.ndarray:
    opc, ins, out = program.encode()
    if len(program) == 0:
        return state
    return execute(state, jnp.asarray(opc), jnp.asarray(ins), jnp.asarray(out))


class CRAMArray:
    """Convenience stateful wrapper (functional core above).

    Memory-configuration operations (read/write, Sec. 2.1) are host-mediated
    and tracked in ``mem_stats`` for the cost model; logic-configuration
    operations come in as ``Program``s.
    """

    def __init__(self, n_rows: int, n_cols: int):
        self.n_rows = n_rows
        self.n_cols = n_cols
        self.state = jnp.zeros((n_rows, n_cols), jnp.uint8)
        self.mem_stats = {"row_writes": 0, "bits_written": 0,
                          "row_reads": 0, "bits_read": 0}

    # -- memory configuration ---------------------------------------------
    def write_row(self, row: int, col0: int, bits: Sequence[int]) -> None:
        bits = np.asarray(bits, np.uint8)
        self.state = self.state.at[row, col0:col0 + len(bits)].set(bits)
        self.mem_stats["row_writes"] += 1
        self.mem_stats["bits_written"] += int(len(bits))

    def write_column_rows(self, col0: int, bits2d: np.ndarray) -> None:
        """Write the same column range of every row (counted as per-row writes,
        since at most one row can be written at a time, Sec. 3.3)."""
        bits2d = np.asarray(bits2d, np.uint8)
        assert bits2d.shape[0] == self.n_rows
        self.state = self.state.at[:, col0:col0 + bits2d.shape[1]].set(bits2d)
        self.mem_stats["row_writes"] += int(bits2d.shape[0])
        self.mem_stats["bits_written"] += int(bits2d.size)

    def read_row(self, row: int, col0: int, n: int) -> np.ndarray:
        self.mem_stats["row_reads"] += 1
        self.mem_stats["bits_read"] += n
        return np.asarray(self.state[row, col0:col0 + n])

    def read_columns(self, col0: int, n: int) -> np.ndarray:
        """Read-out of the same columns in all rows (score buffer drain)."""
        self.mem_stats["row_reads"] += self.n_rows
        self.mem_stats["bits_read"] += n * self.n_rows
        return np.asarray(self.state[:, col0:col0 + n])

    # -- logic configuration ------------------------------------------------
    def run(self, program: Program) -> None:
        self.state = run_program(self.state, program)
