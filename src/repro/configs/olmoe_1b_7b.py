"""olmoe-1b-7b [moe]: 64 experts top-8.

[arXiv:2409.02060; hf]  16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64e top-8, head_dim=128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab=50_304,
    block_pattern=("moe",),
    n_experts=64, top_k=8, moe_d_ff=1024, capacity_factor=1.25,
    moe_group_size=256,
    rope_theta=1e4, act="silu", norm="rms",
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=256,
    block_pattern=("moe",),
    n_experts=8, top_k=2, moe_d_ff=32, moe_group_size=32,
    capacity_factor=4.0,   # E/top_k: no token drops -> exact equivalences
    rope_theta=1e4,
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
