"""Match service: many tenants, one resident corpus.

The multi-tenant serving layer (DESIGN.md Sec. 3d) in four steps:

1. Concurrent small queries coalesce into one fused batched launch.
2. Mixed reductions / row subsets group separately but stay correct.
3. Repeat queries hit the LRU result cache.
4. A corpus row write bumps the generation and invalidates the cache.
5. Declarative wildcard queries (accept-mask predicates) coalesce too.

Run:  PYTHONPATH=src python examples/match_service.py
"""

import numpy as np

from repro.match import MatchEngine, MatchQuery, MatchService


def main() -> None:
    rng = np.random.default_rng(0)
    frags = rng.integers(0, 4, (64, 256), np.uint8)
    engine = MatchEngine(frags)
    service = MatchService(engine)

    print("== 1. coalescing: 16 tenants submit, one fused launch ==")
    pats = rng.integers(0, 4, (16, 32), np.uint8)
    tickets = [service.submit(p) for p in pats]
    service.flush()
    s = service.stats.snapshot()
    print(f"  {s['n_completed']} queries served by {s['n_launches']} launch"
          f" ({s['n_coalesced_queries']} fused);"
          f" avg latency {s['avg_latency_s']*1e3:.1f}ms")
    solo = engine.match(pats[3])
    assert np.array_equal(tickets[3].result.best_scores, solo.best_scores)
    print("  scattered result == solo engine.match: True")

    print("\n== 2. mixed work in one tick ==")
    t_best = service.submit(pats[0])                       # cache hit
    t_topk = service.submit(rng.integers(0, 4, 32, np.uint8),
                            reduction="topk", k=3)
    t_sub = service.submit(rng.integers(0, 4, 32, np.uint8),
                           rows=np.array([5, 1, 9]))
    done = service.tick()
    print(f"  one tick completed {done} requests "
          f"(best-from-cache={t_best.cached}, "
          f"topk rows={t_topk.result.topk_rows.tolist()}, "
          f"subset best={t_sub.result.best_scores.tolist()})")

    print("\n== 3. result cache ==")
    before = service.stats.n_cache_hits
    service.match(pats[7])
    print(f"  resubmitted a seen pattern: cache hits "
          f"{before} -> {service.stats.n_cache_hits}")

    print("\n== 4. corpus write invalidates ==")
    gen = engine.corpus.generation
    engine.corpus.set_rows(0, rng.integers(0, 4, (1, 256), np.uint8))
    t = service.submit(pats[7])
    service.tick()
    print(f"  generation {gen} -> {engine.corpus.generation}; "
          f"resubmit after write served from cache: {t.cached}")

    print("\n== 5. wildcard predicates coalesce like exact queries ==")
    before_launches = service.stats.n_launches
    wild = []
    for q in range(8):
        masks = (np.uint8(1) << rng.integers(0, 4, 32, np.uint8))
        masks[rng.integers(0, 32, 4)] = 0b1111     # four N wildcards each
        wild.append(service.submit(MatchQuery.from_masks(masks)))
    service.flush()
    s = service.stats.snapshot()
    print(f"  8 N-wildcard queries served by "
          f"{s['n_launches'] - before_launches} fused launch; "
          f"predicate={wild[0].result.plan.predicate!r} "
          f"backend={wild[0].result.plan.backend!r}")


if __name__ == "__main__":
    main()
