"""Counters, gauges, log-bucketed histograms, plan-vs-actual series.

The registry is the queryable side of the observability layer
(DESIGN.md Sec. 3l): spans answer "where did *this request* go",
metrics answer "what does the fleet look like over the whole run".
Zero dependencies -- stdlib only -- so every subsystem can record into
it unconditionally.

``LogHistogram`` gives p50/p95/p99 without storing samples: values land
in geometric buckets of width ``2**0.25`` (quarter-octave, the same
quantization the calibration table uses), so any reported quantile is
within one bucket -- a factor of at most ``2**0.25 ~ 1.19`` -- of the
exact sample quantile, with O(#occupied buckets) memory over an
unbounded run.  This replaces the old ``ServiceStats`` running-sum
latency accounting, which could report an average but no percentile at
all without a sample list.

``record_plan_actual`` is the widened feedback loop: every executed
launch reports ``(est_seconds, observed_seconds)`` under its
``(kernel, shape-bucket)`` key -- the *same* key and the *same* floats
handed to ``FeedbackStore.observe`` -- whether or not runtime feedback
is enabled.  Feedback mutates plans (and so stays off by default
multi-process, where per-process clocks would diverge SPMD plans);
the registry only *observes*, so it is always on and mispredict rate
per bucket is queryable from any run.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

# Quarter-octave buckets: matches calibrate.py's quantization so a
# histogram bucket and a feedback shape-bucket mean the same thing.
DEFAULT_BASE = 2.0 ** 0.25
# Plans whose observed/estimated ratio leaves [1/b, b] count as
# mispredicted -- same bound FeedbackStore uses to re-price a bucket.
DEFAULT_DRIFT_BOUND = 2.0


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (queue depth, hit rate)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class LogHistogram:
    """Log-bucketed histogram: quantiles without sample storage.

    Positive values land in bucket ``round(log(v)/log(base))``; a
    bucket's representative value is ``base**k`` (geometric center), so
    ``quantile`` is exact to within half a bucket plus rank rounding --
    bounded by one bucket width total (asserted against numpy in
    tests).  Zero/negative values are legal (timer underflow) and land
    in a dedicated underflow bucket reported as 0.0.
    """

    __slots__ = ("base", "_log_base", "buckets", "n_under", "count",
                 "sum", "min", "max")

    def __init__(self, base: float = DEFAULT_BASE) -> None:
        if base <= 1.0:
            raise ValueError(f"base must be > 1, got {base}")
        self.base = float(base)
        self._log_base = math.log(self.base)
        self.buckets: Dict[int, int] = {}
        self.n_under = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self.n_under += 1
            return
        k = int(round(math.log(v) / self._log_base))
        self.buckets[k] = self.buckets.get(k, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Value at rank ``q`` in [0, 1]; 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        # Rank among recorded values, nearest-rank definition; the
        # underflow bucket sorts first.
        target = q * (self.count - 1)
        seen = self.n_under
        if target < seen:
            return 0.0
        rep = 0.0
        for k in sorted(self.buckets):
            seen += self.buckets[k]
            if target < seen:
                rep = self.base ** k
                break
        else:
            rep = self.base ** max(self.buckets) if self.buckets else 0.0
        # Clamp to the observed extremes: the top/bottom bucket centers
        # can overshoot the true min/max by half a bucket.
        if self.max > -math.inf:
            rep = min(rep, self.max)
        if self.min > 0.0:
            rep = max(rep, self.min)
        return rep

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }
        out.update(self.percentiles())
        return out


class PlanActual:
    """One (kernel, shape-bucket)'s est-vs-observed series.

    Keeps aggregate counts plus a log-histogram of observed/estimated
    ratios -- drift direction and spread per bucket, no sample storage.
    """

    __slots__ = ("n", "n_mispredict", "ratio_hist", "last_est",
                 "last_obs", "drift_bound")

    def __init__(self, drift_bound: float = DEFAULT_DRIFT_BOUND) -> None:
        self.n = 0
        self.n_mispredict = 0
        self.ratio_hist = LogHistogram()
        self.last_est = 0.0
        self.last_obs = 0.0
        self.drift_bound = float(drift_bound)

    def record(self, est_s: float, observed_s: float) -> None:
        self.n += 1
        self.last_est = float(est_s)
        self.last_obs = float(observed_s)
        if est_s > 0.0 and observed_s > 0.0:
            ratio = observed_s / est_s
            self.ratio_hist.record(ratio)
            if ratio > self.drift_bound or ratio < 1.0 / self.drift_bound:
                self.n_mispredict += 1
        else:
            # Degenerate estimate or clock underflow: mispredicted by
            # definition, but no meaningful ratio to bucket.
            self.n_mispredict += 1

    @property
    def mispredict_rate(self) -> float:
        return self.n_mispredict / self.n if self.n else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "n": self.n,
            "mispredict_rate": self.mispredict_rate,
            "ratio_p50": self.ratio_hist.quantile(0.50),
            "ratio_p95": self.ratio_hist.quantile(0.95),
            "last_est_s": self.last_est,
            "last_obs_s": self.last_obs,
        }


def plan_key_str(key: Tuple) -> str:
    """JSON-safe form of a feedback ``kernel_key`` tuple."""
    return "/".join(str(p) for p in key)


class MetricsRegistry:
    """Named counters/gauges/histograms plus plan-vs-actual series.

    Instruments are created on first use and live for the registry's
    lifetime.  ``keep_records`` bounds an optional raw record list used
    by tests to check bit-for-bit agreement with ``FeedbackStore``;
    aggregates are unaffected when it saturates.
    """

    def __init__(self, *, keep_records: int = 4096,
                 drift_bound: float = DEFAULT_DRIFT_BOUND) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, LogHistogram] = {}
        self.plan_actual: Dict[Tuple, PlanActual] = {}
        self.plan_actual_records: List[Tuple[Tuple, float, float]] = []
        self.keep_records = int(keep_records)
        self.drift_bound = float(drift_bound)

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  base: float = DEFAULT_BASE) -> LogHistogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = LogHistogram(base)
        return h

    # -- plan-vs-actual --------------------------------------------------------
    def record_plan_actual(self, key: Tuple, est_s: float,
                           observed_s: float) -> None:
        """One executed launch: estimate vs what the clock said.

        ``key`` is the exact ``feedback.kernel_key`` tuple and the
        floats are the exact values passed to ``FeedbackStore.observe``
        when runtime feedback is on -- callers compute them once and
        hand them to both sinks, so the two accountings agree
        bit-for-bit (tested).
        """
        cell = self.plan_actual.get(key)
        if cell is None:
            cell = self.plan_actual[key] = PlanActual(self.drift_bound)
        cell.record(est_s, observed_s)
        if len(self.plan_actual_records) < self.keep_records:
            self.plan_actual_records.append(
                (key, float(est_s), float(observed_s)))

    def mispredict_rate(self, kernel: Optional[str] = None) -> float:
        """Aggregate mispredict rate, optionally for one kernel."""
        n = bad = 0
        for key, cell in self.plan_actual.items():
            if kernel is not None and key and key[0] != kernel:
                continue
            n += cell.n
            bad += cell.n_mispredict
        return bad / n if n else 0.0

    def plan_actual_summary(self) -> Dict[str, Dict[str, Any]]:
        """Per-bucket series keyed by ``kernel/oR/ox/oQ`` strings."""
        return {plan_key_str(k): cell.snapshot()
                for k, cell in sorted(self.plan_actual.items(),
                                      key=lambda kv: plan_key_str(kv[0]))}

    # -- export ----------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Everything, JSON-safe."""
        return {
            "counters": {k: c.value
                         for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
            "plan_actual": self.plan_actual_summary(),
            "plan_mispredict_rate": self.mispredict_rate(),
        }
