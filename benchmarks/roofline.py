"""Roofline tables from the dry-run records (assignment deliverable g).

Loads ``experiments/dryrun/*.jsonl`` (last record wins per cell), computes
the three terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the
roofline fraction:

    mfu_bound = (MODEL_FLOPS / n_dev / peak) / max(compute, memory, collective)

i.e. what fraction of the step-time *bound* is useful model compute -- the
score §Perf hillclimbs.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

PEAK = 197e12

REPO = pathlib.Path(__file__).resolve().parent.parent


def load(path: str | pathlib.Path = None) -> Dict[tuple, dict]:
    path = pathlib.Path(path) if path else REPO / "experiments/dryrun/full.jsonl"
    cells: Dict[tuple, dict] = {}
    if not path.exists():
        return cells
    for line in path.open():
        r = json.loads(line)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def enrich(r: dict) -> dict:
    if r.get("status") != "ok":
        return r
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    bound = max(terms.values())
    model_term = r["model_flops_global"] / r["n_devices"] / PEAK
    r = dict(r)
    r["bound_s"] = bound
    r["mfu_bound"] = model_term / bound if bound else None
    r["compute_fraction"] = terms["compute"] / bound if bound else None
    return r


def table(mesh: str = "16x16", path=None) -> List[dict]:
    cells = load(path)
    out = []
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        out.append(enrich(r))
    return out


def markdown(mesh: str = "16x16", path=None) -> str:
    rows = table(mesh, path)
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| model/HLO flops | MFU@bound |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                         f"skip | -- | -- |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['mfu_bound']:.4f} |")
    return "\n".join(lines)


def run():
    rows = []
    cells = table("16x16")
    ok = [r for r in cells if r.get("status") == "ok"]
    if not ok:
        return [("roofline/missing", 0.0,
                 "run python -m repro.launch.dryrun --all first")]
    for r in ok:
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"compute={r['compute_s']:.3g}s memory={r['memory_s']:.3g}s"
                     f" collective={r['collective_s']:.3g}s dom={r['dominant']}"
                     f" mfu_bound={r['mfu_bound']:.4f}"))
    worst = min(ok, key=lambda r: r["mfu_bound"])
    collb = max(ok, key=lambda r: r["collective_s"] / max(r["bound_s"], 1e-12))
    rows.append(("roofline/worst_fraction", 0.0,
                 f"{worst['arch']}/{worst['shape']} mfu={worst['mfu_bound']:.4f}"))
    rows.append(("roofline/most_collective_bound", 0.0,
                 f"{collb['arch']}/{collb['shape']}"
                 f" coll_share={collb['collective_s']/collb['bound_s']:.3f}"))
    return rows
