"""Multi-host bootstrap tests (environment detection is pure)."""

from repro.launch import cluster


class TestDetectEnvironment:
    def test_single_host_default(self):
        info = cluster.detect_environment({})
        assert info.process_count == 1
        assert info.coordinator is None
        assert info.is_coordinator

    def test_explicit_env(self):
        info = cluster.detect_environment({
            "REPRO_COORDINATOR": "10.0.0.1:8476",
            "REPRO_PROCESS_ID": "3",
            "REPRO_NUM_PROCESSES": "8",
        })
        assert info.coordinator == "10.0.0.1:8476"
        assert info.process_id == 3
        assert info.process_count == 8
        assert not info.is_coordinator

    def test_slurm_nodelist_parsing(self):
        info = cluster.detect_environment({
            "SLURM_JOB_NUM_NODES": "4",
            "SLURM_NODELIST": "tpu[001-004]",
            "SLURM_PROCID": "2",
        })
        assert info.coordinator == "tpu001:8476"
        assert info.process_count == 4
        assert info.process_id == 2

    def test_slurm_plain_hostname(self):
        info = cluster.detect_environment({
            "SLURM_JOB_NUM_NODES": "2",
            "SLURM_NODELIST": "nodeA,nodeB",
            "SLURM_PROCID": "0",
        })
        assert info.coordinator == "nodeA:8476"

    def test_initialize_single_host_noop(self):
        info = cluster.initialize(cluster.HostInfo(None, 0, 1))
        assert info.process_count == 1
