"""Randomized properties of the standing-query bank (hypothesis-driven;
DESIGN.md Sec. 3j).

Split out behind ``importorskip`` so a missing ``hypothesis`` install
skips only this module (repo convention, see
``test_kernels_properties.py``).

Properties:

* **prefilter conservativeness, roles swapped** -- for ANY bank (random
  wildcard mixes, random thresholds incl. unsatisfiable ones) and ANY
  document batch, the forced-prefilter scan returns hits exactly equal
  to the forced-full-scan (the pattern-side q-gram lemma may only drop
  patterns that provably cannot fire);
* **fused launch = ad-hoc compiles** -- every live pattern's hit stream
  out of the one roles-swapped launch is bit-identical to compiling
  that pattern as an ad-hoc threshold query over the same docs;
* **lifecycle invariants** -- under ANY register/unregister/scan
  interleaving the live slots stay dense, pack counters stay <= 1, and
  the bank keeps answering exactly like a fresh bank holding the same
  surviving patterns.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.match import (MatchEngine, PackedCorpus,  # noqa: E402
                         PatternBank)


def random_masks(rng, p, wild_frac):
    codes = rng.integers(0, 4, p, np.uint8)
    masks = (np.uint8(1) << codes).astype(np.uint8)
    wild = rng.random(p) < wild_frac
    masks[wild] = rng.integers(1, 16, int(wild.sum()), np.uint8)
    return masks


def spell(masks):
    """Accept masks -> IUPAC string (the bank registers any spelling)."""
    from repro.core.encoding import IUPAC_MASKS
    inv = {v: k for k, v in IUPAC_MASKS.items()}
    return "".join(inv[int(m)] for m in masks)


class TestStandingProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31), st.data())
    def test_property_prefilter_never_loses_a_hit(self, seed, data):
        rng = np.random.default_rng(seed)
        d, f = int(rng.integers(1, 16)), int(rng.integers(24, 64))
        p = int(rng.integers(4, min(f, 20)))
        wild = data.draw(st.sampled_from([0.0, 0.2, 0.5]))
        n_pat = int(rng.integers(1, 12))
        docs = rng.integers(0, 4, (d, f), np.uint8)
        specs, thrs = [], []
        for i in range(n_pat):
            masks = random_masks(rng, p, wild)
            specs.append(spell(masks))
            # Thresholds sweep satisfiable -> unsatisfiable (> p).
            thrs.append(float(rng.integers(0, p + 2)))
            if rng.random() < 0.5:
                # Plant the lowest accepted code per position: a real
                # qualifying window for any threshold <= p.
                row = int(rng.integers(0, d))
                off = int(rng.integers(0, f - p + 1))
                lowest = np.array([0, 0, 1, 0, 2, 0, 1, 0,
                                   3, 0, 1, 0, 2, 0, 1, 0], np.uint8)
                docs[row, off:off + p] = lowest[masks]
        tickets = {}
        for mode in (True, False):
            bank = PatternBank(f, p, capacity=n_pat, filter=mode,
                               interpret=True)
            for s, t in zip(specs, thrs):
                bank.register(s, threshold=t)
            tickets[mode] = bank.scan(docs)
        np.testing.assert_array_equal(tickets[True].hits,
                                      tickets[False].hits)
        assert tickets[True].n_verified <= tickets[False].n_verified

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_bank_hits_equal_adhoc_compiles(self, seed):
        rng = np.random.default_rng(seed)
        d, f = int(rng.integers(1, 12)), int(rng.integers(24, 56))
        p = int(rng.integers(4, min(f, 18)))
        docs = rng.integers(0, 4, (d, f), np.uint8)
        bank = PatternBank(f, p, capacity=8, interpret=True)
        pids = []
        for i in range(int(rng.integers(1, 8))):
            masks = random_masks(rng, p, float(rng.random() * 0.4))
            thr = float(rng.integers(max(0, p - 4), p + 1))
            if rng.random() < 0.6:
                row = int(rng.integers(0, d))
                off = int(rng.integers(0, f - p + 1))
                lowest = np.array([0, 0, 1, 0, 2, 0, 1, 0,
                                   3, 0, 1, 0, 2, 0, 1, 0], np.uint8)
                docs[row, off:off + p] = lowest[masks]
            pids.append(bank.register(spell(masks), threshold=thr))
        t = bank.scan(docs)
        eng = MatchEngine(PackedCorpus(docs), interpret=True)
        for pid in pids:
            mine = t.hits[t.hits[:, 2] == pid][:, [0, 1, 3]]
            ref = eng.match(bank.pattern(pid).query).hits
            np.testing.assert_array_equal(mine, ref)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_lifecycle_keeps_invariants(self, seed):
        rng = np.random.default_rng(seed)
        f, p = 40, 8
        docs = rng.integers(0, 4, (6, f), np.uint8)
        bank = PatternBank(f, p, capacity=2, interpret=True)
        live = {}
        for step in range(20):
            op = rng.random()
            if op < 0.55 or not live:
                pat = rng.integers(0, 4, p, np.uint8)
                if rng.random() < 0.5:
                    docs[int(rng.integers(0, 6)), 3:3 + p] = pat
                thr = float(rng.integers(p - 2, p + 1))
                pid = bank.register(pat, threshold=thr)
                live[pid] = (pat, thr)
            elif op < 0.8:
                pid = int(rng.choice(list(live)))
                bank.unregister(pid)
                del live[pid]
            else:
                bank.scan(docs)
            assert bank.n_live == len(live)
            assert set(int(x) for x in bank.live_ids()) == set(live)
            assert bank.plane_pack_count <= 1
            assert bank.sig_pack_count <= 1
        # The survivors answer exactly like a fresh bank of the same
        # patterns (fresh ids follow registration order = slot order of
        # nothing in particular, so compare per-pattern by position).
        fresh = PatternBank(f, p, capacity=max(1, len(live)),
                            interpret=True)
        remap = {fresh.register(pat, threshold=thr): pid
                 for pid, (pat, thr) in live.items()}
        told, tnew = bank.scan(docs), fresh.scan(docs)
        by_old = {int(k): v[:, [0, 1, 3]]
                  for k, v in told.by_pattern().items()}
        for fid, pid in remap.items():
            mine = tnew.hits[tnew.hits[:, 2] == fid][:, [0, 1, 3]]
            theirs = by_old.get(pid, np.zeros((0, 3), np.int64))
            np.testing.assert_array_equal(mine, theirs)
