"""Architecture registry: ``--arch <id>`` resolution for launchers/tests.

Every assigned architecture is a selectable config with a reduced ``smoke``
variant of the same family (small widths / few experts / tiny vocab) used by
the per-arch CPU smoke tests; the FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.models.config import ModelConfig

from . import (internlm2_20b, llama3_2_1b, mamba2_130m, moonshot_v1_16b_a3b,
               olmoe_1b_7b, pixtral_12b, qwen1_5_32b, recurrentgemma_9b,
               stablelm_3b, whisper_tiny)

_MODULES = {
    "pixtral-12b": pixtral_12b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "mamba2-130m": mamba2_130m,
    "qwen1.5-32b": qwen1_5_32b,
    "llama3.2-1b": llama3_2_1b,
    "stablelm-3b": stablelm_3b,
    "internlm2-20b": internlm2_20b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "whisper-tiny": whisper_tiny,
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)

CONFIGS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE_CONFIGS: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}

# Winning beyond-paper optimizations from the §Perf hillclimb
# (EXPERIMENTS.md §Perf).  ``get_config(name, optimized=True, kind=...)``
# applies them; the plain configs remain the recorded baselines.
#
# * train: the ZeRO/FSDP-only sharding profile (batch over all mesh axes,
#   weights 256-way sharded and gathered per layer) beats 2-D FSDP+TP on
#   every arch at global batch 256 (1.6x-15x MFU@bound) -- activation
#   all-reduces cost more than weight all-gathers at these widths.
# * serve: int8 KV cache + bf16 weights (measured on llama decode_32k:
#   2.26x); serving shapes keep the 2-D profile (batch < device count).
_FSDP_TRAIN = dict(sharding_profile="fsdp", microbatch=1)
_SERVE_KV = dict(kv_quant=True, param_dtype="bf16")
OPTIMIZED_OVERRIDES: Dict[str, Dict[str, dict]] = {
    "pixtral-12b": {"train": dict(_FSDP_TRAIN),
                    "serve": dict(_SERVE_KV, pad_kv_heads=True)},
    "recurrentgemma-9b": {"train": dict(_FSDP_TRAIN, rglru_block_diag=16),
                          "serve": dict(rglru_block_diag=16)},
    "mamba2-130m": {"train": dict(_FSDP_TRAIN, ssd_bf16_intra=True,
                                  microbatch=1)},
    "qwen1.5-32b": {"train": dict(_FSDP_TRAIN), "serve": dict(_SERVE_KV)},
    "llama3.2-1b": {"train": dict(_FSDP_TRAIN),
                    "serve": dict(_SERVE_KV, pad_kv_heads=True)},
    "stablelm-3b": {"train": dict(_FSDP_TRAIN), "serve": dict(_SERVE_KV)},
    "internlm2-20b": {"train": dict(_FSDP_TRAIN),
                      "serve": dict(_SERVE_KV, pad_kv_heads=True)},
    "moonshot-v1-16b-a3b": {"train": dict(_FSDP_TRAIN),
                            "serve": dict(_SERVE_KV)},
    "olmoe-1b-7b": {"train": dict(_FSDP_TRAIN), "serve": dict(_SERVE_KV)},
    "whisper-tiny": {"train": dict(_FSDP_TRAIN)},
}


def get_config(name: str, smoke: bool = False, optimized: bool = False,
               kind: str = "train") -> ModelConfig:
    import dataclasses
    table = SMOKE_CONFIGS if smoke else CONFIGS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(table)}")
    cfg = table[name]
    if optimized and not smoke:
        kind_key = "train" if kind == "train" else "serve"
        over = OPTIMIZED_OVERRIDES.get(name, {}).get(kind_key)
        if over:
            cfg = dataclasses.replace(cfg, **over)
    return cfg
