"""Gate-level tests: truth tables emerge from device physics (paper Sec. 2)."""

import itertools

import pytest

from repro.core import gates
from repro.core.tech import LONG_TERM, NEAR_TERM, PAPER_VGATE_V, TECHS


@pytest.mark.parametrize("tech", [NEAR_TERM, LONG_TERM], ids=lambda t: t.name)
@pytest.mark.parametrize("gate", sorted(gates.GATES))
def test_truth_table_emerges_from_analog_model(tech, gate):
    """Every gate's truth table must emerge from the resistive-divider +
    threshold model at the center of its derived V_gate window."""
    spec = gates.GATES[gate]
    for bits in itertools.product((0, 1), repeat=spec.arity):
        assert gates.analog_gate_output(gate, bits, tech) == spec.truth(bits)


@pytest.mark.parametrize("tech", [NEAR_TERM, LONG_TERM], ids=lambda t: t.name)
@pytest.mark.parametrize("gate", sorted(gates.GATES))
def test_window_nonempty(tech, gate):
    lo, hi = gates.vgate_window(gate, tech)
    assert 0 < lo < hi


def test_near_term_windows_match_paper_table3():
    """Near-term windows land on the paper's Table 3 (within 100 mV)."""
    tech = NEAR_TERM
    for gate, (plo, phi) in PAPER_VGATE_V["near-term"].items():
        lo, hi = gates.vgate_window(gate, tech)
        assert abs(lo - plo) < 0.1, (gate, lo, plo)
        assert abs(hi - phi) < 0.1, (gate, hi, phi)


def test_inv_copy_windows_identical():
    """Paper Table 3 lists identical V ranges for INV and COPY."""
    for tech in TECHS.values():
        assert gates.vgate_window("INV", tech) == gates.vgate_window("COPY", tech)


def test_window_ordering_matches_paper():
    """V_INV > V_NOR > V_MAJ3 > V_MAJ5 ~ V_TH (both technologies)."""
    for tech in TECHS.values():
        c = {g: gates.vgate_center(g, tech) for g in gates.PM_GATE_SET}
        assert c["INV"] > c["NOR"] > c["MAJ3"] > c["MAJ5"]
        assert c["NOR"] > c["TH"]


def test_xor_impossible_as_single_gate():
    """Sec. 2.2: no single V window can realize XOR (I_00 > I_01 > I_11
    forbids switching on 00 and 11 but not 01)."""
    tech = NEAR_TERM
    for preset in (0, 1):
        want = {(0, 0): 0, (0, 1): 1, (1, 0): 1, (1, 1): 0}
        switch_cases = [b for b, o in want.items() if o != preset]
        hold_cases = [b for b, o in want.items() if o == preset]
        v_min = max(
            tech.i_crit_ua * 1e-6 / gates.output_current_slope(b, preset, tech)
            for b in switch_cases)
        v_max = min(
            tech.i_crit_ua * 1e-6 / gates.output_current_slope(b, preset, tech)
            for b in hold_cases)
        assert v_min >= v_max  # empty window


def test_more_zeros_means_more_current():
    """The current ordering I_00 > I_01 = I_10 > I_11 (paper Table 1)."""
    tech = NEAR_TERM
    s00 = gates.output_current_slope((0, 0), 0, tech)
    s01 = gates.output_current_slope((0, 1), 0, tech)
    s10 = gates.output_current_slope((1, 0), 0, tech)
    s11 = gates.output_current_slope((1, 1), 0, tech)
    assert s00 > s01 == s10 > s11


@pytest.mark.parametrize("tech", [NEAR_TERM, LONG_TERM], ids=lambda t: t.name)
def test_variation_study(tech):
    """Sec. 5.5: PM gates are structurally distinct (arity, preset) so
    variation cannot alias one used gate into another; wide-window gates
    tolerate the paper's +/-20% swing without recalibration."""
    study = gates.variation_study(tech)
    assert study["pm_gates_structurally_distinct"]
    tol = study["tolerance_interval"]
    # INV/COPY have the widest windows -> largest tolerance.
    assert tol["INV"][0] < 0.9 and tol["INV"][1] > 1.1
    # Tolerance interval always brackets 1 (nominal point is valid).
    for g, (lo, hi) in tol.items():
        assert lo < 1.0 < hi
    # Narrow MAJ windows (paper's own Table 3 shows ~10 mV) tolerate less.
    assert (tol["MAJ5"][1] - tol["MAJ5"][0]) < (tol["NOR"][1] - tol["NOR"][0])


@pytest.mark.parametrize("gate", sorted(gates.GATES))
def test_gate_energy_positive_and_scales_down_longterm(gate):
    e_near = gates.gate_energy_pj(gate, NEAR_TERM)
    e_long = gates.gate_energy_pj(gate, LONG_TERM)
    assert e_near > 0 and e_long > 0
    assert e_long < e_near  # smaller devices, lower switching energy


def test_functional_gates_match_specs():
    """Vectorized GATE_FNS agree with the GateSpec truth tables."""
    import numpy as np
    for name, spec in gates.GATES.items():
        fn = gates.GATE_FNS[name]
        for bits in itertools.product((0, 1), repeat=spec.arity):
            arrs = [np.array([b], dtype=np.uint8) for b in bits]
            assert int(fn(*arrs)[0]) == spec.truth(bits), (name, bits)
