"""Public jit'd wrappers for the CRAM-PM TPU kernels.

Handles packing, tile padding, pattern broadcast and output trimming so
callers deal only in character codes.  ``interpret`` defaults to True off
TPU (kernel bodies execute in Python via the Pallas interpreter, which is
how this CPU container validates them); on TPU it compiles to Mosaic.
"""

from __future__ import annotations


from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding

from . import bitwise as _bitwise
from . import match_mxu as _mxu
from . import match_swar as _swar
from . import popcount as _popcount
from . import ref as _ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: np.ndarray, mult: int) -> np.ndarray:
    r = (-x.shape[0]) % mult
    if r:
        x = np.concatenate([x, np.zeros((r,) + x.shape[1:], x.dtype)], 0)
    return x


def match_scores(fragments: np.ndarray, patterns: np.ndarray,
                 method: Literal["swar", "mxu", "ref"] = "swar",
                 interpret: bool | None = None) -> jnp.ndarray:
    """Similarity scores for all alignments (Algorithm 1 fast path).

    fragments: (R, F) uint8 codes.  patterns: (P,) shared, or (R, P) per-row
    (swar/ref), or (Q, P) batched (mxu -> (R, L, Q)).
    Returns (R, L) int32 (swar/ref) or (R, L, Q) int32 (mxu), L = F - P + 1.
    """
    if interpret is None:
        interpret = default_interpret()
    fragments = np.asarray(fragments, np.uint8)
    patterns = np.asarray(patterns, np.uint8)
    R, F = fragments.shape
    P = patterns.shape[-1]
    L = F - P + 1
    if L <= 0:
        raise ValueError("pattern longer than fragment")

    if method == "ref":
        return _ref.match_scores_ref(fragments, patterns)[:, :L]

    if method == "swar":
        if patterns.ndim == 1:
            patterns = np.broadcast_to(patterns, (R, P))
        ref_words = encoding.pack_codes_u32(fragments)
        # Pad so every (base + Wp + 1) word read stays in bounds.
        wp = -(-P // encoding.CHARS_PER_WORD_DNA)
        need = (L - 1) // 16 + wp + 1
        if ref_words.shape[1] < need:
            ref_words = np.concatenate(
                [ref_words,
                 np.zeros((R, need - ref_words.shape[1]), np.uint32)], 1)
        pat_words = encoding.pack_codes_u32(patterns)
        mask_codes = np.zeros(wp * 16, np.uint32)
        mask_codes[:P] = 1
        valid_mask = encoding.pack_codes_u32(mask_codes[None, :])  # (1, wp)
        rw = _pad_rows(ref_words, _swar.ROW_TILE)
        pw = _pad_rows(pat_words, _swar.ROW_TILE)
        out = _swar.match_swar(
            jnp.asarray(rw), jnp.asarray(pw), jnp.asarray(valid_mask),
            n_locs=L, pattern_chars=P, interpret=interpret)
        return out[:R]

    if method == "mxu":
        shared = patterns.ndim == 1
        if shared:
            patterns = patterns[None, :]
        Q = patterns.shape[0]
        n_chunks = -(-P // _mxu.CHARS_PER_CHUNK)
        p_chars = n_chunks * _mxu.CHARS_PER_CHUNK
        l_pad = max(-(-L // _mxu.L_TILE) * _mxu.L_TILE, _mxu.L_TILE)
        f_chars = l_pad + p_chars
        f1h = np.zeros((R, f_chars, 4), np.float32)
        f1h[np.arange(R)[:, None], np.arange(F)[None, :], fragments] = 1.0
        ref_flat = f1h.reshape(R, f_chars * 4).astype(jnp.bfloat16)
        q_pad = -(-Q // 128) * 128
        pat_mat = np.zeros((p_chars * 4, q_pad), np.float32)
        for q in range(Q):
            for i in range(P):
                pat_mat[i * 4 + int(patterns[q, i]), q] = 1.0
        out = _mxu.match_mxu(jnp.asarray(ref_flat),
                             jnp.asarray(pat_mat, jnp.bfloat16),
                             l_pad=l_pad, interpret=interpret)
        scores = jnp.round(out[:, :L, :Q]).astype(jnp.int32)
        return scores[:, :, 0] if shared else scores

    raise ValueError(method)


def popcount(words: np.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """(N, W) uint32 -> (N,) int32."""
    if interpret is None:
        interpret = default_interpret()
    words = np.asarray(words, np.uint32)
    N = words.shape[0]
    padded = _pad_rows(words, _popcount.N_TILE)
    out = _popcount.popcount(jnp.asarray(padded), interpret=interpret)
    return out[:N, 0]


def bitwise(op: str, a: np.ndarray, b: np.ndarray | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """Bulk bitwise op over (N, W) uint32 operands."""
    if interpret is None:
        interpret = default_interpret()
    a = np.asarray(a, np.uint32)
    N = a.shape[0]
    ap = _pad_rows(a, _bitwise.N_TILE)
    bp = ap if b is None else _pad_rows(np.asarray(b, np.uint32), _bitwise.N_TILE)
    out = _bitwise.bitwise(op, jnp.asarray(ap), jnp.asarray(bp),
                           interpret=interpret)
    return out[:N]


