"""Q-gram filter index tests (DESIGN.md Sec. 3g).

The load-bearing invariants:

* **zero false negatives** -- filtered threshold execution produces
  ``hits`` bit-identical to the full scan (and to the NumPy oracle) on
  every backend, for exact and wildcard/IUPAC queries, before and after
  corpus growth;
* the index is **incrementally resident** -- built lazily once
  (``sig_pack_count <= 1``), kept current by ``append_rows`` / ``set_rows``
  splices of exactly the touched rows, zero-extended across capacity
  growth, dropped by ``invalidate``;
* the **planner's two-stage cost model** picks filter-then-verify for
  selective queries at scale, falls back to the full scan for dense /
  unprunable / ineligible queries, and honors the query hints;
* the **service** routes eligible queries through the index transparently
  and reports filter hit-rate / survivor fraction (plus the per-tick
  launch and cache-hit-rate satellites).
"""

import numpy as np
import pytest

from repro.core import encoding
from repro.core.matcher import sliding_scores, sliding_scores_masks
from repro.kernels.filter_qgram import (FILTER_ROW_TILE, filter_qgram,
                                        filter_qgram_ref)
from repro.match import (CorpusIndex, MatchEngine, MatchQuery,
                         MatchService, PackedCorpus, Planner,
                         build_query_filter)
from repro.match.index import (binom_cdf, hash_bits, qgram_values,
                               row_signatures)

R0, F, P = 48, 96, 16


def make_engine(r=R0, f=F, seed=0, planted=(), pat=None, **kw):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (r, f), np.uint8)
    if pat is not None:
        for row in planted:
            off = int(rng.integers(0, f - len(pat) + 1))
            frags[row, off:off + len(pat)] = pat
    return rng, frags, MatchEngine(frags, **kw)


def naive_row_bits(row, q, n_bits):
    """Set of signature bits a row's q-grams occupy (python reference)."""
    vals = [int(qgram_values(row[j:j + q], q)[0])
            for j in range(len(row) - q + 1)]
    return set(int(b) for b in hash_bits(np.asarray(vals, np.uint32),
                                         n_bits))


def unpack_sig(words):
    """(Wb,) uint32 signature words -> set of set bit indices."""
    return {w * 32 + b for w in range(len(words)) for b in range(32)
            if (int(words[w]) >> b) & 1}


class TestSignatures:
    def test_row_signature_matches_naive(self):
        rng = np.random.default_rng(1)
        rows = rng.integers(0, 4, (5, 40), np.uint8)
        words, counts = row_signatures(rows, 4, 256)
        for r in range(5):
            want = naive_row_bits(rows[r], 4, 256)
            assert unpack_sig(words[r]) == want
            assert counts[r] == len(want)

    def test_query_signature_drops_wildcard_spanning_qgrams(self):
        pat = np.random.default_rng(7).integers(0, 4, 12, np.uint8)
        masks = (np.uint8(1) << pat).astype(np.uint8)
        full = build_query_filter(masks[None, :], [12.0], 4, 256)
        masks_w = masks.copy()
        masks_w[5] = 0b1111                   # N wildcard at position 5
        part = build_query_filter(masks_w[None, :], [12.0], 4, 256)
        # Grams starting at 2..5 span position 5; of 9 gram positions, 4
        # are dropped.  Remaining bits are a subset of the exact query's.
        assert part.n_bits[0] < full.n_bits[0]
        assert unpack_sig(part.qsig_words[0]) <= \
            unpack_sig(full.qsig_words[0])

    def test_all_wildcard_pattern_has_no_bits(self):
        masks = np.full((1, 8), 0b1111, np.uint8)
        ops = build_query_filter(masks, [8.0], 4, 256)
        assert ops.n_bits == (0,)

    def test_slack_from_threshold(self):
        masks = (np.uint8(1) << (np.arange(10, dtype=np.uint8) % 4))
        ops = build_query_filter(masks[None, :], [10.0, 8.0, 10.5], 4, 256)
        assert ops.slacks == (0, 8, -1)       # e=0, e=2 -> 2q, unsatisfiable

    def test_binom_cdf_sane(self):
        assert binom_cdf(-1, 10, 0.5) == 0.0
        assert binom_cdf(10, 10, 0.5) == 1.0
        assert abs(binom_cdf(5, 10, 0.5) - 0.623046875) < 1e-9


class TestFilterKernel:
    def test_kernel_matches_ref(self):
        rng = np.random.default_rng(2)
        sigs = rng.integers(0, 2**32, (FILTER_ROW_TILE * 2, 8),
                            dtype=np.uint32)
        qsig = rng.integers(0, 2**32, (1, 8), dtype=np.uint32)
        for slack in (0, 3, 17, -1):
            got = np.asarray(filter_qgram(sigs, qsig, slack=slack,
                                          interpret=True))[:, 0]
            np.testing.assert_array_equal(
                got, filter_qgram_ref(sigs, qsig, slack))

    def test_kernel_rejects_unpadded_rows(self):
        with pytest.raises(ValueError, match="padded"):
            filter_qgram(np.zeros((7, 8), np.uint32),
                         np.zeros((1, 8), np.uint32), slack=0,
                         interpret=True)


class TestIndexResidency:
    def test_lazy_pack_once(self):
        _, _, eng = make_engine()
        ix = eng.index
        assert ix.sig_pack_count == 0         # nothing until first use
        ix.signatures()
        ix.signatures()
        assert ix.sig_pack_count == 1

    def test_append_splices_only_touched_rows(self):
        rng, frags, eng = make_engine(seed=3)
        ix = eng.index
        ix.signatures()
        new = rng.integers(0, 4, (3, F), np.uint8)
        eng.corpus.append_rows(new)
        assert ix.sig_pack_count == 1         # no repack
        assert ix.row_update_count == 3
        got = np.asarray(ix.signatures())[R0:R0 + 3]
        want, _ = row_signatures(new, ix.q, ix.n_bits)
        np.testing.assert_array_equal(got, want)

    def test_set_rows_replaces_signature(self):
        rng, frags, eng = make_engine(seed=4)
        ix = eng.index
        ix.signatures()
        new = rng.integers(0, 4, (1, F), np.uint8)
        eng.corpus.set_rows(5, new)
        got = np.asarray(ix.signatures())[5]
        want, _ = row_signatures(new, ix.q, ix.n_bits)
        np.testing.assert_array_equal(got, want[0])

    def test_capacity_growth_extends_device_form(self):
        rng, frags, eng = make_engine(seed=5)
        ix = eng.index
        ix.signatures()
        rows0 = ix._sigs.shape[0]
        while eng.corpus.capacity_padded <= rows0:   # force a device extend
            eng.corpus.append_rows(rng.integers(0, 4, (32, F), np.uint8))
        assert ix._sigs.shape[0] >= ix._rows_padded
        assert ix._sigs.shape[0] % FILTER_ROW_TILE == 0
        assert ix.sig_pack_count == 1

    def test_invalidate_drops_form(self):
        _, _, eng = make_engine(seed=6)
        ix = eng.index
        ix.signatures()
        eng.corpus.invalidate()
        assert ix._sigs is None
        ix.signatures()
        assert ix.sig_pack_count == 2

    def test_index_validates_parameters(self):
        corpus = PackedCorpus(np.zeros((4, 16), np.uint8))
        with pytest.raises(ValueError, match="power of two"):
            CorpusIndex(corpus, n_bits=48)
        with pytest.raises(ValueError, match="q must be"):
            CorpusIndex(corpus, q=0)
        with pytest.raises(ValueError, match="shorter than"):
            CorpusIndex(PackedCorpus(np.zeros((4, 2), np.uint8)), q=4)

    def test_engine_rejects_foreign_index(self):
        a = PackedCorpus(np.zeros((4, 16), np.uint8))
        b = np.zeros((4, 16), np.uint8)
        ix = CorpusIndex(a)
        with pytest.raises(ValueError, match="different corpus"):
            MatchEngine(b, index=ix)

    def test_engines_share_one_index_and_detach_stops_updates(self):
        rng = np.random.default_rng(7)
        corpus = PackedCorpus(rng.integers(0, 4, (R0, F), np.uint8))
        a, b = MatchEngine(corpus), MatchEngine(corpus)
        assert a.index is b.index                  # no observer stacking
        assert len(corpus._indexes) == 1
        old = a.index
        old.signatures()
        corpus.detach_index(old)
        corpus.append_rows(rng.integers(0, 4, (2, F), np.uint8))
        assert old.row_update_count == 0           # no longer notified


THR = float(P)


class TestFilteredOracle:
    """Filtered == full scan == NumPy oracle, bit for bit."""

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_exact_threshold_all_backends(self, backend):
        rng = np.random.default_rng(10)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=10, planted=(5, 17), pat=pat)
        oracle = sliding_scores(frags, pat)
        for thr in (THR, THR - 2.0):
            fil = eng.match(MatchQuery.exact(
                pat, reduction="threshold", threshold=thr, filter=True,
                backend=backend))
            scan = eng.match(MatchQuery.exact(
                pat, reduction="threshold", threshold=thr, filter=False,
                backend=backend))
            assert fil.plan.strategy == "filter"
            assert scan.plan.strategy == "scan"
            np.testing.assert_array_equal(fil.hits, scan.hits)
            want = np.argwhere(oracle >= thr)
            np.testing.assert_array_equal(scan.hits[:, :2], want)
        assert {5, 17} <= set(fil.survivor_rows.tolist())
        assert 0 < fil.survivor_frac < 1

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_wildcard_threshold_all_backends(self, backend):
        rng = np.random.default_rng(11)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=11, planted=(3,), pat=pat)
        masks = (np.uint8(1) << pat).astype(np.uint8)
        masks[[2, 9]] = 0b1111                # N wildcards
        oracle = sliding_scores_masks(frags, masks)
        fil = eng.match(MatchQuery.from_masks(
            masks, reduction="threshold", threshold=THR, filter=True,
            backend=backend))
        scan = eng.match(MatchQuery.from_masks(
            masks, reduction="threshold", threshold=THR, filter=False,
            backend=backend))
        assert fil.plan.strategy == "filter"
        np.testing.assert_array_equal(fil.hits, scan.hits)
        np.testing.assert_array_equal(
            scan.hits[:, :2], np.argwhere(oracle >= THR))
        assert (fil.hits[:, 0] == 3).any()

    def test_iupac_query_filters(self):
        eng = MatchEngine(np.tile(encoding.encode_dna("ACGTACGTACGT"),
                                  (12, 1)))
        fil = eng.match(MatchQuery.iupac("ACGTRCGT", reduction="threshold",
                                         threshold=8, filter=True))
        scan = eng.match(MatchQuery.iupac("ACGTRCGT", reduction="threshold",
                                          threshold=8, filter=False))
        assert fil.plan.strategy == "filter"
        np.testing.assert_array_equal(fil.hits, scan.hits)
        assert fil.hits.shape[0] == 12 * 2    # two alignments per row

    def test_batched_per_query_thresholds(self):
        rng = np.random.default_rng(12)
        pats = rng.integers(0, 4, (3, P), np.uint8)
        _, frags, eng = make_engine(seed=12)
        frags[7, 5:5 + P] = pats[0]
        frags[30, 11:11 + P] = pats[2]
        eng = MatchEngine(frags)
        thrs = [THR, THR - 1.0, THR]
        fil = eng.match(MatchQuery.exact(
            pats, mode="batched", reduction="threshold", threshold=thrs,
            filter=True))
        scan = eng.match(MatchQuery.exact(
            pats, mode="batched", reduction="threshold", threshold=thrs,
            filter=False))
        assert fil.plan.strategy == "filter"
        np.testing.assert_array_equal(fil.hits, scan.hits)
        assert {7, 30} <= set(fil.hits[:, 0].tolist())

    def test_zero_survivors_well_formed(self):
        rng = np.random.default_rng(13)
        _, frags, eng = make_engine(seed=13)
        pat = rng.integers(0, 4, P, np.uint8)   # no planted needle
        res = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=THR, filter=True))
        if res.survivor_frac == 0.0:            # typical for random data
            assert res.hits.shape == (0, 3)
            assert res.best_scores.shape == (0,)
            assert res.survivor_rows.shape == (0,)
        scan = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=THR, filter=False))
        np.testing.assert_array_equal(res.hits, scan.hits)

    def test_unsatisfiable_threshold_prunes_everything(self):
        rng = np.random.default_rng(14)
        _, frags, eng = make_engine(seed=14)
        pat = rng.integers(0, 4, P, np.uint8)
        res = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=P + 1.0, filter=True))
        assert res.survivor_frac == 0.0 and res.hits.shape == (0, 3)

    def test_hits_sorted_like_full_scan(self):
        """Survivor order is ascending corpus rows, so hit order matches
        the chunk-streamed full scan exactly (part of bit-identity)."""
        rng = np.random.default_rng(15)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=15, planted=(40, 2, 21), pat=pat)
        fil = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=THR - 1, filter=True))
        assert (np.diff(fil.hits[:, 0]) >= 0).all()


class TestFilteredAcrossGrowth:
    def test_compiled_filter_survives_append(self):
        rng = np.random.default_rng(20)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=20, planted=(9,), pat=pat)
        cm = eng.compile(MatchQuery.exact(
            pat, reduction="threshold", threshold=THR, filter=True))
        r1 = cm.run()
        assert r1.plan.strategy == "filter"
        ops_before = cm._filter_ops
        planted = np.zeros(F, np.uint8)
        planted[4:4 + P] = pat
        eng.corpus.append_rows(planted)
        r2 = cm.run()                          # same compiled object
        assert r2.plan.strategy == "filter"
        assert cm._filter_ops is not None
        np.testing.assert_array_equal(
            cm._filter_ops.qsig_words, ops_before.qsig_words)
        assert (r2.hits[:, 0] == R0).any()     # new row's hit observed
        scan = eng.match(MatchQuery.exact(
            pat, reduction="threshold", threshold=THR, filter=False))
        np.testing.assert_array_equal(r2.hits, scan.hits)

    def test_append_while_filtering_no_repacks(self):
        rng = np.random.default_rng(21)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=21, planted=(1,), pat=pat)
        q = MatchQuery.exact(pat, reduction="threshold", threshold=THR,
                             filter=True)
        eng.match(q)
        for _ in range(3):
            row = np.zeros(F, np.uint8)
            row[7:7 + P] = pat
            eng.corpus.append_rows(row)
            fil = eng.match(q)
            scan = eng.match(MatchQuery.exact(
                pat, reduction="threshold", threshold=THR, filter=False))
            np.testing.assert_array_equal(fil.hits, scan.hits)
        assert eng.index.sig_pack_count == 1   # spliced, never repacked

    def test_selectivity_feedback_recorded(self):
        rng = np.random.default_rng(22)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=22, planted=(0, 1, 2, 3), pat=pat)
        assert eng.index.n_filter_runs == 0
        eng.match(MatchQuery.exact(pat, reduction="threshold",
                                   threshold=THR, filter=True))
        assert eng.index.n_filter_runs == 1
        assert eng.index.last_survivor_frac >= 4 / R0
        assert eng.index._calibration is not None


class TestPlannerStrategy:
    def big_engine(self, rows=20000, f=256):
        # Reserved capacity + live zero rows: planning never runs kernels,
        # so a large corpus is cheap to stand up for decision tests.
        rng = np.random.default_rng(30)
        return MatchEngine(rng.integers(0, 4, (rows, f), np.uint8))

    def test_selective_filters_dense_scans_at_scale(self):
        eng = self.big_engine()
        pat = np.random.default_rng(32).integers(0, 4, 32, np.uint8)
        sel = eng.compile(MatchQuery.exact(pat, reduction="threshold",
                                           threshold=32.0))
        dense = eng.compile(MatchQuery.exact(pat, reduction="threshold",
                                             threshold=5.0))
        assert sel.plan.strategy == "filter"
        assert sel.plan.filter_words == eng.index.sig_words
        assert sel.plan.est_survivor_frac < 0.01
        assert dense.plan.strategy == "scan"
        assert "filter" in sel.plan.reason

    def test_small_corpus_scans_without_hint(self):
        _, _, eng = make_engine()
        pat = np.arange(P, dtype=np.uint8) % 4
        cm = eng.compile(MatchQuery.exact(pat, reduction="threshold",
                                          threshold=THR))
        assert cm.plan.strategy == "scan"      # dispatch overhead dominates

    def test_filter_false_hint_always_scans(self):
        eng = self.big_engine()
        pat = np.arange(32, dtype=np.uint8) % 4
        cm = eng.compile(MatchQuery.exact(pat, reduction="threshold",
                                          threshold=32.0, filter=False))
        assert cm.plan.strategy == "scan"

    def test_index_disabled_engine_scans(self):
        rng = np.random.default_rng(31)
        eng = MatchEngine(rng.integers(0, 4, (R0, F), np.uint8),
                          index=False)
        assert eng.index is None
        pat = rng.integers(0, 4, P, np.uint8)
        res = eng.match(MatchQuery.exact(pat, reduction="threshold",
                                         threshold=THR, filter=True))
        assert res.plan.strategy == "scan"     # hint is moot without index

    def test_non_threshold_reductions_never_filter(self):
        eng = self.big_engine()
        pat = np.arange(32, dtype=np.uint8) % 4
        for red, kw in (("best", {}), ("topk", {"k": 3}), ("full", {})):
            cm = eng.compile(MatchQuery.exact(pat, reduction=red, **kw))
            assert cm.plan.strategy == "scan"

    def test_filter_hint_rejected_for_row_dense_reductions(self):
        with pytest.raises(ValueError, match="threshold"):
            MatchQuery.exact(np.zeros(4, np.uint8), filter=True)

    def test_rows_subset_never_filters(self):
        eng = self.big_engine()
        pat = np.arange(32, dtype=np.uint8) % 4
        cm = eng.compile(MatchQuery.exact(
            pat, reduction="threshold", threshold=32.0, rows=range(64)))
        assert cm.plan.strategy == "scan"

    def test_unprunable_wildcards_scan(self):
        """A pattern whose every q-gram spans a wildcard has no signature
        bits -- the filter cannot prune and must not be chosen."""
        eng = self.big_engine()
        masks = np.full(32, 0b1111, np.uint8)  # all-N pattern
        cm = eng.compile(MatchQuery.from_masks(
            masks, reduction="threshold", threshold=32.0, filter=True))
        assert cm.plan.strategy == "scan"

    def test_planner_plan_accepts_filter_ctx(self):
        from repro.match import FilterContext
        pl = Planner()
        ctx = FilterContext(sig_words=8, n_queries=1, prunable=True,
                            survivor_frac=1e-5)
        p = pl.plan(n_rows=100000, fragment_chars=256, pattern_chars=32,
                    predicate="exact", filter_ctx=ctx)
        assert p.strategy == "filter"
        assert p.est_seconds < pl.plan(
            n_rows=100000, fragment_chars=256, pattern_chars=32,
            predicate="exact").est_seconds


class TestServiceFilterRouting:
    def make_service(self, seed=40):
        rng = np.random.default_rng(seed)
        pat = rng.integers(0, 4, P, np.uint8)
        _, frags, eng = make_engine(seed=seed, planted=(4, 9), pat=pat)
        return rng, pat, eng, MatchService(eng)

    def test_filtered_launch_counted_and_identical(self):
        rng, pat, eng, svc = self.make_service()
        t = svc.submit(MatchQuery.exact(pat, reduction="threshold",
                                        threshold=THR, filter=True))
        svc.flush()
        want = eng.match(MatchQuery.exact(pat, reduction="threshold",
                                          threshold=THR, filter=False))
        np.testing.assert_array_equal(t.result.hits, want.hits)
        snap = svc.stats.snapshot()
        assert snap["n_filtered_launches"] == 1
        assert snap["filter_hit_rate"] == 1.0
        assert 0 < snap["avg_survivor_frac"] < 1

    def test_coalesced_threshold_group_filters_once(self):
        rng, pat, eng, svc = self.make_service(41)
        pats = [pat] + [rng.integers(0, 4, P, np.uint8) for _ in range(3)]
        tickets = [svc.submit(MatchQuery.exact(
            p, reduction="threshold", threshold=THR, filter=True))
            for p in pats]
        svc.flush()
        assert svc.stats.n_coalesced_launches == 1
        assert svc.stats.n_filtered_launches == 1   # union filter, 1 launch
        for t, p in zip(tickets, pats):
            want = eng.match(MatchQuery.exact(
                p, reduction="threshold", threshold=THR, filter=False))
            np.testing.assert_array_equal(t.result.hits, want.hits)

    def test_per_tick_and_cache_stats(self):
        rng, pat, eng, svc = self.make_service(42)
        q = MatchQuery.exact(pat, reduction="threshold", threshold=THR)
        svc.submit(q)
        svc.tick()
        assert svc.stats.n_ticks == 1
        assert svc.stats.launches_last_tick == 1
        svc.submit(q)                          # result-cache hit
        svc.tick()
        snap = svc.stats.snapshot()
        assert snap["n_ticks"] == 2
        assert snap["launches_last_tick"] == 0
        assert snap["cache_hit_rate"] == 0.5
        assert snap["avg_launches_per_tick"] == 0.5

    def test_empty_tick_resets_last_tick_launches(self):
        rng, pat, eng, svc = self.make_service(43)
        svc.submit(MatchQuery.exact(pat))
        svc.tick()
        assert svc.stats.launches_last_tick == 1
        svc.tick()
        assert svc.stats.launches_last_tick == 0


class TestReserveShrink:
    def test_reserve_below_live_rows_raises(self):
        rng = np.random.default_rng(50)
        corpus = PackedCorpus(rng.integers(0, 4, (R0, F), np.uint8))
        with pytest.raises(ValueError) as ei:
            corpus.reserve(R0 - 5)
        msg = str(ei.value)
        assert f"{R0} live rows" in msg and str(R0 - 5) in msg

    def test_reserve_between_live_and_capacity_is_noop(self):
        rng = np.random.default_rng(51)
        corpus = PackedCorpus(rng.integers(0, 4, (R0, F), np.uint8),
                              capacity=4 * R0)
        corpus.reserve(2 * R0)                 # can't shrink; no-op
        assert corpus.capacity == 4 * R0
