"""Training-data near-dup filtering with the CRAM-PM match engine.

The paper's row-parallel string matcher doing production data-plane work:
documents are fingerprinted into the 2-bit alphabet and matched against a
device-resident store through the match engine; near-duplicates (including
shifted copies) are dropped before they reach the tokenizer.  Each add is
an in-place packed-row append into a growable corpus: capacity doubles on
device, the engine survives growth, and the resident rows are never
repacked -- the store ingests while it serves.

Run:  PYTHONPATH=src python examples/dedup_pipeline.py
"""

import numpy as np

from repro.data.dedup import CRAMDedup


def main() -> None:
    rng = np.random.default_rng(0)
    base_docs = [rng.bytes(300) for _ in range(20)]
    corpus = []
    for d in base_docs:
        corpus.append(d)
        if rng.random() < 0.5:
            corpus.append(d)                       # exact dup
        if rng.random() < 0.3:
            corpus.append(d[3:] + rng.bytes(3))    # shifted near-dup
        if rng.random() < 0.3:
            mutated = bytearray(d)
            for i in rng.integers(0, len(d), 4):
                mutated[i] ^= 0xFF
            corpus.append(bytes(mutated))          # lightly mutated dup
    rng.shuffle(corpus)

    dedup = CRAMDedup(threshold=0.85)
    engine_before = dedup.engine                   # held for the lifetime
    kept = dedup.filter(corpus)
    assert dedup.engine is engine_before           # growth never rebuilds it
    print(f"corpus {len(corpus)} docs -> kept {len(kept)} "
          f"({len(corpus) - len(kept)} near-dups dropped)")
    # every base doc survives; the large majority of injected dups drop
    assert len(base_docs) <= len(kept) <= len(base_docs) + 5
    print("store rows (one fingerprint per CRAM row):", len(dedup))
    print(f"engine store: capacity {dedup.capacity} rows, "
          f"{dedup.total_host_packs} full pack(s), "
          f"{dedup.total_row_writes} incremental row writes, "
          f"planner backend for queries: "
          f"{dedup.engine.plan(np.zeros(dedup.pattern_len, np.uint8)).backend}")


if __name__ == "__main__":
    main()
