"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
contraction *within* chunks + a linear recurrence *across* chunks -- the
same compute shape as the paper's reduction tree (local combine, global
carry), which is why it scans/shards cleanly.  Decode is the O(1) recurrent
state update.

Head count is padded to the TP width (cfg.ssd_heads); d_inner follows as
heads * head_dim.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import COMPUTE_DTYPE
from .spec import P


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.ssd_heads * cfg.ssm_head_dim


def ssd_specs(cfg: ModelConfig) -> Dict[str, P]:
    d, N, H = cfg.d_model, cfg.ssm_state, cfg.ssd_heads
    di = _d_inner(cfg)
    kc = cfg.ssm_conv
    return {
        "wz": P((d, di), ("embed", "heads_inner")),
        "wx": P((d, di), ("embed", "heads_inner")),
        "wB": P((d, N), ("embed", None)),
        "wC": P((d, N), ("embed", None)),
        "wdt": P((d, H), ("embed", "heads")),
        "dt_bias": P((H,), ("heads",), "zeros"),
        "A_log": P((H,), ("heads",), "zeros"),
        "D": P((H,), ("heads",), "ones"),
        "conv_x": P((kc, di), (None, "heads_inner"), "normal"),
        "conv_B": P((kc, N), (None, None), "normal"),
        "conv_C": P((kc, N), (None, None), "normal"),
        "norm": P((di,), ("heads_inner",), "ones"),
        "wo": P((di, d), ("heads_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, kernel k.  x (B,S,C), w (k,C).
    state (B,k-1,C) holds the trailing context for decode; returns
    (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y, new_state


def ssd_apply(cfg: ModelConfig, p, x, *, mode: str,
              cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B,S,d) -> (y (B,S,d), new_cache)."""
    B, S, d = x.shape
    H, N, Pd = cfg.ssd_heads, cfg.ssm_state, cfg.ssm_head_dim
    di = _d_inner(cfg)
    z = x @ p["wz"].astype(x.dtype)
    xs = x @ p["wx"].astype(x.dtype)
    Bv = x @ p["wB"].astype(x.dtype)
    Cv = x @ p["wC"].astype(x.dtype)
    dt = jax.nn.softplus(
        (x @ p["wdt"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"]).astype(jnp.float32)                       # (B,S,H)
    conv_state = cache.get("conv") if cache else None
    packed = jnp.concatenate([xs, Bv, Cv], -1)
    wconv = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], -1)
    packed, new_conv = _causal_conv(packed, wconv, conv_state)
    packed = jax.nn.silu(packed)
    xs, Bv, Cv = jnp.split(packed, [di, di + N], axis=-1)

    A = -jnp.exp(p["A_log"])                                      # (H,)
    log_a = dt * A                                                # (B,S,H) <= 0

    if mode == "decode":
        assert S == 1 and cache is not None
        h = cache["state"]                                        # (B,H,Pd,N)
        a = jnp.exp(log_a[:, 0])                                  # (B,H)
        xh = xs[:, 0].reshape(B, H, Pd)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bv[:, 0], xh)
        h = h * a[:, :, None, None] + dBx.astype(h.dtype)
        y = jnp.einsum("bhpn,bn->bhp", h, Cv[:, 0])
        y = y + p["D"].astype(y.dtype)[None, :, None] * xh
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_cache = {"conv": new_conv, "state": h}
    else:
        c = min(cfg.ssm_chunk, S)
        nc = S // c
        assert nc * c == S, "seq must divide ssm_chunk"
        xc = xs.reshape(B, nc, c, H, Pd)
        Bc = Bv.reshape(B, nc, c, N)
        Cc = Cv.reshape(B, nc, c, N)
        dtc = dt.reshape(B, nc, c, H)
        lac = log_a.reshape(B, nc, c, H)
        La = jnp.cumsum(lac, axis=2)                              # (B,nc,c,H)
        # Intra-chunk (the "duality" quadratic form).  n = chunk, m = state.
        intra_dt = COMPUTE_DTYPE if cfg.ssd_bf16_intra else jnp.float32
        G = jnp.einsum("bnim,bnjm->bnij",
                       Cc.astype(jnp.float32),
                       Bc.astype(jnp.float32)).astype(intra_dt)
        decay = jnp.exp(La[:, :, :, None, :]
                        - La[:, :, None, :, :]).astype(intra_dt)
        ii = jnp.arange(c)
        causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
        M = jnp.where(causal, G[..., None] * decay
                      * dtc[:, :, None, :, :].astype(intra_dt),
                      jnp.zeros((), intra_dt))
        y_intra = jnp.einsum("bnijh,bnjhp->bnihp", M.astype(COMPUTE_DTYPE),
                             xc.astype(COMPUTE_DTYPE))
        # Chunk states + inter-chunk linear recurrence.
        tail = jnp.exp(La[:, :, -1:, :] - La)                     # (B,nc,c,H)
        chunk_state = jnp.einsum(
            "bnch,bncm,bnchp->bnhpm",
            (tail * dtc).astype(COMPUTE_DTYPE), Bc.astype(COMPUTE_DTYPE),
            xc.astype(COMPUTE_DTYPE))
        a_chunk = jnp.exp(La[:, :, -1, :])                        # (B,nc,H)

        h0 = (cache["state"].astype(jnp.float32) if cache and "state" in cache
              else jnp.zeros((B, H, Pd, N), jnp.float32))

        def scan_fn(h, inp):
            s_n, a_n = inp  # (B,H,Pd,N), (B,H)
            out_h = h
            h = h * a_n[:, :, None, None] + s_n
            return h, out_h

        cs = jnp.moveaxis(chunk_state.astype(jnp.float32), 1, 0)  # (nc,B,H,Pd,N)
        ac = jnp.moveaxis(a_chunk, 1, 0)                          # (nc,B,H)
        h_final, h_prevs = jax.lax.scan(scan_fn, h0, (cs, ac))
        h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # (B,nc,H,Pd,N)
        y_inter = jnp.einsum(
            "bncm,bnch,bnhpm->bnchp",
            Cc.astype(jnp.float32), jnp.exp(La), h_prevs)
        y = (y_intra.astype(jnp.float32) + y_inter)
        y = y + p["D"][None, None, None, :, None] * xc.astype(jnp.float32)
        y = y.reshape(B, S, di).astype(x.dtype)
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "state": h_final.astype(COMPUTE_DTYPE)}

    # Gated RMSNorm + output projection (Mamba-2 block epilogue).
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(jnp.mean(jnp.square(y32), -1, keepdims=True)
                             + 1e-6) * p["norm"]).astype(x.dtype)
    return y @ p["wo"].astype(x.dtype), new_cache


def ssd_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, P]:
    H, N, Pd = cfg.ssd_heads, cfg.ssm_state, cfg.ssm_head_dim
    di = _d_inner(cfg)
    ch = di + 2 * N
    return {
        "conv": P((batch, cfg.ssm_conv - 1, ch), ("batch", None, None),
                  "zeros", COMPUTE_DTYPE),
        "state": P((batch, H, Pd, N), ("batch", "heads", None, None),
                   "zeros", COMPUTE_DTYPE),
    }
