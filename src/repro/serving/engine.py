"""Serving engine: batched prefill + decode with slot management.

Continuous-batching-lite: a fixed pool of decode slots; finished requests
free their slot and queued prompts are prefilled into it (cache rows are
per-slot, so admission is a cache write, not a recompile).  Greedy sampling
(argmax) keeps the engine deterministic for tests; the sampler is
pluggable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int,
                 n_slots: int,
                 sampler: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.caches = model.init_cache(cfg, n_slots, max_seq)
        self._decode = jax.jit(
            lambda p, c, t, i: model.decode_step(cfg, p, c, t, i))
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots

    # -- admission -----------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def add(self, req: Request) -> bool:
        if len(req.prompt) == 0:
            # A zero-length prompt has no logits to seed decoding from
            # (the prefill loop below would never run).
            raise ValueError("empty prompt: at least one token required")
        if len(req.prompt) > self.max_seq - 1:
            # Cache rows past max_seq-1 don't exist; the scatter write
            # would silently drop those positions and decode garbage.
            raise ValueError(f"prompt length {len(req.prompt)} exceeds "
                             f"max_seq-1 ({self.max_seq - 1})")
        slot = self._free_slot()
        if slot is None:
            return False
        # Per-slot prefill: decode the prompt token by token into the slot's
        # cache rows (keeps a single compiled decode program; a batched
        # prefill program is used by the launcher for cold starts).  Every
        # decode call writes KV for *all* slots, so each slot must write at
        # its own position: the admitted slot at its growing prefill
        # position, every other slot at its next free row (slot_pos), where
        # the junk is overwritten by that slot's own next real decode and
        # its causal mask (kv_pos <= pos) never attends it meanwhile.
        for t, tok in enumerate(req.prompt):
            toks = np.zeros((self.n_slots, 1), np.int32)
            toks[slot, 0] = tok
            # Copy: device_put can alias the numpy buffer zero-copy on CPU,
            # and slot_pos is mutated below while the dispatch is in flight.
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(np.array(self.slot_pos)))
            self.slot_pos[slot] += 1
        self.slot_req[slot] = req
        req._last_logits = np.asarray(logits[slot])  # type: ignore
        return True

    # -- decode --------------------------------------------------------------
    def step(self) -> None:
        """One batched decode step across all active slots."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        active = []
        for i, r in enumerate(self.slot_req):
            if r is None or r.done:
                continue
            last = r.out[-1] if r.out else int(
                np.argmax(r._last_logits))  # type: ignore
            if not r.out:
                r.out.append(last)
            toks[i, 0] = r.out[-1]
            active.append(i)
        if not active:
            return
        # Per-slot positions: slots admitted with shorter prompts sit at
        # lower positions than their neighbors; decoding all of them at
        # max(slot_pos) would write their KV rows at the wrong positions
        # (and rotate queries with the wrong phase) as soon as slot
        # lengths diverge.
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(np.array(self.slot_pos)))
        nxt = np.asarray(self.sampler(logits))
        for i in active:
            r = self.slot_req[i]
            r.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new or self.slot_pos[i] >= self.max_seq - 1:
                r.done = True
                self.slot_req[i] = None
                # Reset the freed slot to position 0: the next admission
                # prefills from the start, and the causal mask
                # (kv_pos <= pos) hides the previous occupant's stale KV
                # rows until they are overwritten.  Leaving the position
                # where the old request ended would make a reused slot
                # attend its predecessor's cache.
                self.slot_pos[i] = 0

    def run(self, requests: List[Request], max_steps: int = 10_000) -> None:
        queue = list(requests)
        steps = 0
        while (queue or any(self.slot_req)) and steps < max_steps:
            while queue and self.add(queue[0]):
                queue.pop(0)
            self.step()
            steps += 1


def generate_greedy(cfg: ModelConfig, params, prompts: np.ndarray,
                    max_new: int, max_seq: int) -> np.ndarray:
    """Simple batched prefill+decode generation (examples/tests).

    prompts: (B, S) int32 -> (B, max_new) int32 greedy continuations.
    """
    B, S = prompts.shape
    caches = model.init_cache(cfg, B, max_seq)
    logits, caches = model.prefill(
        cfg, params, {"tokens": jnp.asarray(prompts)}, caches)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    decode = jax.jit(lambda p, c, t, i: model.decode_step(cfg, p, c, t, i))
    for t in range(max_new):
        out.append(np.asarray(tok)[:, 0])
        logits, caches = decode(params, caches, tok, jnp.int32(S + t))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return np.stack(out, 1)
