"""Cost-model reproduction tests: the paper's headline numbers (Sec. 5)."""

import pytest

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM


@pytest.fixture(scope="module")
def designs():
    return {
        (opt, t.name): cm.Design(tech=t, opt=opt)
        for opt in (False, True) for t in (NEAR_TERM, LONG_TERM)
    }


class TestFig5:
    """Throughput/energy characterization, 3M-pattern DNA pool."""

    def test_naive_hours_matches_paper(self, designs):
        r = cm.run_workload(designs[(False, "near-term")], 3_000_000, "naive")
        assert r.total_time_s / 3600 == pytest.approx(23215.3, rel=0.02)

    def test_oracular_hours_matches_paper(self, designs):
        r = cm.run_workload(designs[(False, "near-term")], 3_000_000, "oracular")
        assert r.total_time_s / 3600 == pytest.approx(2.32, rel=0.15)

    def test_naive_to_oracular_ratio(self, designs):
        n = cm.run_workload(designs[(False, "near-term")], 3_000_000, "naive")
        o = cm.run_workload(designs[(False, "near-term")], 3_000_000, "oracular")
        assert n.total_time_s / o.total_time_s == pytest.approx(1e4, rel=0.15)

    def test_opt_energy_unchanged(self, designs):
        """Paper Sec. 5.1: preset rescheduling leaves energy unchanged."""
        plain = cm.pass_cost(designs[(False, "near-term")])
        opt = cm.pass_cost(designs[(True, "near-term")])
        assert opt.energy_j == pytest.approx(plain.energy_j, rel=1e-6)

    def test_opt_throughput_skyrockets(self, designs):
        plain = cm.pass_cost(designs[(False, "near-term")])
        opt = cm.pass_cost(designs[(True, "near-term")])
        assert plain.latency_s / opt.latency_s > 100


class TestFig6:
    """Energy/latency breakdown (unoptimized design)."""

    def test_preset_latency_dominates(self, designs):
        pc = cm.pass_cost(designs[(False, "near-term")])
        assert pc.share("2_5_presets", "latency") > 0.9

    def test_preset_energy_share(self, designs):
        pc = cm.pass_cost(designs[(False, "near-term")])
        assert pc.share("2_5_presets", "energy") == pytest.approx(0.4386, abs=0.06)

    def test_write_share_below_1pct(self, designs):
        pc = cm.pass_cost(designs[(False, "near-term")])
        assert pc.share("1_write_pattern", "latency") < 0.01
        assert pc.share("1_write_pattern", "energy") < 0.01

    def test_bl_energy_below_1pct(self, designs):
        pc = cm.pass_cost(designs[(False, "near-term")])
        assert pc.share("3_6_bl_drive", "energy") < 0.01

    def test_score_phase_energy_about_double_match_phase(self, designs):
        """Paper: 'the energy required by the similarity score compute phase
        is around twice of that of match phase'."""
        pc = cm.pass_cost(designs[(False, "near-term")])
        ratio = pc.stages["7_score"].energy_j / pc.stages["4_match"].energy_j
        assert 0.7 < ratio < 2.5

    def test_readout_dominates_opt_latency_residual(self, designs):
        """Fig. 6b: with presets excluded, read-outs + additions dominate."""
        pc = cm.pass_cost(designs[(False, "near-term")])
        non_preset = (pc.latency_s - pc.stages["2_5_presets"].latency_s)
        ro_add = (pc.stages["8_readout"].latency_s
                  + pc.stages["7_score"].latency_s)
        assert ro_add / non_preset > 0.5


class TestFig7:
    """Pattern-length sensitivity (OracularOpt)."""

    @pytest.mark.parametrize("plen", [200, 300])
    def test_throughput_stays_close(self, plen):
        base = cm.run_workload(
            cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=100),
            3_000_000, "oracular")
        longer = cm.run_workload(
            cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=plen),
            3_000_000, "oracular")
        # Paper: "throughput remains close to the baseline" -- the scalable
        # gang-preset schedule absorbs most of the extra work.
        assert longer.match_rate > 0.2 * base.match_rate

    @pytest.mark.parametrize("plen", [200, 300])
    def test_efficiency_decreases(self, plen):
        base = cm.run_workload(
            cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=100),
            3_000_000, "oracular")
        longer = cm.run_workload(
            cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=plen),
            3_000_000, "oracular")
        assert longer.efficiency < base.efficiency


class TestFig8:
    def test_long_term_boost(self):
        """Paper: ~2.15x match-rate boost with projected long-term MTJs."""
        near = cm.run_workload(cm.Design(tech=NEAR_TERM, opt=True),
                               3_000_000, "oracular")
        longt = cm.run_workload(cm.Design(tech=LONG_TERM, opt=True),
                                3_000_000, "oracular")
        assert longt.match_rate / near.match_rate == pytest.approx(2.15, abs=0.15)


class TestFig9_10:
    def test_cram_beats_nmp_dna(self):
        d = cm.Design(tech=NEAR_TERM, opt=False)
        cram = cm.run_workload(d, 3_000_000, "oracular")
        nmp = cm.dna_nmp_run(d, 3_000_000)
        assert cram.match_rate / nmp.match_rate > 1e3

    def test_nmp_hyp_faster_than_nmp(self):
        d = cm.Design(tech=NEAR_TERM)
        nmp = cm.dna_nmp_run(d, 1000)
        hyp = cm.dna_nmp_run(d, 1000, hyp=True)
        assert hyp.match_rate > nmp.match_rate

    def test_app_models_all_favor_cram(self):
        for app in cm.table4_apps().values():
            cram = cm.app_cram_run(app, NEAR_TERM)
            nmp = cm.app_nmp_run(app)
            assert cram.match_rate > nmp.match_rate, app.name

    def test_bc_least_benefit_vs_nmp_hyp(self):
        """Paper: BC has the least compute-efficiency benefit vs NMP-Hyp."""
        apps = cm.table4_apps()
        gains = {}
        for name, app in apps.items():
            cram = cm.app_cram_run(app, NEAR_TERM)
            hyp = cm.app_nmp_run(app, hyp=True)
            gains[name] = cram.efficiency / hyp.efficiency
        assert gains["BC"] == min(gains.values())

    def test_long_term_improves_all_apps(self):
        for app in cm.table4_apps().values():
            near = cm.app_cram_run(app, NEAR_TERM)
            longt = cm.app_cram_run(app, LONG_TERM)
            assert longt.match_rate > near.match_rate


class TestFig11:
    def test_not_ratio_vs_ambit(self):
        ratio = cm.bulk_gops("NOT", NEAR_TERM) / cm.AMBIT_GOPS["NOT"]
        assert ratio == pytest.approx(178, rel=0.05)

    def test_xor_ratio_vs_ambit(self):
        ratio = cm.bulk_gops("XOR", NEAR_TERM) / cm.AMBIT_GOPS["XOR"]
        assert ratio == pytest.approx(1.34, rel=0.05)

    def test_pinatubo_or_ratios(self):
        near = cm.bulk_gops("OR", NEAR_TERM) / cm.PINATUBO_OR_GOPS
        longt = cm.bulk_gops("OR", LONG_TERM) / cm.PINATUBO_OR_GOPS
        assert near == pytest.approx(6, rel=0.1)
        assert longt == pytest.approx(12, rel=0.15)

    def test_basic_ops_comparable_on_cram(self):
        """Paper: NOT/OR/NAND throughput 'very comparable' on CRAM-PM."""
        vals = [cm.bulk_gops(op, NEAR_TERM) for op in ("NOT", "OR", "NAND")]
        assert max(vals) / min(vals) < 1.1

    def test_xor_is_third_of_basic(self):
        assert cm.bulk_gops("NOT", NEAR_TERM) / cm.bulk_gops("XOR", NEAR_TERM) \
            == pytest.approx(3.0, rel=0.05)

    def test_long_term_scaling(self):
        r = cm.bulk_gops("NOT", LONG_TERM) / cm.bulk_gops("NOT", NEAR_TERM)
        assert r == pytest.approx(2.15, abs=0.1)


class TestPracticalConsiderations:
    def test_peak_current_below_ddr3_write(self):
        """Sec. 3.4: long-term 128MB-class array draws less than a DDR3
        write burst (~1A)."""
        assert cm.peak_array_current_a(cm.Design(tech=LONG_TERM)) < 1.0

    def test_t_op_gives_2p15x_tech_ratio(self):
        near = cm.Design(tech=NEAR_TERM).t_op_ns
        longt = cm.Design(tech=LONG_TERM).t_op_ns
        assert near / longt == pytest.approx(2.146, abs=0.02)
