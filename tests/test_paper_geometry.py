"""Paper-geometry integration tests: the real Sec. 4 design point
(2400-cell rows, 100-char patterns) runs end to end on the functional
array, and the optimized configs still smoke-run/compile.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, OPTIMIZED_OVERRIDES, get_config
from repro.core.matcher import Matcher, plan_layout, sliding_scores


class TestPaperGeometry:
    def test_full_row_alignment_program(self):
        """One Algorithm-1 iteration at the paper's real row geometry:
        2400 columns, 100-char pattern, ~1000-char fragment."""
        layout = plan_layout(2400, 100, scratch_budget=128)
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (4, layout.fragment_chars), np.uint8)
        pat = rng.integers(0, 4, 100, np.uint8)
        frags[2, 37:137] = pat
        m = Matcher(frags, pattern_chars=100, n_cols=2400)
        m.load_pattern(pat)
        scores = m.run(range(30, 45))            # a window of alignments
        oracle = sliding_scores(frags, pat)[:, 30:45]
        np.testing.assert_array_equal(scores, oracle)
        assert scores[2, 7] == 100               # loc 37 within window

    def test_row_fits_2k_class_width(self):
        layout = plan_layout(2400, 100, scratch_budget=128)
        assert layout.n_cols <= 2400
        assert layout.fragment_chars >= 900


class TestOptimizedConfigs:
    @pytest.mark.parametrize("arch", sorted(OPTIMIZED_OVERRIDES))
    def test_optimized_train_config_constructs(self, arch):
        cfg = get_config(arch, optimized=True, kind="train")
        assert cfg.n_params() > 0

    def test_optimized_serve_smoke_decode(self):
        """int8-KV + padded-KV smoke decode matches the bf16 baseline."""
        import jax
        import jax.numpy as jnp
        from repro.models import model
        base = get_config("llama3.2-1b", smoke=True)
        opt = dataclasses.replace(base, kv_quant=True, pad_kv_heads=True)
        params = model.init_params(base, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, base.vocab, (2, 10)))
        full, _, _ = model.forward(base, params, {"tokens": tokens})
        caches = model.init_cache(opt, 2, 10)
        logits = None
        for t in range(10):
            logits, caches = model.decode_step(
                opt, params, caches, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1]), rtol=0.1,
                                   atol=0.1 * float(jnp.abs(full).max()))

    def test_blockdiag_param_shapes(self):
        cfg = get_config("recurrentgemma-9b", optimized=True, kind="train")
        from repro.models import rglru
        specs = rglru.rglru_specs(cfg)
        assert specs["w_a"].shape == (16, 256, 256)
