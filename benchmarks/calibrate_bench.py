"""Calibrated cost model bench: the three proofs behind DESIGN.md Sec. 3i.

The autotuned table (``repro.match.calibrate``) replaces the static
``TPU_V5E`` constants with curves fitted to the kernels as they actually
run on this substrate.  This bench demonstrates the claim is load-bearing
rather than cosmetic, with three machine-checked proofs:

* **decisions differ** -- over the golden shape matrix the calibrated
  planner must pick a different kernel than the static one on >= 1 real
  shape (on the interpret-mode container it flips the tiny-shape ref
  escape and the large-Q mxu crossover);
* **never slower** -- on every validation-grid shape where the two
  sources disagree, the calibrated choice's *measured* wall time must
  not exceed the static choice's measured wall time (equal choices are
  trivially tied and are not re-measured);
* **feedback converges** -- an engine running with runtime recording
  against a deliberately-wrong source (static pricing in interpret mode
  is off by orders of magnitude) must re-price the hot bucket so its
  post-feedback estimate lands within the 2x drift bound of observed
  wall time.

Emits ``BENCH_match_calibrate.json`` at the repo root.  CI runs
``--smoke``: a fast-grid in-process autotune (no table I/O, so the guard
is self-contained on any runner), the cheap half of the validation grid,
and a shorter feedback loop -- same schema, artifact not rewritten.

The full validation grid deliberately omits the golden matrix's
(R=2048, Q=256) shape: static picks mxu there and measuring that pick in
interpret mode costs tens of seconds for no extra coverage (the same
mxu-vs-swar flip is already proven at R=512, Q=128).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_calibrate.json"

# Validation grid for the never-slower proof (planner vocabulary).  The
# smoke subset keeps only shapes whose static choice is cheap to measure.
FULL_SHAPES = (
    dict(n_rows=2, fragment_chars=20, pattern_chars=8),
    dict(n_rows=64, fragment_chars=128, pattern_chars=16),
    dict(n_rows=512, fragment_chars=1024, pattern_chars=100),
    dict(n_rows=512, fragment_chars=1024, pattern_chars=100, n_patterns=128),
    dict(n_rows=4096, fragment_chars=256, pattern_chars=32, n_patterns=64),
    dict(n_rows=16384, fragment_chars=256, pattern_chars=32),
)
SMOKE_SHAPES = (
    dict(n_rows=2, fragment_chars=20, pattern_chars=8),
    dict(n_rows=64, fragment_chars=128, pattern_chars=16),
)

FULL = dict(repeats=2, shapes=FULL_SHAPES, fb=dict(R=16384, F=256, P=32),
            fb_runs=8, tol=1.25)
SMOKE = dict(repeats=1, shapes=SMOKE_SHAPES, fb=dict(R=2048, F=128, P=16),
             fb_runs=6, tol=1.5)

REQUIRED_KEYS = ("interpret", "smoke", "device_kind", "backend",
                 "calibration", "n_processes", "n_hosts", "table",
                 "decisions", "n_decisions_differ", "never_slower",
                 "feedback")
REQUIRED_NS_KEYS = ("shape", "static_choice", "calibrated_choice", "differs",
                    "static_s", "calibrated_s", "ratio", "ok")
REQUIRED_FB_KEYS = ("runs", "static_base_s", "est_s", "observed_s", "ratio",
                    "converged", "n_repriced", "store")


def _measure_choice(backend: str, shape: dict, interpret: bool,
                    repeats: int) -> float:
    """Measured wall seconds of one planner choice at one query shape.

    Mirrors how the engine actually dispatches each backend: SWAR fuses Q
    patterns as extra row tiles, the MXU batches Q natively, and the jnp
    reference makes Q sequential passes.
    """
    from repro.match import calibrate
    from repro.match.planner import kernel_name

    R, F = shape["n_rows"], shape["fragment_chars"]
    P = shape["pattern_chars"]
    Q = shape.get("n_patterns", 1)
    kernel = kernel_name(backend, shape.get("predicate", "exact"))
    if kernel in ("swar", "swar_masks"):
        rows = -(-max(R, 1) // 8) * 8 * Q
        _, t = calibrate.measure(kernel, dict(R=rows, F=F, P=P),
                                 interpret=interpret, repeats=repeats)
    elif kernel == "mxu":
        _, t = calibrate.measure(kernel, dict(R=max(R, 8), F=F, P=P, Q=Q),
                                 interpret=interpret, repeats=repeats)
    else:
        _, t = calibrate.measure("ref", dict(R=R, F=F, P=P),
                                 interpret=interpret, repeats=repeats)
        t *= Q
    return t


def never_slower_rows(calib_source, cfg: dict, interpret: bool) -> list:
    """Measure static vs. calibrated choices over the validation grid."""
    from repro.core.tech import StaticCostSource
    from repro.match.planner import Planner

    p_static = Planner(cost_source=StaticCostSource())
    p_calib = Planner(cost_source=calib_source)
    rows = []
    for shape in cfg["shapes"]:
        key = ",".join(f"{k}={v}" for k, v in sorted(shape.items()))
        a = p_static.plan(**shape).backend
        b = p_calib.plan(**shape).backend
        if a == b:
            t = _measure_choice(a, shape, interpret, cfg["repeats"])
            ta, tb, ratio, ok = t, t, 1.0, True
        else:
            ta = _measure_choice(a, shape, interpret, cfg["repeats"])
            tb = _measure_choice(b, shape, interpret, cfg["repeats"])
            ratio = tb / max(ta, 1e-12)
            ok = tb <= ta * cfg["tol"]
        rows.append({"shape": key, "static_choice": a,
                     "calibrated_choice": b, "differs": a != b,
                     "static_s": round(ta, 6), "calibrated_s": round(tb, 6),
                     "ratio": round(ratio, 4), "ok": ok})
    return rows


def feedback_convergence(cfg: dict) -> dict:
    """Run a recording engine against static pricing; check convergence.

    Static pricing in interpret mode misses by orders of magnitude, so
    the feedback loop must publish a re-priced factor for the hot
    (kernel, shape-bucket) and the engine's subsequent estimate must land
    within the 2x drift bound of the observed wall time.  The backend is
    pinned so the proof exercises one bucket instead of the explore
    flip-flop between mispriced kernels.
    """
    from repro.match import MatchEngine, MatchQuery

    fb = cfg["fb"]
    rng = np.random.default_rng(7)
    frags = rng.integers(0, 4, (fb["R"], fb["F"]), np.uint8)
    pat = np.ascontiguousarray(frags[0, :fb["P"]])
    eng = MatchEngine(frags, record_runtimes=True)
    q = MatchQuery.exact(pat, backend="swar")

    walls = []
    for _ in range(cfg["fb_runs"]):
        t0 = time.perf_counter()
        eng.match(q)
        walls.append(time.perf_counter() - t0)

    plan = eng.compile(q).plan
    r_price = (plan.n_rows if plan.backend == "ref"
               else -(-plan.n_rows // plan.n_shards))
    price = lambda **kw: eng.planner.backend_seconds(
        plan.backend, r_price, plan.n_locs, plan.pattern_chars,
        plan.n_patterns, plan.predicate, **kw)
    est, base = price(), price(base=True)
    obs = statistics.median(walls[-3:])
    ratio = max(est / obs, obs / est)
    snap = eng.planner.feedback.snapshot()
    return {
        "runs": cfg["fb_runs"],
        "shape": {k: int(v) for k, v in fb.items()},
        "static_base_s": round(base, 8),
        "est_s": round(est, 6),
        "observed_s": round(obs, 6),
        "ratio": round(ratio, 3),
        "converged": ratio <= 2.0,
        "n_repriced": snap["n_repriced"],
        "store": snap,
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not record["calibration"].startswith("calibrated:"):
        raise ValueError("bench did not run under a calibrated source: "
                         f"{record['calibration']!r}")
    if record["n_decisions_differ"] < 1:
        raise ValueError("calibrated decisions identical to static on "
                         "every golden shape: calibration is not "
                         "load-bearing on this substrate")
    if not record["never_slower"]:
        raise ValueError("BENCH record has no never-slower rows")
    for row in record["never_slower"]:
        for key in REQUIRED_NS_KEYS:
            if key not in row:
                raise ValueError(f"never-slower row missing {key!r}: {row}")
        if not row["ok"]:
            raise ValueError(
                f"calibrated choice SLOWER than static on {row['shape']}: "
                f"{row['calibrated_choice']}={row['calibrated_s']}s vs "
                f"{row['static_choice']}={row['static_s']}s")
    fb = record["feedback"]
    for key in REQUIRED_FB_KEYS:
        if key not in fb:
            raise ValueError(f"feedback block missing key {key!r}")
    if not fb["converged"]:
        raise ValueError(
            f"feedback did not converge: est={fb['est_s']}s vs "
            f"observed={fb['observed_s']}s (ratio {fb['ratio']} > 2)")
    if fb["n_repriced"] < 1:
        raise ValueError("feedback loop never re-priced the hot bucket")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.core.tech import StaticCostSource
    from repro.match import calibrate

    cfg = SMOKE if smoke else FULL
    interpret = calibrate.default_interpret()
    if smoke:
        # Self-contained on any runner: fast in-process autotune, no
        # table I/O (the committed table may describe other hardware).
        table = calibrate.autotune(fast=True, interpret=interpret)
        source = table.cost_source()
    else:
        source = calibrate.load_cost_source(interpret=interpret)
        if source is None:
            table = calibrate.autotune(interpret=interpret)
            table.save()
            source = table.cost_source()

    static_dec = calibrate.golden_decisions(StaticCostSource())
    calib_dec = calibrate.golden_decisions(source)
    decisions = [{"shape": k, "static": a, "calibrated": b,
                  "differs": a != b}
                 for (k, a), (_, b) in zip(static_dec, calib_dec)]

    record = {
        "interpret": interpret,
        "smoke": smoke,
        **calibrate.bench_provenance(source),
        "table": {"tag": source.tag,
                  "curves": {k: {"alpha": c.alpha, "beta": c.beta,
                                 "rel_err": c.rel_err,
                                 "n_samples": c.n_samples}
                             for k, c in sorted(source.curves.items())}},
        "decisions": decisions,
        "n_decisions_differ": sum(d["differs"] for d in decisions),
        "never_slower": never_slower_rows(source, cfg, interpret),
        "feedback": feedback_convergence(cfg),
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with the reduced grid.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    fb = record["feedback"]
    rows = [("calibrate/decisions", 0.0,
             f"differ={record['n_decisions_differ']}/"
             f"{len(record['decisions'])} tag={record['calibration']}")]
    rows += [
        (f"calibrate/never_slower[{r['shape']}]",
         round(r["calibrated_s"] * 1e6, 1),
         f"static={r['static_choice']}:{r['static_s']*1e6:.1f}us "
         f"calib={r['calibrated_choice']} ratio={r['ratio']} ok={r['ok']}")
        for r in record["never_slower"]
    ]
    rows.append(("calibrate/feedback", round(fb["observed_s"] * 1e6, 1),
                 f"est_us={fb['est_s']*1e6:.1f} ratio={fb['ratio']} "
                 f"converged={fb['converged']} "
                 f"repriced={fb['n_repriced']}"))
    return rows


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    fb = rec["feedback"]
    n_ok = sum(r["ok"] for r in rec["never_slower"])
    return (f"{BENCH_JSON.name} calib={rec['calibration']} "
            f"differ={rec['n_decisions_differ']}/{len(rec['decisions'])} "
            f"never_slower={n_ok}/{len(rec['never_slower'])} "
            f"fb_ratio={fb['ratio']} repriced={fb['n_repriced']}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast in-process autotune + reduced grid (CI "
                         "schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    print(f"calibration: {record['calibration']} on "
          f"{record['device_kind']}/{record['backend']} "
          f"interpret={record['interpret']}")
    for d in record["decisions"]:
        mark = "DIFF" if d["differs"] else "same"
        print(f"  decision[{d['shape']}] static={d['static']} "
              f"calibrated={d['calibrated']} {mark}")
    for r in record["never_slower"]:
        print(f"  never_slower[{r['shape']}] "
              f"static={r['static_choice']}:{r['static_s']*1e3:.2f}ms "
              f"calib={r['calibrated_choice']}:{r['calibrated_s']*1e3:.2f}ms"
              f" ratio={r['ratio']} ok={r['ok']}")
    fb = record["feedback"]
    print(f"  feedback est={fb['est_s']*1e3:.2f}ms "
          f"observed={fb['observed_s']*1e3:.2f}ms ratio={fb['ratio']} "
          f"converged={fb['converged']} repriced={fb['n_repriced']} "
          f"(static base {fb['static_base_s']*1e3:.4f}ms)")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
