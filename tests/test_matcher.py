"""Matcher (Algorithm 1) + scheduler tests."""

import numpy as np
import pytest

from repro.core import encoding
from repro.core.matcher import (Matcher, best_alignment, compile_alignment,
                                count_alignment_ops, plan_layout,
                                sliding_scores)
from repro.core.scheduler import (KmerIndex, expected_candidates,
                                  schedule_naive, schedule_oracular)


class TestEncoding:
    def test_dna_roundtrip(self):
        s = "ACGTACGTTTGGCCAA"
        assert encoding.decode_dna(encoding.encode_dna(s)) == s

    def test_bits_roundtrip(self):
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 4, (3, 17), np.uint8)
        bits = encoding.codes_to_bits(codes)
        assert bits.shape == (3, 34)
        np.testing.assert_array_equal(encoding.bits_to_codes(bits), codes)

    def test_pack_unpack_u32(self):
        rng = np.random.default_rng(1)
        codes = rng.integers(0, 4, (5, 37), np.uint8)
        words = encoding.pack_codes_u32(codes)
        assert words.shape == (5, 3)  # ceil(37/16)
        np.testing.assert_array_equal(
            encoding.unpack_codes_u32(words, 37), codes)

    def test_fold_reference_overlap(self):
        """Adjacent fragments overlap by P-1 so no alignment is lost."""
        rng = np.random.default_rng(2)
        ref = rng.integers(0, 4, 1000, np.uint8)
        P = 10
        frags = encoding.fold_reference(ref, fragment_len=100, pattern_len=P)
        # Every length-P window of ref appears in some fragment row.
        step = 100 - (P - 1)
        for loc in range(len(ref) - P + 1):
            r = min(loc // step, frags.shape[0] - 1)
            # window must be fully inside row r or row loc//step
            found = False
            for row in range(frags.shape[0]):
                start = row * step
                if start <= loc and loc + P <= start + 100:
                    np.testing.assert_array_equal(
                        frags[row, loc - start: loc - start + P],
                        ref[loc: loc + P])
                    found = True
                    break
            assert found, loc


class TestMatcher:
    def test_scores_match_oracle(self):
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (16, 32), np.uint8)
        pat = rng.integers(0, 4, 8, np.uint8)
        m = Matcher(frags, pattern_chars=8)
        m.load_pattern(pat)
        np.testing.assert_array_equal(m.run(), sliding_scores(frags, pat))

    def test_scores_match_oracle_opt_schedule(self):
        """Gang-preset schedule is functionally identical (paper Sec. 5.1)."""
        rng = np.random.default_rng(4)
        frags = rng.integers(0, 4, (8, 20), np.uint8)
        pat = rng.integers(0, 4, 5, np.uint8)
        m_plain = Matcher(frags, pattern_chars=5, opt=False)
        m_opt = Matcher(frags, pattern_chars=5, opt=True)
        m_plain.load_pattern(pat)
        m_opt.load_pattern(pat)
        np.testing.assert_array_equal(m_plain.run(), m_opt.run())

    def test_per_row_patterns(self):
        rng = np.random.default_rng(1)
        frags = rng.integers(0, 4, (6, 24), np.uint8)
        pats = rng.integers(0, 4, (6, 6), np.uint8)
        m = Matcher(frags, pattern_chars=6)
        m.load_patterns_per_row(pats)
        np.testing.assert_array_equal(m.run(), sliding_scores(frags, pats))

    def test_planted_exact_match_wins(self):
        rng = np.random.default_rng(2)
        frags = rng.integers(0, 4, (4, 40), np.uint8)
        pat = rng.integers(0, 4, 10, np.uint8)
        frags[2, 7:17] = pat
        m = Matcher(frags, pattern_chars=10)
        m.load_pattern(pat)
        locs, scores = best_alignment(m.run())
        assert scores[2] == 10 and locs[2] == 7

    def test_partial_run_locs(self):
        rng = np.random.default_rng(3)
        frags = rng.integers(0, 4, (4, 20), np.uint8)
        pat = rng.integers(0, 4, 5, np.uint8)
        m = Matcher(frags, pattern_chars=5)
        m.load_pattern(pat)
        sub = m.run(range(3, 7))
        full = sliding_scores(frags, pat)
        np.testing.assert_array_equal(sub, full[:, 3:7])

    def test_layout_fits_2k_row(self):
        """Paper geometry: 100-char pattern in a ~2.4K-cell row leaves a
        ~1000-char fragment (Sec. 4 case study)."""
        layout = plan_layout(2400, 100, scratch_budget=128)
        assert 900 <= layout.fragment_chars <= 1050
        assert layout.score_bits == 7

    def test_census_against_paper(self):
        """Per-alignment op census: 7 logic steps per char in Phase 1 + ~188
        FAs in Phase 2 (paper Sec. 3.2)."""
        c = count_alignment_ops(100)
        assert c["NOR"] == 300 and c["TH"] == 200    # 3+2 per char
        assert 180 <= c["FA_COUNT"] <= 200
        assert c["SCORE_BITS"] == 7

    def test_compile_alignment_bounds(self):
        layout = plan_layout(512, 10)
        with pytest.raises(ValueError):
            compile_alignment(layout, layout.n_alignments)


class TestScheduler:
    def test_naive_pass_count(self):
        s = schedule_naive(n_rows=8, n_patterns=5)
        assert s.n_passes == 5
        assert all(len(p) == 8 for p in s.passes)

    def test_oracular_fewer_passes_than_naive(self):
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (32, 64), np.uint8)
        pats = np.stack([
            frags[i % 32, 5:25] for i in range(64)])  # planted patterns
        s = schedule_oracular(frags, pats, k=8)
        assert s.n_passes < 64  # naive would need 64 passes

    def test_oracular_schedules_every_pattern_at_its_home_row(self):
        rng = np.random.default_rng(1)
        frags = rng.integers(0, 4, (16, 48), np.uint8)
        pats = np.stack([frags[i, 10:30] for i in range(16)])
        s = schedule_oracular(frags, pats, k=8)
        # every pattern must be scheduled on its true home row in some pass
        for p in range(16):
            assert any(assign.get(p) == p for assign in s.passes), p

    def test_kmer_index_candidates(self):
        frags = np.array([[0, 1, 2, 3, 0, 1], [3, 2, 1, 0, 3, 2]], np.uint8)
        idx = KmerIndex(frags, k=3)
        cand = idx.candidate_rows(np.array([0, 1, 2], np.uint8))
        assert 0 in cand.tolist()

    def test_expected_candidates_paper_scale(self):
        """At paper scale (3G ref, 100-char patterns, k=15) the analytic
        model predicts ~300 candidate rows -> ~300 Oracular passes for 3M
        patterns on 3M rows, i.e. the paper's ~10^4x Naive/Oracular gap."""
        c = expected_candidates(3e9, 100, k=15)
        assert 200 < c < 450

    def test_schedule_replication_consistency(self):
        rng = np.random.default_rng(5)
        frags = rng.integers(0, 4, (8, 40), np.uint8)
        pats = rng.integers(0, 4, (12, 12), np.uint8)
        s = schedule_oracular(frags, pats, k=4)
        assert s.replication == pytest.approx(
            sum(len(p) for p in s.passes) / 12)
