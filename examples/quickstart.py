"""Quickstart: CRAM-PM in five minutes.

1. Gates emerge from device physics (V_gate windows).
2. A micro-program runs row-parallel on the array interpreter.
3. Algorithm 1 (match + score) on the functional array.
4. The same search through the match engine (planner-selected TPU kernel,
   device-resident packed corpus).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import encoding, gates, matcher
from repro.core.array import CRAMArray, MicroOp, Program
from repro.core.tech import NEAR_TERM
from repro.match import MatchEngine


def main() -> None:
    print("== 1. gates from device physics ==")
    for g in ("NOR", "MAJ3", "TH"):
        lo, hi = gates.vgate_window(g, NEAR_TERM)
        print(f"  {g:4s}: V_gate in ({lo:.3f}, {hi:.3f}) V")

    print("\n== 2. row-parallel micro-program ==")
    arr = CRAMArray(n_rows=4, n_cols=16)
    arr.write_column_rows(0, np.array(
        [[0, 0], [0, 1], [1, 0], [1, 1]], np.uint8))
    arr.run(Program([MicroOp("PRESET0", (), 8), MicroOp("NOR", (0, 1), 8)]))
    print("  NOR of columns 0,1 across all rows:",
          np.asarray(arr.state[:, 8]))

    print("\n== 3. Algorithm 1 on the array ==")
    rng = np.random.default_rng(0)
    frags = rng.integers(0, 4, (6, 48), np.uint8)
    pattern = rng.integers(0, 4, 12, np.uint8)
    frags[4, 20:32] = pattern                     # plant a perfect hit
    m = matcher.Matcher(frags, pattern_chars=12)
    m.load_pattern(pattern)
    scores = m.run()
    locs, best = matcher.best_alignment(scores)
    print(f"  best alignment per row: locs={locs.tolist()} "
          f"scores={best.tolist()} (pattern planted at row 4, loc 20)")

    print("\n== 4. match engine: same semantics on the TPU fast path ==")
    engine = MatchEngine(frags)
    plan = engine.plan(pattern)
    print(f"  planner chose {plan.backend!r} ({plan.reason})")
    fast = np.asarray(engine.scores(pattern))
    assert np.array_equal(fast, scores)
    print("  engine scores == CRAM array scores:", True)
    best = engine.match(pattern, reduction="best")
    print("  per-row best (fused reduction): locs="
          f"{best.best_locs.tolist()} scores={best.best_scores.tolist()}")
    print("  corpus host pack events across queries:",
          engine.corpus.host_pack_count,
          "(packed forms build lazily, only for kernels that need them)")
    print("  pattern:", encoding.decode_dna(pattern))


if __name__ == "__main__":
    main()
