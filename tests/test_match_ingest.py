"""Growable-corpus + online-ingestion tests (DESIGN.md Sec. 3f).

The load-bearing invariants:

* growth is in place -- ``append_rows`` / ``reserve`` never host-repack a
  resident row (pack counters flat) and never rebuild device forms;
* a ``CompiledMatch`` survives growth -- geometry revalidates per run,
  results stay oracle-equivalent on every backend, and the pinned mode
  can never silently flip as the row count moves through Q;
* the service ingests while serving -- appends batch per tick, the
  generation-keyed result cache invalidates, and same-tick duplicate
  non-coalescible queries share one launch (regression);
* ``CRAMDedup`` holds one engine for its whole lifetime.
"""

import numpy as np
import pytest

from repro.core.matcher import sliding_scores
from repro.data.dedup import CRAMDedup
from repro.match import (MatchEngine, MatchQuery, MatchService,
                         PackedCorpus)

R0, F, P = 10, 96, 16


def make_corpus(r=R0, f=F, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return rng, PackedCorpus(rng.integers(0, 4, (r, f), np.uint8), **kw)


class TestGrowableCorpus:
    def test_append_grows_live_rows_and_generation(self):
        rng, corpus = make_corpus()
        gen = corpus.generation
        start = corpus.append_rows(rng.integers(0, 4, (3, F), np.uint8))
        assert start == R0
        assert corpus.n_rows == R0 + 3
        assert corpus.generation == gen + 1         # one bump per append
        corpus.append_rows(rng.integers(0, 4, F, np.uint8))  # 1-D row
        assert corpus.n_rows == R0 + 4
        assert corpus.generation == gen + 2

    def test_reserve_keeps_contents_and_generation(self):
        rng, corpus = make_corpus()
        before = np.array(corpus.fragments)
        gen = corpus.generation
        corpus.reserve(4 * R0)
        assert corpus.capacity >= 4 * R0
        assert corpus.n_rows == R0
        assert corpus.generation == gen             # contents unchanged
        np.testing.assert_array_equal(corpus.fragments, before)

    def test_capacity_doubles_without_host_repack(self):
        """Growth past capacity pad-extends the device forms in place --
        the resident rows are never re-packed on the host."""
        rng, corpus = make_corpus()
        corpus.swar_words(8)
        corpus.onehot_flat(F)
        assert corpus.host_pack_count == 2
        total = 0
        cap0 = corpus.capacity
        while corpus.capacity == cap0:              # force >= 1 doubling
            corpus.append_rows(rng.integers(0, 4, (7, F), np.uint8))
            total += 7
        assert corpus.host_pack_count == 2          # flat across growth
        assert corpus.row_update_count == total
        assert corpus._swar.shape[0] == corpus.capacity_padded
        assert corpus._onehot.shape[0] == corpus.capacity_padded

    def test_appended_rows_spliced_into_device_forms(self):
        rng, corpus = make_corpus()
        corpus.swar_words(8)
        new = rng.integers(0, 4, (2, F), np.uint8)
        start = corpus.append_rows(new)
        from repro.core import encoding
        words = np.asarray(corpus.swar_words(8))[start:start + 2]
        want = encoding.pack_codes_u32(new)
        np.testing.assert_array_equal(words[:, :want.shape[1]], want)

    def test_empty_start_with_reserved_capacity(self):
        corpus = PackedCorpus(np.zeros((0, F), np.uint8), capacity=8)
        assert corpus.n_rows == 0 and corpus.capacity == 8
        rng = np.random.default_rng(1)
        corpus.append_rows(rng.integers(0, 4, (3, F), np.uint8))
        assert corpus.n_rows == 3

    def test_set_rows_error_names_the_range(self):
        rng, corpus = make_corpus()
        with pytest.raises(ValueError) as ei:
            corpus.set_rows(R0 - 1, rng.integers(0, 4, (2, F), np.uint8))
        msg = str(ei.value)
        assert f"[{R0 - 1}, {R0 + 1})" in msg
        assert f"{R0} live rows" in msg and "append_rows" in msg

    def test_set_rows_cannot_write_reserved_region(self):
        rng, corpus = make_corpus(capacity=64)
        with pytest.raises(ValueError, match="live rows"):
            corpus.set_rows(R0, rng.integers(0, 4, (1, F), np.uint8))

    def test_append_rejects_width_mismatch(self):
        rng, corpus = make_corpus()
        with pytest.raises(ValueError, match=f"\\(n, {F}\\)"):
            corpus.append_rows(np.zeros((2, F + 1), np.uint8))

    def test_reserve_shrink_below_live_rows_raises(self):
        """Regression: a shrink request used to be silently ignored; it
        must raise and name the live rows it would cut."""
        rng, corpus = make_corpus()
        corpus.append_rows(rng.integers(0, 4, (6, F), np.uint8))
        live = corpus.n_rows
        with pytest.raises(ValueError) as ei:
            corpus.reserve(live - 1)
        msg = str(ei.value)
        assert f"{live} live rows" in msg and str(live - 1) in msg
        corpus.reserve(live)                        # at-live is a no-op
        assert corpus.n_rows == live


class TestQueryingAcrossGrowth:
    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_append_while_querying_oracle_equivalent(self, backend):
        """One engine, repeated append->query rounds: every round must be
        bit-identical to the from-scratch oracle on the grown corpus."""
        rng, corpus = make_corpus(seed=2)
        eng = MatchEngine(corpus)
        pat = rng.integers(0, 4, P, np.uint8)
        for _ in range(3):
            res = eng.match(pat, backend=backend, reduction="full")
            np.testing.assert_array_equal(
                res.scores, sliding_scores(corpus.fragments, pat))
            corpus.append_rows(rng.integers(0, 4, (5, F), np.uint8))
        assert corpus.swar_pack_count <= 1
        assert corpus.onehot_pack_count <= 1

    def test_compiled_match_reused_across_appends(self):
        """One CompiledMatch, growing corpus: pack counters flat, plan
        geometry follows the live row count, results track content."""
        rng, corpus = make_corpus(seed=3)
        eng = MatchEngine(corpus)
        pat = rng.integers(0, 4, P, np.uint8)
        cm = eng.compile(MatchQuery.exact(pat, backend="swar"))
        r1 = cm.run()
        assert r1.best_scores.shape == (R0,)
        planted = np.zeros(F, np.uint8)
        planted[10:10 + P] = pat                    # exact hit in new row
        corpus.append_rows(planted)
        r2 = cm.run()                               # same compiled object
        assert r2.best_scores.shape == (R0 + 1,)
        assert cm.plan.n_rows == R0 + 1             # geometry revalidated
        assert r2.best_scores[R0] == P and r2.best_locs[R0] == 10
        np.testing.assert_array_equal(
            r2.best_scores, sliding_scores(corpus.fragments, pat).max(1))
        assert corpus.swar_pack_count == 1          # packed once, ever
        assert eng.compile(MatchQuery.exact(pat, backend="swar")) is cm

    def test_compiled_backend_can_shift_with_scale(self):
        """Growth that moves the workload off the tiny-ref regime re-lowers
        the (tiny) pattern operands; results stay oracle-equivalent."""
        rng = np.random.default_rng(4)
        corpus = PackedCorpus(rng.integers(0, 4, (1, 20), np.uint8))
        eng = MatchEngine(corpus)
        pat = rng.integers(0, 4, 8, np.uint8)
        cm = eng.compile(MatchQuery.exact(pat))
        assert cm.run().plan.backend == "ref"       # tiny workload
        corpus.append_rows(rng.integers(0, 4, (499, 20), np.uint8))
        res = cm.run()
        assert res.plan.backend != "ref"            # roofline re-decided
        np.testing.assert_array_equal(
            res.best_scores, sliding_scores(corpus.fragments, pat).max(1))

    def test_row_subset_pinned_to_selection_across_growth(self):
        rng, corpus = make_corpus(seed=5)
        eng = MatchEngine(corpus)
        pat = rng.integers(0, 4, P, np.uint8)
        cm = eng.compile(MatchQuery.exact(pat, rows=(3, 1, 7)))
        r1 = cm.run()
        corpus.append_rows(rng.integers(0, 4, (6, F), np.uint8))
        r2 = cm.run()                               # selection unchanged
        np.testing.assert_array_equal(r1.best_scores, r2.best_scores)
        np.testing.assert_array_equal(
            r2.best_scores,
            sliding_scores(corpus.fragments[[3, 1, 7]], pat).max(1))

    def test_reductions_see_appended_rows(self):
        rng, corpus = make_corpus(seed=6)
        eng = MatchEngine(corpus)
        pat = rng.integers(0, 4, P, np.uint8)
        cm = eng.compile(MatchQuery.exact(pat, reduction="topk", k=3))
        cm.run()
        planted = np.zeros(F, np.uint8)
        planted[0:P] = pat
        new_row = corpus.append_rows(planted)
        res = cm.run()
        assert res.topk_rows[0] == new_row          # new best row wins
        assert res.topk_scores[0] == P


class TestModePinnedAcrossGrowth:
    def test_inferred_per_row_does_not_flip_to_batched(self):
        """(Q, P) with Q == n_rows compiles as per_row; after growth the
        same compiled query must refuse to run, not silently re-read the
        patterns as a batch."""
        rng, corpus = make_corpus(seed=7)
        eng = MatchEngine(corpus)
        pats = rng.integers(0, 4, (R0, P), np.uint8)   # Q == n_rows
        cm = eng.compile(MatchQuery.exact(pats, backend="swar"))
        assert cm.plan.mode == "per_row"
        cm.run()
        corpus.append_rows(rng.integers(0, 4, (2, F), np.uint8))
        with pytest.raises(ValueError, match="per_row"):
            cm.run()

    def test_inferred_batched_does_not_flip_to_per_row(self):
        """(Q, P) with Q != n_rows compiles as batched; growing the corpus
        *to* Q rows must not flip the pinned mode."""
        rng, corpus = make_corpus(seed=8)
        eng = MatchEngine(corpus)
        q = R0 + 4
        pats = rng.integers(0, 4, (q, P), np.uint8)
        cm = eng.compile(MatchQuery.exact(pats, backend="swar"))
        assert cm.plan.mode == "batched"
        r1 = cm.run()
        assert r1.best_scores.shape == (R0, q)
        corpus.append_rows(rng.integers(0, 4, (4, F), np.uint8))
        r2 = cm.run()                               # now Q == n_rows
        assert cm.plan.mode == "batched"            # still pinned
        assert r2.best_scores.shape == (q, q)
        for i in range(q):
            np.testing.assert_array_equal(
                r2.best_scores[:, i],
                sliding_scores(corpus.fragments, pats[i]).max(1))

    def test_fresh_compile_after_growth_may_infer_per_row(self):
        """Pinning is per compiled query, not a global freeze: a *new*
        compile sees the grown corpus and applies the inference to it."""
        rng, corpus = make_corpus(seed=9)
        eng = MatchEngine(corpus)
        corpus.append_rows(rng.integers(0, 4, (2, F), np.uint8))
        pats = rng.integers(0, 4, (R0 + 2, P), np.uint8)
        cm = eng.compile(MatchQuery.exact(pats, backend="swar"))
        assert cm.plan.mode == "per_row"


class TestEmptyGrowableEngine:
    def test_engine_accepts_reserved_empty_corpus(self):
        corpus = PackedCorpus(np.zeros((0, F), np.uint8), capacity=16)
        eng = MatchEngine(corpus)
        rng = np.random.default_rng(10)
        pat = rng.integers(0, 4, P, np.uint8)
        res = eng.match(pat)
        assert res.best_scores.shape == (0,)        # no rows yet
        corpus.append_rows(rng.integers(0, 4, (3, F), np.uint8))
        res = eng.match(pat)                        # same compiled query
        np.testing.assert_array_equal(
            res.best_scores, sliding_scores(corpus.fragments, pat).max(1))

    def test_engine_still_rejects_zero_capacity(self):
        with pytest.raises(ValueError, match="non-empty corpus"):
            MatchEngine(PackedCorpus(np.zeros((0, F), np.uint8)))

    def test_empty_corpus_still_validates_geometry(self):
        corpus = PackedCorpus(np.zeros((0, F), np.uint8), capacity=16)
        eng = MatchEngine(corpus)
        with pytest.raises(ValueError, match="longer"):
            eng.match(np.zeros(F + 1, np.uint8))


class TestServiceIngestion:
    def make(self, seed=0):
        rng = np.random.default_rng(seed)
        eng = MatchEngine(rng.integers(0, 4, (R0, F), np.uint8))
        return rng, eng, MatchService(eng)

    def test_ingest_applies_on_tick_in_one_batch(self):
        rng, eng, svc = self.make(20)
        t1 = svc.ingest(rng.integers(0, 4, (2, F), np.uint8))
        t2 = svc.ingest(rng.integers(0, 4, F, np.uint8))
        assert not t1.done and eng.corpus.n_rows == R0
        svc.tick()
        assert t1.done and t2.done
        assert (t1.start, t1.n) == (R0, 2)
        assert (t2.start, t2.n) == (R0 + 2, 1)      # submission order
        assert eng.corpus.n_rows == R0 + 3
        assert svc.stats.n_ingest_batches == 1      # one batched append
        assert svc.stats.n_ingested_rows == 3

    def test_ingest_validates_width_at_the_door(self):
        rng, eng, svc = self.make(21)
        with pytest.raises(ValueError, match=f"\\(n, {F}\\)"):
            svc.ingest(np.zeros((1, F + 5), np.uint8))

    def test_queries_in_same_tick_see_ingested_rows(self):
        rng, eng, svc = self.make(22)
        pat = rng.integers(0, 4, P, np.uint8)
        planted = np.zeros(F, np.uint8)
        planted[5:5 + P] = pat
        svc.ingest(planted)
        ticket = svc.submit(pat)
        svc.tick()
        assert ticket.result.best_scores.shape == (R0 + 1,)
        assert ticket.result.best_scores[R0] == P

    def test_cache_invalidated_by_ingest(self):
        rng, eng, svc = self.make(23)
        pat = rng.integers(0, 4, P, np.uint8)
        stale = svc.match(pat)
        svc.ingest(rng.integers(0, 4, F, np.uint8))
        fresh = svc.submit(pat)
        svc.tick()
        assert not fresh.cached                     # generation moved
        assert fresh.result.best_scores.shape[0] == R0 + 1
        assert stale.best_scores.shape[0] == R0

    def test_ingest_wait_drives_ticks(self):
        rng, eng, svc = self.make(24)
        t = svc.ingest(rng.integers(0, 4, F, np.uint8))
        assert t.wait() == R0
        assert eng.corpus.n_rows == R0 + 1

    def test_flush_drains_ingest_queue(self):
        rng, eng, svc = self.make(25)
        svc.ingest(rng.integers(0, 4, (4, F), np.uint8))
        svc.flush()
        assert eng.corpus.n_rows == R0 + 4

    def test_mixed_ingest_query_stream_no_repacks(self):
        rng, eng, svc = self.make(26)
        pats = [rng.integers(0, 4, P, np.uint8) for _ in range(6)]
        svc.match(pats[0])                          # warm: pack forms
        packs = eng.corpus.host_pack_count
        for p in pats:
            svc.ingest(rng.integers(0, 4, (2, F), np.uint8))
            svc.submit(p)
            svc.tick()
        assert eng.corpus.n_rows == R0 + 12
        assert eng.corpus.host_pack_count == packs  # 0 resident repacks
        want = MatchEngine(np.array(eng.corpus.fragments)).match(pats[-1])
        got = svc.match(pats[-1])
        np.testing.assert_array_equal(got.best_scores, want.best_scores)
        np.testing.assert_array_equal(got.best_locs, want.best_locs)


class TestSameTickDuplicateLaunch:
    def test_duplicate_batched_queries_share_one_launch(self):
        """Regression: non-coalescible (2-D) duplicates in one tick used to
        be keyed by ticket identity and each paid a full launch."""
        rng = np.random.default_rng(30)
        eng = MatchEngine(rng.integers(0, 4, (R0, F), np.uint8))
        svc = MatchService(eng)
        pats = rng.integers(0, 4, (4, P), np.uint8)
        q = MatchQuery.exact(pats, mode="batched")
        t1, t2 = svc.submit(q), svc.submit(q)
        svc.tick()
        assert svc.stats.n_launches == 1            # was 2 before the fix
        assert t1.result is t2.result               # shared, bit-identical
        assert t1.result.best_scores.shape == (R0, 4)

    def test_distinct_batched_queries_still_launch_separately(self):
        rng = np.random.default_rng(31)
        eng = MatchEngine(rng.integers(0, 4, (R0, F), np.uint8))
        svc = MatchService(eng)
        a = MatchQuery.exact(rng.integers(0, 4, (3, P), np.uint8),
                             mode="batched")
        b = MatchQuery.exact(rng.integers(0, 4, (3, P), np.uint8),
                             mode="batched")
        ta, tb = svc.submit(a), svc.submit(b)
        svc.tick()
        assert svc.stats.n_launches == 2
        assert ta.result is not tb.result


class TestDedupLifetimeEngine:
    def test_engine_survives_capacity_growth(self):
        rng = np.random.default_rng(40)
        d = CRAMDedup(threshold=1.01)               # never a duplicate
        engine = d.engine
        corpus = engine.corpus
        for _ in range(70):                         # crosses capacity 64
            d.add(rng.bytes(64))
        assert d.engine is engine                   # no rebuild, ever
        assert d.engine.corpus is corpus
        assert len(d) == 70 and d.capacity == 128
        assert d.total_row_writes == 70

    def test_add_rejects_fingerprint_wider_than_fp_len(self):
        d = CRAMDedup(fp_len=64, pattern_len=32)
        with pytest.raises(ValueError, match="fp_len=64"):
            d.add(np.zeros(65, np.uint8))
        d.add(np.zeros(64, np.uint8))               # exact width is fine
        assert len(d) == 1

    def test_precomputed_fingerprint_roundtrip(self):
        from repro.data.dedup import fingerprint
        rng = np.random.default_rng(41)
        doc = rng.bytes(200)
        d = CRAMDedup(threshold=0.9)
        d.add(fingerprint(doc, d.fp_len))           # array spelling
        assert d.is_duplicate(doc)                  # bytes spelling agrees

    def test_pattern_len_cannot_exceed_fp_len(self):
        with pytest.raises(ValueError, match="pattern_len"):
            CRAMDedup(fp_len=32, pattern_len=64)
