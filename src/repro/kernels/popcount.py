"""Bulk popcount -- Pallas TPU kernel (BC benchmark / Phase-2 analogue).

The CRAM-PM adder reduction tree (Fig. 4b) becomes branch-free SWAR
arithmetic over uint32 lanes; one VPU op pops 8x128 words.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

M1 = np.uint32(0x55555555)
M2 = np.uint32(0x33333333)
M4 = np.uint32(0x0F0F0F0F)
MUL = np.uint32(0x01010101)

N_TILE = 256


def popcount_words(v: jnp.ndarray) -> jnp.ndarray:
    """Branch-free SWAR popcount per uint32 word (int32 out).

    Pure jnp, so it inlines into Pallas kernel bodies (this module's bulk
    kernel, ``filter_qgram``) as well as ordinary jitted code.
    """
    v = v - ((v >> jnp.uint32(1)) & M1)
    v = (v & M2) + ((v >> jnp.uint32(2)) & M2)
    v = (v + (v >> jnp.uint32(4))) & M4
    return ((v * MUL) >> jnp.uint32(24)).astype(jnp.int32)


def _popcount_kernel(x_ref, out_ref):
    out_ref[...] = popcount_words(x_ref[...]).sum(axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def popcount(words: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """(N, W) uint32 -> (N, 1) int32 per-row popcount. N % N_TILE == 0."""
    N, W = words.shape
    if N % N_TILE:
        raise ValueError(f"rows must be padded to a multiple of {N_TILE}")
    return pl.pallas_call(
        _popcount_kernel,
        grid=(N // N_TILE,),
        in_specs=[pl.BlockSpec((N_TILE, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((N_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        interpret=interpret,
    )(words)
