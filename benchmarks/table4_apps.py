"""Paper Table 4: benchmark applications -- per-app CRAM-PM op census and
absolute run characteristics on both technology points."""

import time

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM


def run():
    rows = []
    for name, app in cm.table4_apps().items():
        t0 = time.perf_counter()
        near = cm.app_cram_run(app, NEAR_TERM)
        longt = cm.app_cram_run(app, LONG_TERM)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table4/{name}", round(us, 1),
                     f"items={app.n_items:.4g} logic_ops={app.cram_logic_ops}"
                     f" presets={app.cram_presets}"
                     f" rate_near={near.match_rate:.4g}/s"
                     f" rate_long={longt.match_rate:.4g}/s"))
    return rows
