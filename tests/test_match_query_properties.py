"""Randomized property tests for the predicate API (hypothesis-driven).

Split out of ``test_match_query.py`` so a missing ``hypothesis`` install
skips only this module (repo convention, see
``test_kernels_properties.py``); install dev deps with
``pip install -r requirements-dev.txt``.

Property: for any fragments and any accept-mask pattern, every backend is
bit-identical to the NumPy oracle ``matcher.sliding_scores_masks`` -- and
one-hot masks degenerate to exact matching exactly.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.matcher import sliding_scores, sliding_scores_masks  # noqa: E402
from repro.match import MatchEngine, MatchQuery  # noqa: E402


def random_masks(rng, shape):
    """Biased mix: mostly one-hot, some multi-accept, some full N."""
    codes = rng.integers(0, 4, shape, np.uint8)
    masks = (np.uint8(1) << codes).astype(np.uint8)
    wild = rng.random(shape) < 0.25
    masks[wild] = rng.integers(1, 16, int(wild.sum()), np.uint8)
    return masks


class TestPredicateProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 6), st.integers(2, 60), st.data())
    def test_property_masks_match_oracle_swar(self, r, f, data):
        p = data.draw(st.integers(1, f))
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (r, f), np.uint8)
        masks = random_masks(rng, p)
        q = MatchQuery.from_masks(masks, reduction="full", backend="swar")
        got = np.asarray(MatchEngine(frags).match(q).scores)
        np.testing.assert_array_equal(got,
                                      sliding_scores_masks(frags, masks))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_backends_agree(self, seed):
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (3, 70), np.uint8)
        masks = random_masks(rng, int(rng.integers(2, 32)))
        outs = [np.asarray(MatchEngine(frags).match(
                    MatchQuery.from_masks(masks, reduction="full",
                                          backend=b)).scores)
                for b in ("swar", "mxu", "ref")]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_onehot_degenerates_to_exact(self, seed):
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (4, 50), np.uint8)
        pat = rng.integers(0, 4, 12, np.uint8)
        masks = (np.uint8(1) << pat).astype(np.uint8)
        q = MatchQuery.from_masks(masks, reduction="full", backend="swar")
        got = np.asarray(MatchEngine(frags).match(q).scores)
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31))
    def test_property_score_bounds_and_wildcard_hits(self, seed):
        """Scores stay within [0, P]; an all-N window always scores P."""
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (4, 40), np.uint8)
        p = int(rng.integers(1, 12))
        masks = random_masks(rng, p)
        masks[: max(1, p // 2)] = 0b1111
        q = MatchQuery.from_masks(masks, reduction="full", backend="swar")
        s = np.asarray(MatchEngine(frags).match(q).scores)
        assert (s >= (masks == 0b1111).sum()).all() and (s <= p).all()
