"""Gate-level model of the CRAM-PM cell (paper Sec. 2.1-2.2).

Two views of every gate are provided and cross-checked in tests:

1. **Analog threshold model** (`output_current`, `vgate_window`): the gate is a
   resistive divider.  Input MTJs (resistance R_P for logic 0 / R_AP for 1)
   connect their BSL voltage ``V`` to the logic line LL; the output MTJ
   connects LL to ground.  The output switches away from its preset value iff
   the current through it exceeds the (guard-banded) critical current.  Gate
   *function* is selected purely by ``V_gate`` + the output preset, exactly as
   in the paper: the truth tables below *emerge* from device physics, they are
   not hard-coded.

2. **Functional model** (`GATE_FNS`): fast vectorized logic used by the array
   interpreter, validated against (1) for every input combination in
   ``tests/test_gates.py``.

Circuit solved (Fig. 1(c)): let ``u`` be the LL node voltage, ``g_i = 1/(R_i +
R_s)`` the input branch conductances (R_s = series transistor+wire resistance)
and ``g_o = 1/(R_out + R_s)`` the output branch conductance.  KCL gives::

    u = V * sum(g_i) / (g_o + sum(g_i))          (all input BSLs at V, out at 0)
    I_out = u * g_o  =  V * g_o * sum(g_i) / (g_o + sum(g_i))

``I_out`` is linear and increasing in ``V``, so for each input combination
there is a unique threshold voltage ``V* = I_crit / slope`` and every gate's
feasible window is an interval -- which is how the paper derives Table 3.

Two calibration facts recovered from the paper's own Table 3:

* Reported V_INV == V_COPY exactly, although INV presets the output to 0
  (R_P) and COPY to 1 (R_AP).  Hence the paper evaluates the output branch
  with a preset-independent resistance; we use R_P ("switching onset"
  resistance) for window derivation.
* Reported windows correspond to the *raw* 50%-switching I_crit; the 2x/5x
  WER guard band of Sec. 4 is applied to latency/energy derivation only.

With R_SERIES = 1.5 kOhm this model lands on the paper's near-term windows to
within a few tens of mV (asserted in tests/test_gates.py).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from .tech import MTJTech, R_SERIES_OHM


# ---------------------------------------------------------------------------
# Analog threshold model
# ---------------------------------------------------------------------------

def _branch_conductance(bit: int, tech: MTJTech, r_series: float) -> float:
    r = tech.r_ap_ohm if bit else tech.r_p_ohm
    return 1.0 / (r + r_series)


def output_current_slope(
    input_bits: Sequence[int], preset: int, tech: MTJTech,
    r_series: float = R_SERIES_OHM,
) -> float:
    """d(I_out)/dV for the given input combination.

    The output branch is evaluated at R_P (switching-onset resistance),
    independent of the preset -- see module docstring (this is what makes the
    paper's V_INV == V_COPY identity hold).  ``preset`` is kept in the
    signature for clarity at call sites.
    """
    del preset  # output branch modeled at R_P; see docstring.
    g_in = sum(_branch_conductance(b, tech, r_series) for b in input_bits)
    g_out = _branch_conductance(0, tech, r_series)
    return g_out * g_in / (g_out + g_in)


def output_current(
    input_bits: Sequence[int], preset: int, v_gate: float, tech: MTJTech,
    r_series: float = R_SERIES_OHM,
) -> float:
    """I_out (amps) through the output MTJ for input BSLs driven at v_gate."""
    return v_gate * output_current_slope(input_bits, preset, tech, r_series)


@dataclasses.dataclass(frozen=True)
class GateSpec:
    """A CRAM-PM gate = arity + output preset + which input combos switch.

    ``switches(bits) == True`` means I_out must exceed I_crit for that combo,
    flipping the output from ``preset`` to ``1 - preset``.
    """

    name: str
    arity: int
    preset: int
    switches: Callable[[Tuple[int, ...]], bool]

    def truth(self, bits: Tuple[int, ...]) -> int:
        return (1 - self.preset) if self.switches(bits) else self.preset


# Paper Sec. 2.2 gate set.  `switches` predicates follow directly from the
# current ordering I_00 > I_01 = I_10 > I_11 (more zeros => more current).
GATES: Dict[str, GateSpec] = {
    # NOR: preset 0; only the all-zeros combo drives enough current to switch.
    "NOR": GateSpec("NOR", 2, 0, lambda b: sum(b) == 0),
    # OR: same voltage window as NOR but preset 1 (out drops to 0 only on 00).
    "OR": GateSpec("OR", 2, 1, lambda b: sum(b) == 0),
    # NAND: preset 0; any combo with at least one zero switches.
    "NAND": GateSpec("NAND", 2, 0, lambda b: sum(b) <= 1),
    # AND: NAND window with preset 1.
    "AND": GateSpec("AND", 2, 1, lambda b: sum(b) <= 1),
    # INV: preset 0; switches when the single input is 0.
    "INV": GateSpec("INV", 1, 0, lambda b: b[0] == 0),
    # COPY (buffer): preset 1; switches to 0 when the input is 0.
    "COPY": GateSpec("COPY", 1, 1, lambda b: b[0] == 0),
    # MAJ3: preset 1; switches to 0 when fewer than two ones (majority 0).
    "MAJ3": GateSpec("MAJ3", 3, 1, lambda b: sum(b) < 2),
    # MAJ5: preset 1; switches to 0 when fewer than three ones.
    "MAJ5": GateSpec("MAJ5", 5, 1, lambda b: sum(b) < 3),
    # TH ("threshold", XOR helper, Sec. 2.2): 4 inputs, preset 0, switches
    # when at most one input is 1 (>=3 low-resistance branches).
    "TH": GateSpec("TH", 4, 0, lambda b: sum(b) <= 1),
}


def vgate_window(
    gate: str, tech: MTJTech, r_series: float = R_SERIES_OHM,
    i_crit_scale: float = 1.0,
) -> Tuple[float, float]:
    """Feasible (V_min, V_max) for `gate`; raises if the window is empty.

    ``i_crit_scale`` perturbs I_crit for the process-variation study (Sec 5.5).
    """
    spec = GATES[gate]
    i_crit = tech.i_crit_ua * 1e-6 * i_crit_scale   # raw I_crit; see docstring
    v_switch, v_hold = [], []
    for bits in itertools.product((0, 1), repeat=spec.arity):
        slope = output_current_slope(bits, spec.preset, tech, r_series)
        v_star = i_crit / slope
        (v_switch if spec.switches(bits) else v_hold).append(v_star)
    v_min = max(v_switch)            # must exceed every switching threshold
    v_max = min(v_hold) if v_hold else float("inf")
    if v_min >= v_max:
        raise ValueError(f"empty V_gate window for {gate} on {tech.name}")
    return (v_min, v_max)


def vgate_center(gate: str, tech: MTJTech, **kw) -> float:
    lo, hi = vgate_window(gate, tech, **kw)
    return 0.5 * (lo + hi)


def analog_gate_output(
    gate: str, input_bits: Sequence[int], tech: MTJTech,
    v_gate: float | None = None, r_series: float = R_SERIES_OHM,
    i_crit_scale: float = 1.0,
) -> int:
    """Evaluate a gate through the analog model (device-physics ground truth)."""
    spec = GATES[gate]
    if len(input_bits) != spec.arity:
        raise ValueError(f"{gate} expects {spec.arity} inputs")
    if v_gate is None:
        v_gate = vgate_center(gate, tech, r_series=r_series)
    i_out = output_current(input_bits, spec.preset, v_gate, tech, r_series)
    i_crit = tech.i_crit_ua * 1e-6 * i_crit_scale
    return (1 - spec.preset) if i_out > i_crit else spec.preset


# ---------------------------------------------------------------------------
# Functional (vectorized) model -- used by the array interpreter
# ---------------------------------------------------------------------------

def _maj(*xs):
    s = sum(x.astype(np.int32) if hasattr(x, "astype") else int(x) for x in xs)
    return (s * 2 > len(xs)).astype(xs[0].dtype) if hasattr(xs[0], "astype") else int(s * 2 > len(xs))


GATE_FNS: Dict[str, Callable] = {
    "NOR": lambda a, b: 1 - (a | b),
    "OR": lambda a, b: a | b,
    "NAND": lambda a, b: 1 - (a & b),
    "AND": lambda a, b: a & b,
    "INV": lambda a: 1 - a,
    "COPY": lambda a: a,
    "MAJ3": lambda a, b, c: ((a + b + c) >= 2).astype(a.dtype) if hasattr(a, "astype") else int(a + b + c >= 2),
    "MAJ5": lambda a, b, c, d, e: ((a + b + c + d + e) >= 3).astype(a.dtype) if hasattr(a, "astype") else int(a + b + c + d + e >= 3),
    "TH": lambda a, b, c, d: ((a + b + c + d) <= 1).astype(a.dtype) if hasattr(a, "astype") else int(a + b + c + d <= 1),
}


def gate_energy_pj(gate: str, tech: MTJTech, r_series: float = R_SERIES_OHM) -> float:
    """Worst-case per-row energy of one gate invocation (pJ).

    Energy = sum over branches of V_drop * I * t_switch, evaluated at the
    gate's center voltage for the highest-current input combination (all
    zeros), plus the output switching event itself.  This ties the cost model
    to the device model instead of a free constant.
    """
    spec = GATES[gate]
    v = vgate_center(gate, tech, r_series=r_series)
    bits = (0,) * spec.arity                      # highest-current case
    g_in = [_branch_conductance(b, tech, r_series) for b in bits]
    g_out = _branch_conductance(spec.preset, tech, r_series)
    u = v * sum(g_in) / (g_out + sum(g_in))
    t = tech.switching_latency_ns * 1e-9
    p_inputs = sum((v - u) * (v - u) * g for g in g_in)   # input branch drops
    p_out = u * u * g_out
    return (p_inputs + p_out) * t * 1e12


# Gates actually used by the pattern-matching workload (Sec. 3.2).
PM_GATE_SET = ("NOR", "INV", "COPY", "MAJ3", "MAJ5", "TH")


def icrit_tolerance(gate: str, tech: MTJTech) -> Tuple[float, float]:
    """Multiplicative I_crit drift interval tolerated at the nominal V_gate.

    Windows scale linearly with I_crit, so with V fixed at the nominal center
    ``c`` of window (lo, hi), the gate stays correct for scale s in
    (c/hi, c/lo).  Returns that interval.
    """
    lo, hi = vgate_window(gate, tech)
    c = 0.5 * (lo + hi)
    return (c / hi, c / lo)


def variation_study(tech: MTJTech, scales=(0.05, 0.10, 0.20)) -> Dict[str, object]:
    """Sec. 5.5 process-variation analysis.

    The paper's claim is that switching-current variation is "unlikely" to
    make gate *functions overlap* because gates with close V_gate are
    distinguished by preset value or input count.  Within the pattern
    matching gate set this is structural: no two gates share (arity, preset),
    so no variation can alias one used gate into another.  Per-gate absolute
    tolerance (drift the gate survives without V_gate recalibration) is also
    reported; narrow-window MAJ gates need recalibration beyond ~1-3% --
    consistent with the sliver-thin MAJ windows in the paper's own Table 3.
    """
    arity_preset = {(GATES[g].arity, GATES[g].preset) for g in PM_GATE_SET}
    structural_distinct = len(arity_preset) == len(PM_GATE_SET)
    tol = {g: icrit_tolerance(g, tech) for g in GATES}
    per_scale = {
        s: {g: (tol[g][0] <= 1 - s and 1 + s <= tol[g][1]) for g in GATES}
        for s in scales
    }
    return {
        "pm_gates_structurally_distinct": structural_distinct,
        "tolerance_interval": tol,
        "survives_plus_minus": per_scale,
    }
