import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input-shape x mesh) cell: build the production
mesh from 512 placeholder host devices, lower the step function with
ShapeDtypeStruct stand-ins (zero allocation), ``.compile()`` it, and record
``memory_analysis()`` / ``cost_analysis()`` / the post-SPMD collective
schedule into a JSON line.  A failure here (sharding mismatch, OOM at
compile, unsupported collective) is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import pathlib
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.distributed import context as dist_context
from repro.distributed import hlo_analysis, sharding
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, model, shape_applicable
from repro.models.spec import abstract, tree_axes
from repro.optim import adamw
from repro.runtime import steps


def analytic_bytes_per_dev(cfg, shape, n_dev: int, tp: int = 16,
                           dp: int | None = None) -> float:
    """Coarse analytic HBM-traffic floor per device (documented in
    EXPERIMENTS §Roofline): weight/grad/optimizer/activation/cache passes
    for an ideally fused TPU program.  The HLO-walker bytes term reflects
    CPU fusion granularity and is an upper bound; the truth for a real TPU
    compile lies between the two."""
    Na = cfg.n_active_params()
    dp = dp or (n_dev // tp)
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // dp, 1)
    d = cfg.d_model
    L = cfg.n_layers
    if shape.kind == "train":
        w = 3 * 2 * Na / tp                      # gather-write + fwd/bwd reads
        g = 2 * 4 * Na / tp * max(cfg.microbatch, 1)   # f32 grad accum r/w
        opt = 6 * 4 * Na / n_dev                 # m, v, master r+w
        acts = L * b_loc * S * d * 2 * 4 * 2     # saved residuals w+r
        logits = 2 * b_loc * S * (cfg.padded_vocab / tp) * 4
        return w + g + opt + acts + logits
    cache = 0.0
    if shape.kind in ("prefill", "decode"):
        # KV/state cache bytes per device (from the cache specs).
        from repro.models.spec import abstract as _abs
        caches = _abs(model.cache_specs(cfg, B, S))
        total = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                    for a in jax.tree.leaves(caches))
        cache = total / n_dev
    if shape.kind == "prefill":
        w = 2 * 2 * Na / tp
        acts = L * b_loc * S * d * 2 * 2
        return w + acts + 2 * cache
    # decode: every parameter read once per step + cache read + write slice.
    w = 2 * Na / tp
    return w + cache


import numpy as np  # noqa: E402  (used by analytic_bytes_per_dev)


def _opt_state_abstract(params_abs):
    f32 = lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params_abs),
        "v": jax.tree.map(f32, params_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _opt_state_shardings(params_sh, mesh):
    return {"m": params_sh, "v": params_sh, "step": sharding.replicated(mesh)}


def _batch_shardings(cfg, shape, batch_abs, mesh, rules=None):
    out = sharding.batch_specs(
        {k: v for k, v in batch_abs.items() if k != "caches"}, mesh)
    if "caches" in batch_abs:
        cache_axes = tree_axes(model.cache_specs(
            cfg, shape.global_batch, shape.seq_len))
        out["caches"] = sharding.shardings_for(
            cache_axes, batch_abs["caches"], mesh, rules)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg=None, mesh=None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    try:
        mesh = mesh or make_production_mesh(multi_pod=multi_pod)
        rules = sharding.RULE_PROFILES[cfg.sharding_profile]
        pspecs = model.param_specs(cfg)
        params_abs = abstract(pspecs)
        params_sh = sharding.shardings_for(tree_axes(pspecs), params_abs,
                                           mesh, rules)
        batch_abs = model.input_specs(cfg, shape)
        batch_sh = _batch_shardings(cfg, shape, batch_abs, mesh, rules)
        step_fn = steps.make_step(cfg, shape.kind, adamw.OptConfig())

        with dist_context.activation_sharding(mesh, rules):
            if shape.kind == "train":
                opt_abs = _opt_state_abstract(params_abs)
                opt_sh = _opt_state_shardings(params_sh, mesh)
                jitted = jax.jit(step_fn,
                                 in_shardings=(params_sh, opt_sh, batch_sh),
                                 out_shardings=(params_sh, opt_sh, None),
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            else:
                jitted = jax.jit(step_fn,
                                 in_shardings=(params_sh, batch_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_abs, batch_abs)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost = dict(cost or {})
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            }
        except Exception as e:  # pragma: no cover
            mem_rec = {"error": str(e)}
        text = compiled.as_text()
        walk = hlo_analysis.analyze_hlo(text)
        roof = hlo_analysis.roofline_from_cost(walk)

        n = cfg.n_params()
        na = cfg.n_active_params()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * na * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * na * tokens
        else:
            tokens = shape.global_batch
            model_flops = 2.0 * na * tokens
        n_dev = mesh.devices.size
        ana_bytes = analytic_bytes_per_dev(cfg, shape, n_dev)
        rec.update({
            "analytic_bytes_per_dev": ana_bytes,
            "memory_s_analytic": ana_bytes / 819e9,
            "status": "ok",
            "n_devices": int(n_dev),
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "params": n, "active_params": na, "tokens": tokens,
            "model_flops_global": model_flops,
            "hlo_flops_per_dev": roof.flops,
            "hlo_bytes_per_dev": roof.hbm_bytes,
            "hlo_bytes_strict_per_dev": walk.bytes_strict,
            "collective_bytes_per_dev": roof.collective_bytes,
            # XLA's own cost_analysis (loop bodies counted once) kept as a
            # cross-check against the trip-multiplied walker numbers above.
            "xla_flops_per_dev": float(cost.get("flops", 0.0) or 0.0),
            "xla_bytes_per_dev": float(cost.get("bytes accessed", 0.0) or 0.0),
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "useful_flops_ratio": (model_flops / n_dev) / roof.flops
            if roof.flops else None,
            "collectives": roof.collectives,
            "collective_counts": roof.collective_counts,
            "memory": mem_rec,
        })
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None,
                    help="append JSONL records to this file")
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    out_path = pathlib.Path(args.out) if args.out else None
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)

    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                rec = lower_cell(arch, shape_name, multi_pod, cfg=cfg,
                                 mesh=mesh)
                line = json.dumps(rec)
                summary = {k: rec.get(k) for k in
                           ("arch", "shape", "mesh", "status", "dominant",
                            "compile_s", "error")}
                print(json.dumps(summary), flush=True)
                if out_path:
                    with out_path.open("a") as f:
                        f.write(line + "\n")


if __name__ == "__main__":
    main()
