"""Observability for the match stack: spans + metrics + plan-vs-actual.

One ``Observability`` object is threaded through a ``MatchEngine`` and
everything it owns (corpus, index, merger, service, bank).  Spans are
off by default and free when off; the metrics registry is always on
(it is pure accounting and never influences plans, so -- unlike
``record_runtimes`` -- it is safe multi-process).

Typical use::

    obs = Observability(spans=True)
    eng = MatchEngine(fragments, obs=obs)
    eng.match("pattern")
    obs.tracer.write_chrome("trace.json")   # open in ui.perfetto.dev
    obs.metrics.plan_actual_summary()        # est-vs-observed per bucket

``launch/serve.py --trace out.json`` wires exactly this around a serve
run; ``--metrics-every N`` prints registry snapshots while it runs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.obs.metrics import (Counter, Gauge, LogHistogram,
                               MetricsRegistry, PlanActual,
                               DEFAULT_BASE, DEFAULT_DRIFT_BOUND,
                               plan_key_str)
from repro.obs.trace import NOOP_SPAN, STAGES, Span, Tracer

__all__ = [
    "Counter", "Gauge", "LogHistogram", "MetricsRegistry",
    "NULL_OBS", "NOOP_SPAN", "Observability", "PlanActual", "Span",
    "STAGES", "Tracer", "plan_key_str",
    "DEFAULT_BASE", "DEFAULT_DRIFT_BOUND",
]


class Observability:
    """Tracer + metrics registry, one handle for the whole stack."""

    def __init__(self, *, spans: bool = False, profiler: bool = False,
                 max_spans: int = 100_000, keep_records: int = 4096):
        self.tracer = Tracer(enabled=spans, profiler=profiler,
                             max_spans=max_spans)
        self.metrics = MetricsRegistry(keep_records=keep_records)

    @property
    def enabled(self) -> bool:
        """True when spans are being recorded."""
        return self.tracer.enabled

    def span(self, name: str, attrs: Optional[Dict[str, Any]] = None):
        """Shorthand for ``self.tracer.span`` (no-op when disabled)."""
        return self.tracer.span(name, attrs)

    def record_plan_actual(self, key: Tuple, est_s: float,
                           observed_s: float) -> None:
        self.metrics.record_plan_actual(key, est_s, observed_s)


# Shared default for components constructed without an engine (e.g. a
# bare PackedCorpus or a PatternBank's passthrough merger): spans off,
# metrics recorded but typically never read.  Engines replace it with
# their own instance.
NULL_OBS = Observability()
