"""repro.distributed"""
