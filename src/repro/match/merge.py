"""Device-side cross-shard reduction merges (DESIGN.md Sec. 3k).

The paper's scale-out story (Sec. 3.4) is arrays computing independently
and exchanging only *reduced* state -- re-funneling every per-shard
result through the controller host re-creates the Von-Neumann bottleneck
in miniature and hard-breaks the moment shards live on another host's
devices (``np.asarray`` of a non-addressable array).  ``ShardMerger`` is
the one place cross-shard results combine, and they combine **on
device** with collectives under ``shard_map``:

* ``pull`` -- replicate a row-sharded array with an ``all_gather`` (the
  cyclic-layout un-permute happens device-side too) and hand the host a
  fully-replicated value; every process gets the same bytes, so the
  multi-controller SPMD discipline holds on any process count.
* ``topk_update`` / ``topk_finalize`` -- running global top-k as a tree
  merge: shard-local ``lax.top_k`` maxima, an ``all_gather`` of the
  (k_loc per shard) candidates, then a replicated ``lexsort`` realizing
  the total order (score desc, row asc) -- bit-identical to the deleted
  host ``np.lexsort`` merge, because each live row appears exactly once
  and int32 scores (>= -1) negate exactly.  Dead/padding entries carry
  the (-1, ROW_SENTINEL) sentinel pair and sort last; ``topk_finalize``
  trims them by the host-tracked live-candidate count.
* ``hot_mask`` / ``gather_rows`` -- the threshold reduction's sparse
  two-phase pull: a per-row any-hit bitmap (integer-exact: scores are
  ints, so ``s >= t``  <=>  ``s >= ceil(t)``), then a device gather of
  only the hot rows' score vectors.  The full per-chunk score block
  never crosses to the host (the satellite host-transfer fix).
* ``chunk_best`` / ``or_`` -- jitted per-chunk reductions so no eager op
  ever touches a non-addressable array.

Transfer accounting (``collective_bytes`` / ``reduced_pull_bytes`` /
``block_pull_bytes``) feeds ``MatchResult.merge_path`` and
``ServiceStats`` so mispriced merges show up in the feedback loop.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed import sharding as _sharding
from repro.obs import NULL_OBS, Observability

# Sentinel pair for dead / padding top-k entries: any real row scores
# >= 0 and has id strictly below ROW_SENTINEL, so sentinels sort
# strictly after every live candidate under (score desc, row asc).
# Row ids live in int32 on device (jax_enable_x64 is off, so int64
# would be silently truncated inside jit -- a 1<<62 sentinel truncates
# to *zero* and sorts first); int32 max is unreachable as a real id.
ROW_SENTINEL = np.int32(np.iinfo(np.int32).max)
SCORE_SENTINEL = np.int32(-1)


# Shared row scatter for incremental splices into sharded device forms
# (corpus/index `.at[].set` is eager and would touch non-addressable
# shards multi-controller).  Every process packs the touched rows (tiny,
# identical host work by SPMD discipline); XLA updates only the
# addressable slots.
scatter_rows = jax.jit(lambda a, i, v: a.at[i, :].set(v))


@functools.lru_cache(maxsize=512)
def _resident_slicer(S: int, j: int, j0: int, j1: int, w: int):
    """Jitted per-shard block slice: multi-process-safe ``_slice_resident``.

    Cached by geometry so repeated chunks reuse the compiled program
    (a fresh closure per call would defeat the jit cache).
    """
    def sl(b):
        return b.reshape(S, j, w)[:, j0:j1].reshape(S * (j1 - j0), w)
    return jax.jit(sl)


class ShardMerger:
    """Cross-shard merges for one engine, device-side under ``shard_map``.

    ``n_shards == 1`` degrades to plain host pulls (``merge_path ==
    "host"``); with shards every merge routes through the collectives --
    including on a single process, so the 8-shard single-process baseline
    exercises exactly the code the 2-process run executes (the
    bit-identity gate in ``BENCH_match_shard.json`` compares the two).
    """

    def __init__(self, mesh: Optional[Mesh], row_axes, n_shards: int,
                 obs: Optional[Observability] = None):
        # Merge/pull spans + transfer counters record here; the engine
        # hands in its own handle, passthrough mergers (PatternBank's
        # single-shard default) keep the shared null one.
        self.obs = obs if obs is not None else NULL_OBS
        self.n_shards = int(n_shards)
        self.mesh = mesh if self.n_shards > 1 else None
        if row_axes is None:
            axes: Tuple[str, ...] = ()
        elif isinstance(row_axes, tuple):
            axes = row_axes
        else:
            axes = (row_axes,)
        self.axes = axes
        self.multiprocess = jax.process_count() > 1
        # Transfer accounting: device-side collective traffic (per-link
        # ring estimate) vs. what actually crossed to the host, split by
        # whether it was reduced state or a score block.
        self.collective_bytes = 0
        self.reduced_pull_bytes = 0
        self.block_pull_bytes = 0
        self.n_collectives = 0
        self.n_pulls = 0
        self._spec = (PartitionSpec(axes if len(axes) > 1 else axes[0])
                      if axes else PartitionSpec())
        self._rep_fns = {}
        self._jit_fns = {}

    @property
    def merge_path(self) -> str:
        """"device" when cross-shard merges run collectives, else "host"."""
        return "device" if self.n_shards > 1 else "host"

    # -- placement -------------------------------------------------------------
    def put_replicated(self, arr):
        """Host array -> device, replicated over the mesh (or local)."""
        if self.mesh is None:
            return jnp.asarray(arr)
        a = np.asarray(arr)
        ns = NamedSharding(self.mesh, PartitionSpec())
        if not self.multiprocess:
            return jax.device_put(a, ns)
        # Non-addressable-safe: each process materializes its own copies.
        return jax.make_array_from_callback(a.shape, ns, lambda idx: a[idx])

    # -- replication (all_gather + device un-permute) --------------------------
    def _sharded(self, x) -> bool:
        return (self.n_shards > 1 and isinstance(x, jax.Array)
                and not x.is_fully_replicated
                and len(x.sharding.device_set) > 1)

    def _localize(self, x):
        """Pull a committed single-device array to host (multi-controller).

        The ref backend computes locally (identically on every process);
        feeding its committed local arrays into a jit whose out_shardings
        span the mesh would be a device mismatch, so hand jit the host
        value instead.
        """
        if (self.multiprocess and isinstance(x, jax.Array)
                and len(x.sharding.device_set) == 1):
            return np.asarray(x)
        return x

    def _replicator(self, unpermute: bool):
        fn = self._rep_fns.get(unpermute)
        if fn is None:
            from jax.experimental.shard_map import shard_map
            S, axes = self.n_shards, self.axes
            def body(x):
                g = jax.lax.all_gather(x, axes, axis=0, tiled=True)
                if unpermute:
                    # Physical (shard-major) -> logical order, on device.
                    R = g.shape[0]
                    g = g.reshape(S, R // S, *g.shape[1:]).swapaxes(
                        0, 1).reshape(R, *g.shape[1:])
                return g
            fn = jax.jit(shard_map(
                body, mesh=self.mesh, in_specs=(self._spec,),
                out_specs=PartitionSpec(), check_rep=False))
            self._rep_fns[unpermute] = fn
        return fn

    def pull(self, x, *, unpermute: bool = False,
             kind: str = "reduced") -> np.ndarray:
        """Device value -> host ndarray, collectively replicated first.

        Row-sharded inputs are all-gathered (and un-permuted to logical
        row order when asked) under ``shard_map`` before the host sees a
        byte; replicated/local inputs pull directly.  ``kind`` buckets
        the transfer accounting ("reduced" state vs. score "block").
        """
        tr = self.obs.tracer
        with tr.span("pull",
                     {"kind": kind} if tr.enabled else None) as sp:
            if self._sharded(x):
                rep = self._replicator(unpermute)(x)
                self.n_collectives += 1
                self.collective_bytes += (int(rep.nbytes)
                                          * (self.n_shards - 1)) \
                    // self.n_shards
                out = np.asarray(rep)
            else:
                out = np.asarray(x)
                if unpermute and self.n_shards > 1:
                    out = _sharding.cyclic_unpermute(out, self.n_shards)
            self.n_pulls += 1
            if kind == "block":
                self.block_pull_bytes += out.nbytes
            else:
                self.reduced_pull_bytes += out.nbytes
            if tr.enabled:
                sp.set("bytes", int(out.nbytes))
        return out

    # -- jitted per-chunk reductions -------------------------------------------
    def _jit(self, key, build):
        fn = self._jit_fns.get(key)
        if fn is None:
            fn = self._jit_fns[key] = build()
        return fn

    def chunk_best(self, scores):
        """(rows, L[, Q]) -> ((rows[, Q]) argmax, (rows[, Q]) max), jitted."""
        fn = self._jit("best", lambda: jax.jit(
            lambda s: (jnp.argmax(s, axis=1), jnp.max(s, axis=1))))
        tr = self.obs.tracer
        with tr.span("merge", {"op": "best"} if tr.enabled else None):
            return fn(scores)

    def hot_mask(self, scores, thr_int: np.ndarray):
        """(rows,) bool: any alignment (any query) reaches the threshold.

        ``thr_int`` is ``ceil(threshold)`` as int32 (() or (Q,)): scores
        are integers, so the integer compare is exact -- no float32
        rounding can create a false negative against the host's float64
        hit extraction.
        """
        def build():
            def hot(s, t):
                m = (s >= t[None, None, :]) if s.ndim == 3 else (s >= t)
                return m.any(axis=tuple(range(1, m.ndim)))
            return jax.jit(hot)
        tr = self.obs.tracer
        with tr.span("merge", {"op": "hot_mask"} if tr.enabled else None):
            return self._jit("hot", build)(scores,
                                           np.asarray(thr_int, np.int32))

    def or_(self, a, b):
        """Jitted elementwise OR (filter flag union across patterns)."""
        return self._jit("or", lambda: jax.jit(lambda x, y: x | y))(a, b)

    def gather_rows(self, arr, idx: np.ndarray):
        """Rows ``idx`` of a (possibly row-sharded) array, replicated.

        The cross-shard gather happens device-side; the result is fully
        replicated so any process may pull it.  ``idx`` is a host array
        (identical on every process by SPMD discipline).
        """
        idx = np.asarray(idx)
        tr = self.obs.tracer
        with tr.span("merge",
                     {"op": "gather_rows"} if tr.enabled else None):
            if self.mesh is None:
                return jnp.take(arr, jnp.asarray(idx), axis=0)
            arr = self._localize(arr)
            def build():
                ns = NamedSharding(self.mesh, PartitionSpec())
                return jax.jit(lambda a, i: jnp.take(a, i, axis=0),
                               out_shardings=ns)
            out = self._jit("gather", build)(arr, idx)
            self.n_collectives += 1
            self.collective_bytes += (int(out.nbytes)
                                      * (self.n_shards - 1)) // self.n_shards
            return out

    # -- top-k tree merge ------------------------------------------------------
    def _shard_index(self):
        s = jax.lax.axis_index(self.axes[0])
        for a in self.axes[1:]:
            s = s * self.mesh.shape[a] + jax.lax.axis_index(a)
        return s

    @staticmethod
    def _lexsort_merge(cs, cr, k):
        """(Q, m) candidates -> (Q, k) under (score desc, row asc).

        Scores are int32 >= -1, so negation is exact (no INT32_MIN).
        """
        def pick(s_col, r_col):
            order = jnp.lexsort((r_col, -s_col))[:k]
            return s_col[order], r_col[order]
        return jax.vmap(pick)(cs, cr)

    def _phys_topk(self):
        def build():
            from jax.experimental.shard_map import shard_map
            S = self.n_shards

            def body(bs, alive_rep, c0, st_s, st_r):
                # bs: per-shard (Jc[, Q]) best-score block, physical
                # layout; alive_rep: (chunk,) bool over logical in-chunk
                # positions (False past the valid rows); st_*: (k[, Q]).
                s_idx = self._shard_index()
                Jc = bs.shape[0]
                rows = (c0 + jnp.arange(Jc, dtype=jnp.int32) * S
                        + s_idx.astype(jnp.int32))
                alive = alive_rep[jnp.arange(Jc) * S + s_idx]
                bs2 = bs if bs.ndim == 2 else bs[:, None]
                st_s2 = st_s if st_s.ndim == 2 else st_s[:, None]
                st_r2 = st_r if st_r.ndim == 2 else st_r[:, None]
                k = st_s2.shape[0]
                sc = jnp.where(alive[:, None], bs2.astype(jnp.int32),
                               SCORE_SENTINEL)
                rw = jnp.where(alive[:, None],
                               jnp.broadcast_to(rows[:, None], bs2.shape),
                               ROW_SENTINEL)
                # Shard-local maxima: lax.top_k ties break to the lowest
                # index, which in a shard block is the lowest slot and so
                # the lowest logical row -- the lexsort total order.
                k_loc = min(k, Jc)
                ts, ti = jax.lax.top_k(sc.T, k_loc)          # (Q, k_loc)
                tr = jnp.take_along_axis(rw.T, ti, axis=1)
                gs = jax.lax.all_gather(ts, self.axes, axis=1, tiled=True)
                gr = jax.lax.all_gather(tr, self.axes, axis=1, tiled=True)
                cs = jnp.concatenate([st_s2.T, gs], axis=1)
                cr = jnp.concatenate([st_r2.T, gr], axis=1)
                ns_, nr_ = self._lexsort_merge(cs, cr, k)
                out_s, out_r = ns_.T, nr_.T
                if bs.ndim == 1:
                    return out_s[:, 0], out_r[:, 0]
                return out_s, out_r

            P0 = PartitionSpec()
            return jax.jit(shard_map(
                body, mesh=self.mesh,
                in_specs=(self._spec, P0, P0, P0, P0),
                out_specs=(P0, P0), check_rep=False))
        return self._jit("phys_topk", build)

    def _logical_topk(self):
        def build():
            def upd(st_s, st_r, bs, rows, alive):
                # bs: (n[, Q]) best scores in *logical* candidate order
                # (rows= subsets / filter survivors / unsharded scans);
                # rows: (n,) int32 corpus ids; alive: (n,) bool.
                bs2 = bs if bs.ndim == 2 else bs[:, None]
                st_s2 = st_s if st_s.ndim == 2 else st_s[:, None]
                st_r2 = st_r if st_r.ndim == 2 else st_r[:, None]
                k = st_s2.shape[0]
                sc = jnp.where(alive[:, None], bs2.astype(jnp.int32),
                               SCORE_SENTINEL)
                rw = jnp.where(alive[:, None],
                               jnp.broadcast_to(rows[:, None], bs2.shape),
                               ROW_SENTINEL)
                cs = jnp.concatenate([st_s2.T, sc.T], axis=1)
                cr = jnp.concatenate([st_r2.T, rw.T], axis=1)
                ns_, nr_ = self._lexsort_merge(cs, cr, k)
                out_s, out_r = ns_.T, nr_.T
                if bs.ndim == 1:
                    return out_s[:, 0], out_r[:, 0]
                return out_s, out_r
            if self.mesh is not None:
                ns = NamedSharding(self.mesh, PartitionSpec())
                return jax.jit(upd, out_shardings=(ns, ns))
            return jax.jit(upd)
        return self._jit("logical_topk", build)

    def topk_init(self, k: int, n_cols: int):
        """Sentinel-filled running state ((k[, Q]) scores + rows)."""
        shape = (k, n_cols) if n_cols else (k,)
        return (np.full(shape, SCORE_SENTINEL, np.int32),
                np.full(shape, ROW_SENTINEL, np.int32))

    def topk_update(self, state, bs, *, phys: bool, alive_chunk: np.ndarray,
                    c0: int = 0, rows_np: Optional[np.ndarray] = None):
        """Fold one chunk's best scores into the running top-k state.

        ``phys=True``: ``bs`` is the row-sharded physical-layout chunk --
        shard-local top-k + all_gather + replicated lexsort merge, one
        jitted ``shard_map`` call.  ``phys=False``: ``bs`` follows
        logical candidate order and ``rows_np`` carries the corpus ids.
        ``alive_chunk`` is the in-chunk validity/tombstone mask (logical
        positions), identical on every process.
        """
        st_s, st_r = state
        alive_chunk = np.asarray(alive_chunk, bool)
        tr = self.obs.tracer
        with tr.span("merge", {"op": "topk"} if tr.enabled else None):
            if phys:
                fn = self._phys_topk()
                st_s, st_r = fn(bs, alive_chunk, np.int32(c0), st_s, st_r)
                if self.n_shards > 1:
                    k_loc = min(np.shape(st_s)[0],
                                bs.shape[0] // self.n_shards)
                    cols = bs.shape[1] if bs.ndim == 2 else 1
                    self.n_collectives += 1
                    self.collective_bytes += (self.n_shards - 1) * k_loc * \
                        cols * 12
            else:
                fn = self._logical_topk()
                st_s, st_r = fn(st_s, st_r, self._localize(bs),
                                np.asarray(rows_np, np.int32), alive_chunk)
        return st_s, st_r

    def topk_finalize(self, state, n_alive: int, k: int):
        """Pull the replicated state, trim sentinels: ((kk[, Q]) rows,
        scores) with kk = min(k, live candidates seen)."""
        st_s, st_r = state
        rows = self.pull(st_r, kind="reduced").astype(np.int64)
        scores = self.pull(st_s, kind="reduced")
        kk = min(int(k), int(n_alive))
        return rows[:kk], scores[:kk]

    # -- filter survivor union -------------------------------------------------
    def survivor_union(self, flags, n_rows: int) -> np.ndarray:
        """(S*jn, 1) per-shard candidate flags -> (n_rows,) logical bool.

        The cross-shard union is the device-side all_gather (+ device
        un-permute back to logical row order); the host only receives
        the final replicated bitmap.
        """
        out = self.pull(flags, unpermute=True, kind="reduced")
        return out[:n_rows, 0].astype(bool)
